"""L1 correctness: Pallas kernel vs pure-jnp oracle vs an independent
pure-python walker, swept over shapes/dtypes with hypothesis.

Everything here is integer-exact: assertions are bit-equality, the
strongest possible parity statement (matching the paper's 'identical
predictions' claim at the tensor level)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import forest as forest_kernel
from compile.kernels import ref as forest_ref


# ---------------------------------------------------------------------------
# forest generator + independent python oracle
# ---------------------------------------------------------------------------

def build_random_forest(rng, T, N, C, F, max_depth):
    """Random padded forest tensors with leaf self-loops.

    Leaf values are bounded by floor((2**32-1)/T) so that summation over
    T trees cannot overflow u32 (the quant module's invariant)."""
    feat = np.zeros((T, N), dtype=np.int32)
    thresh = np.zeros((T, N), dtype=np.uint32)
    left = np.zeros((T, N), dtype=np.int32)
    right = np.zeros((T, N), dtype=np.int32)
    leaf_val = np.zeros((T, N, C), dtype=np.uint32)
    cap = (2**32 - 1) // max(T, 1)

    for t in range(T):
        next_free = [1]  # node 0 is the root

        def grow(i, depth):
            # Decide leaf vs branch: must leaf out at max_depth or when
            # the node budget is exhausted.
            can_branch = next_free[0] + 2 <= N and depth < max_depth
            if not can_branch or rng.random() < 0.3:
                left[t, i] = i  # self-loop
                right[t, i] = i
                leaf_val[t, i] = rng.integers(0, cap + 1, size=C, dtype=np.uint32)
                return
            feat[t, i] = rng.integers(0, F)
            thresh[t, i] = rng.integers(0, 2**32, dtype=np.uint32)
            l, r = next_free[0], next_free[0] + 1
            next_free[0] += 2
            left[t, i] = l
            right[t, i] = r
            grow(l, depth + 1)
            grow(r, depth + 1)

        grow(0, 0)
        # padding nodes beyond next_free: already zero-filled; make them
        # harmless self-loops so stray pointers can't escape.
        for i in range(next_free[0], N):
            left[t, i] = i
            right[t, i] = i

    return feat, thresh, left, right, leaf_val


def walker_oracle(x, feat, thresh, left, right, leaf_val, depth):
    """Scalar python traversal — fully independent of jax."""
    B = x.shape[0]
    T = feat.shape[0]
    C = leaf_val.shape[2]
    out = np.zeros((B, C), dtype=np.uint32)
    for b in range(B):
        for t in range(T):
            i = 0
            for _ in range(depth):
                if left[t, i] == i and right[t, i] == i:
                    break  # at a leaf
                if x[b, feat[t, i]] <= thresh[t, i]:
                    i = left[t, i]
                else:
                    i = right[t, i]
            # after depth steps we must be at a leaf (self-loop)
            out[b] = (out[b] + leaf_val[t, i]).astype(np.uint32)
    return out


def random_x(rng, B, F):
    return rng.integers(0, 2**32, size=(B, F), dtype=np.uint32)


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------

shapes = st.tuples(
    st.integers(1, 6),   # T
    st.integers(1, 8),   # C
    st.integers(1, 8),   # F
    st.integers(0, 5),   # max_depth
    st.integers(1, 3),   # batch blocks
)


@settings(max_examples=40, deadline=None)
@given(shapes, st.integers(0, 2**32 - 1))
def test_ref_matches_walker(shape, seed):
    T, C, F, max_depth, blocks = shape
    rng = np.random.default_rng(seed)
    N = 2 ** (max_depth + 1) - 1
    fo = build_random_forest(rng, T, N, C, F, max_depth)
    B = 8 * blocks
    x = random_x(rng, B, F)
    got = np.asarray(forest_ref.forest_infer_ref(x, *fo, depth=max_depth))
    want = walker_oracle(x, *fo, depth=max_depth)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(0, 2**32 - 1))
def test_pallas_matches_ref(shape, seed):
    T, C, F, max_depth, blocks = shape
    rng = np.random.default_rng(seed)
    N = 2 ** (max_depth + 1) - 1
    fo = build_random_forest(rng, T, N, C, F, max_depth)
    B = 8 * blocks
    x = random_x(rng, B, F)
    got = np.asarray(
        forest_kernel.forest_infer(x, *fo, depth=max_depth, block_b=8)
    )
    want = np.asarray(forest_ref.forest_infer_ref(x, *fo, depth=max_depth))
    np.testing.assert_array_equal(got, want)


def test_extra_depth_is_harmless():
    """Leaves self-loop: running more levels than the tree depth must not
    change the result (this is what lets one artifact serve any model of
    depth <= tier depth)."""
    rng = np.random.default_rng(7)
    fo = build_random_forest(rng, 4, 31, 3, 5, 4)
    x = random_x(rng, 16, 5)
    a = np.asarray(forest_ref.forest_infer_ref(x, *fo, depth=4))
    b = np.asarray(forest_ref.forest_infer_ref(x, *fo, depth=9))
    np.testing.assert_array_equal(a, b)


def test_padding_trees_are_inert():
    """All-zero padded trees contribute nothing."""
    rng = np.random.default_rng(8)
    T, N, C, F, d = 3, 15, 4, 6, 3
    feat, thresh, left, right, leaf_val = build_random_forest(rng, T, N, C, F, d)
    # embed into T+3 trees, padding = zeros with self-loop at node 0
    T2 = T + 3
    feat2 = np.zeros((T2, N), np.int32)
    thresh2 = np.zeros((T2, N), np.uint32)
    left2 = np.zeros((T2, N), np.int32)
    right2 = np.zeros((T2, N), np.int32)
    leaf2 = np.zeros((T2, N, C), np.uint32)
    feat2[:T], thresh2[:T], left2[:T], right2[:T], leaf2[:T] = feat, thresh, left, right, leaf_val
    x = random_x(rng, 8, F)
    a = np.asarray(forest_ref.forest_infer_ref(x, feat, thresh, left, right, leaf_val, depth=d))
    b = np.asarray(
        forest_ref.forest_infer_ref(x, feat2, thresh2, left2, right2, leaf2, depth=d)
    )
    np.testing.assert_array_equal(a, b)


def test_output_dtype_is_u32():
    rng = np.random.default_rng(9)
    fo = build_random_forest(rng, 2, 7, 2, 3, 2)
    x = random_x(rng, 8, 3)
    out = forest_ref.forest_infer_ref(x, *fo, depth=2)
    assert str(out.dtype) == "uint32"
    out2 = forest_kernel.forest_infer(x, *fo, depth=2, block_b=8)
    assert str(out2.dtype) == "uint32"


def test_near_cap_leaves_do_not_overflow():
    """T trees each contributing the cap must sum below 2^32 (quant
    invariant carried into the tensor path)."""
    T, N, C, F, d = 8, 3, 2, 2, 1
    cap = (2**32 - 1) // T
    feat = np.zeros((T, N), np.int32)
    thresh = np.zeros((T, N), np.uint32)  # always go left
    left = np.zeros((T, N), np.int32)
    right = np.zeros((T, N), np.int32)
    leaf_val = np.zeros((T, N, C), np.uint32)
    for t in range(T):
        # root branches to node 1 (left) / node 2 (right); both leaves.
        feat[t, 0] = 0
        thresh[t, 0] = 2**31
        left[t, 0], right[t, 0] = 1, 2
        for i in (1, 2):
            left[t, i] = i
            right[t, i] = i
            leaf_val[t, i] = cap
    x = np.zeros((4, F), np.uint32)
    out = np.asarray(forest_ref.forest_infer_ref(x, feat, thresh, left, right, leaf_val, depth=d))
    assert (out == np.uint32(cap * T)).all()
    assert cap * T <= 2**32 - 1


def test_unsigned_compare_semantics():
    """Thresholds above 2^31 must compare as unsigned (a signed compare
    would flip the branch) — the FlInt ordered-u32 domain."""
    T, N, C, F, d = 1, 3, 1, 1, 1
    feat = np.zeros((T, N), np.int32)
    thresh = np.full((T, N), np.uint32(0x9000_0000), dtype=np.uint32)
    left = np.array([[1, 1, 2]], np.int32)
    right = np.array([[2, 1, 2]], np.int32)
    leaf_val = np.zeros((T, N, C), np.uint32)
    leaf_val[0, 1, 0] = 111  # left leaf
    leaf_val[0, 2, 0] = 222  # right leaf
    x_low = np.array([[0x8FFF_FFFF]], np.uint32)   # <= threshold -> left
    x_high = np.array([[0x9000_0001]], np.uint32)  # > threshold -> right
    lo = np.asarray(forest_ref.forest_infer_ref(x_low, feat, thresh, left, right, leaf_val, depth=d))
    hi = np.asarray(forest_ref.forest_infer_ref(x_high, feat, thresh, left, right, leaf_val, depth=d))
    assert lo[0, 0] == 111 and hi[0, 0] == 222


def test_ordered_map_matches_rust_semantics():
    """ordered_u32_np must preserve float ordering (mirrors the rust
    proptest; the two implementations must agree for the artifact path
    to be sound)."""
    rng = np.random.default_rng(10)
    bits = rng.integers(0, 2**32, size=4000, dtype=np.uint32)
    vals = bits.view(np.float32)
    finite = vals[np.isfinite(vals)]
    m = forest_ref.ordered_u32_np(finite)
    order_f = np.argsort(finite, kind="stable")
    # the integer image must sort identically (ties only at +/-0)
    sf = finite[order_f]
    sm = m[order_f]
    assert (np.diff(sf) >= 0).all()
    assert (np.diff(sm.astype(np.uint64)) >= np.where(np.diff(sf) == 0, -(2**33), 0)).all()
    # strict check on distinct values
    distinct = np.diff(sf) > 0
    assert (np.diff(sm.astype(np.int64))[distinct] > 0).all()


def test_vmem_report_shapes():
    r = forest_kernel.vmem_report(T=64, N=255, C=8, F=8, block_b=64, depth=8)
    assert r["vmem_fits_16mb"]
    assert r["arith_intensity"] > 10
