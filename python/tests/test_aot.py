"""AOT pipeline tests: artifact emission + manifest contract with the
rust runtime."""

import json
import os
import tempfile

from compile import aot


def test_quick_build_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.build(d, quick=True)
        assert manifest["format"] == "intreeger-artifacts-v1"
        names = [t["name"] for t in manifest["tiers"]]
        assert "quick" in names and "quick_jnp" in names
        for t in manifest["tiers"]:
            path = os.path.join(d, t["file"])
            assert os.path.isfile(path), t["file"]
            text = open(path).read()
            assert text.startswith("HloModule"), t["file"]
            assert "mosaic" not in text.lower(), "pallas must lower via interpret mode"
            # manifest fields the rust side requires
            for key in ("B", "F", "T", "N", "C", "depth", "use_pallas"):
                assert key in t, key
        # manifest on disk round-trips
        on_disk = json.load(open(os.path.join(d, "manifest.json")))
        assert on_disk["tiers"] == manifest["tiers"]


def test_hlo_parameter_order_matches_runtime_contract():
    """The rust runtime feeds (x, feat, thresh, left, right, leaf_val) in
    that order; the lowered HLO must have 6 parameters with the expected
    element types (u32/i32/u32/i32/i32/u32)."""
    with tempfile.TemporaryDirectory() as d:
        aot.build(d, quick=True)
        text = open(os.path.join(d, "forest_quick.hlo.txt")).read()
        # The top-level computation declares exactly these typed
        # parameters in this order (sub-computations have their own
        # numbering, so check for the specific typed declarations).
        expected = [
            "u32[64,8]{1,0} parameter(0)",      # x
            "s32[16,63]{1,0} parameter(1)",     # feat
            "u32[16,63]{1,0} parameter(2)",     # thresh
            "s32[16,63]{1,0} parameter(3)",     # left
            "s32[16,63]{1,0} parameter(4)",     # right
            "u32[16,63,8]{2,1,0} parameter(5)", # leaf_val
        ]
        for decl in expected:
            assert decl in text, decl
