"""L2 tests: lowering to HLO text, tier semantics, pallas/jnp agreement
at tier shapes."""

import numpy as np

from compile import aot, model
from compile.kernels import forest as forest_kernel
from compile.kernels import ref as forest_ref
from .test_kernel import build_random_forest, random_x


def test_lower_quick_tier_jnp_to_hlo_text():
    lowered = model.lower_fn(B=8, F=4, T=2, N=7, C=2, depth=2, use_pallas=False)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # output is a tuple of one u32[8,2]
    assert "u32[8,2]" in text.replace(" ", "")


def test_lower_quick_tier_pallas_to_hlo_text():
    lowered = model.lower_fn(B=8, F=4, T=2, N=7, C=2, depth=2, block_b=8, use_pallas=True)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO: no mosaic custom-call
    assert "mosaic" not in text.lower()


def test_pallas_and_jnp_paths_agree_at_tier_shape():
    rng = np.random.default_rng(3)
    B, F, T, N, C, depth = 64, 8, 16, 63, 8, 6
    fo = build_random_forest(rng, T, N, C, F, depth)
    x = random_x(rng, B, F)
    a = np.asarray(model.forest_infer_pallas(x, *fo, depth=depth, block_b=32))
    b = np.asarray(model.forest_infer_jnp(x, *fo, depth=depth))
    np.testing.assert_array_equal(a, b)


def test_tier_table_is_consistent():
    names = [t["name"] for t in aot.TIERS]
    assert len(names) == len(set(names))
    for t in aot.TIERS:
        assert t["B"] % t["block_b"] == 0, t["name"]
        # node capacity must cover a full tree of the tier depth? Not
        # required (trees may be sparse), but N must at least allow depth.
        assert t["N"] >= 2 * t["depth"] + 1
        # VMEM sanity for the pallas tiers
        if t["use_pallas"]:
            r = forest_kernel.vmem_report(
                T=t["T"], N=t["N"], C=t["C"], F=t["F"], block_b=t["block_b"], depth=t["depth"]
            )
            assert r["vmem_fits_16mb"], t["name"]


def test_ordered_map_edge_values():
    m = forest_ref.ordered_u32_np
    assert m(np.array([-0.0], np.float32))[0] == m(np.array([0.0], np.float32))[0]
    vals = np.array([-np.finfo(np.float32).max, -1.0, 0.0, 1.0, np.finfo(np.float32).max], np.float32)
    mm = m(vals).astype(np.uint64)
    assert (np.diff(mm) > 0).all()
