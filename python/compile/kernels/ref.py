"""Pure-jnp reference oracle for the forest-inference kernel.

This is the L1 correctness anchor: the Pallas kernel
(:mod:`compile.kernels.forest`) must agree with this implementation
exactly (integer outputs - bit equality, no tolerance).

Tensor encoding of a padded forest (see DESIGN.md, Hardware-Adaptation):

* ``feat``     i32[T, N]  - feature index per node (0 for leaves/padding)
* ``thresh``   u32[T, N]  - order-preserved FlInt threshold per node
* ``left``     i32[T, N]  - left-child index; leaves self-loop (left=i)
* ``right``    i32[T, N]  - right-child index; leaves self-loop
* ``leaf_val`` u32[T, N, C] - quantized leaf contribution (0 for branches)
* ``x``        u32[B, F]  - order-preserved input features

Traversal is level-synchronous: every (sample, tree) pair advances one
level per step; leaves self-loop so running more steps than a tree's
depth is harmless. After ``depth`` steps every pointer rests on a leaf
and the output is the u32 sum of leaf contributions over trees - the
paper's integer-only accumulation (paper III-A), vectorized.
"""

import jax.numpy as jnp


def forest_infer_ref(x, feat, thresh, left, right, leaf_val, *, depth):
    """Reference forest inference.

    Args:
      x: u32[B, F] order-preserved features.
      feat/thresh/left/right/leaf_val: padded forest tensors (see module).
      depth: number of traversal steps (>= max tree depth).

    Returns:
      u32[B, C] accumulated fixed-point class scores.
    """
    B = x.shape[0]
    T = feat.shape[0]
    t_idx = jnp.arange(T, dtype=jnp.int32)[None, :]  # [1, T]
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]  # [B, 1]

    ptr = jnp.zeros((B, T), dtype=jnp.int32)
    for _ in range(depth):
        f = feat[t_idx, ptr]          # [B, T] feature index per (b, t)
        th = thresh[t_idx, ptr]       # [B, T]
        xv = x[b_idx, f]              # [B, T]
        go_left = xv <= th            # unsigned compare
        ptr = jnp.where(go_left, left[t_idx, ptr], right[t_idx, ptr])

    contrib = leaf_val[t_idx, ptr]    # [B, T, C]
    return jnp.sum(contrib, axis=1, dtype=jnp.uint32)


def ordered_u32_np(x_f32):
    """numpy version of flint::ordered_u32 (order-preserving f32->u32
    map, -0.0 canonicalized). Used by tests and the artifact packer."""
    import numpy as np

    x = np.asarray(x_f32, dtype=np.float32).copy()
    x[x == 0.0] = 0.0  # canonicalize -0.0
    b = x.view(np.uint32)
    return np.where(b & 0x8000_0000 != 0, ~b, b | 0x8000_0000).astype(np.uint32)
