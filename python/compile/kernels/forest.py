"""L1 Pallas kernel: batched integer-only forest traversal.

HARDWARE ADAPTATION (DESIGN.md): the paper's insight — decision-tree
inference needs only the cheapest integer ops once thresholds (FlInt) and
leaf probabilities (fixed point) are integers — is re-thought here for a
vector unit instead of a scalar pipeline. The branchy if-else tree
becomes a *level-synchronous gather traversal*: one loop iteration per
tree level advances all (sample, tree) pairs at once with vectorized u32
compares (the VPU analogue of the paper's `lui`-immediate integer
compares) and the ensemble accumulation is a u32 segment-sum. No float
op appears in the kernel — the paper's property, transplanted to TPU.

Blocking: the grid tiles the batch dimension; the node tables (feat /
thresh / left / right / leaf_val — the reused operand) stay resident in
VMEM across grid steps while samples stream in per block. See
``vmem_report`` for the footprint estimate used in DESIGN.md §Perf.

The kernel runs with ``interpret=True`` — CPU PJRT cannot execute Mosaic
custom-calls; real-TPU behaviour is estimated analytically (§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forest_kernel(x_ref, feat_ref, thresh_ref, left_ref, right_ref, leaf_ref, o_ref, *, depth):
    """One batch block: traverse all T trees for BB samples, depth steps.

    §Perf: node tables are flattened to 1-D and indexed with
    ``ptr + tree_offset`` so every level is a cheap rank-1 gather instead
    of 2-D advanced indexing (XLA:CPU lowers the latter to a slower
    general gather; flat form measured 9-16% faster end to end)."""
    x = x_ref[...]            # u32[BB, F]
    feat = feat_ref[...]      # i32[T, N]
    thresh = thresh_ref[...]  # u32[T, N]
    left = left_ref[...]      # i32[T, N]
    right = right_ref[...]    # i32[T, N]

    bb = x.shape[0]
    t, n = feat.shape
    offs = (jnp.arange(t, dtype=jnp.int32) * n)[None, :]        # [1, T]
    b_off = (jnp.arange(bb, dtype=jnp.int32) * x.shape[1])[:, None]
    featf = feat.reshape(-1)
    threshf = thresh.reshape(-1)
    leftf = left.reshape(-1)
    rightf = right.reshape(-1)
    xf = x.reshape(-1)

    def level(_, ptr):
        g = ptr + offs                                          # [BB, T] flat node ids
        f = jnp.take(featf, g)
        th = jnp.take(threshf, g)
        xv = jnp.take(xf, f + b_off)
        go_left = xv <= th
        return jnp.where(go_left, jnp.take(leftf, g), jnp.take(rightf, g))

    ptr0 = jnp.zeros((bb, t), dtype=jnp.int32)
    ptr = jax.lax.fori_loop(0, depth, level, ptr0)

    leaff = leaf_ref[...].reshape(t * n, -1)
    contrib = jnp.take(leaff, ptr + offs, axis=0)               # u32[BB, T, C]
    o_ref[...] = jnp.sum(contrib, axis=1, dtype=jnp.uint32)


def forest_infer(x, feat, thresh, left, right, leaf_val, *, depth, block_b=64):
    """Batched forest inference via the Pallas kernel.

    Args mirror :func:`compile.kernels.ref.forest_infer_ref`; the batch
    dimension must be a multiple of ``block_b`` (the AOT wrapper pads).

    Returns u32[B, C].
    """
    B, _F = x.shape
    T, N = feat.shape
    C = leaf_val.shape[2]
    assert B % block_b == 0, f"batch {B} not a multiple of block {block_b}"
    assert leaf_val.shape[:2] == (T, N)

    grid = (B // block_b,)
    kernel = functools.partial(_forest_kernel, depth=depth)
    # Node tables use a constant index_map: one VMEM-resident copy reused
    # by every grid step.
    table = lambda shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, x.shape[1]), lambda b: (b, 0)),
            table(feat.shape),
            table(thresh.shape),
            table(left.shape),
            table(right.shape),
            table(leaf_val.shape),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.uint32),
        interpret=True,
    )(x, feat, thresh, left, right, leaf_val)


def vmem_report(*, T, N, C, F, block_b, depth):
    """Analytic VMEM/roofline estimate for DESIGN.md §Perf (interpret mode
    gives no hardware numbers; structure is what we can optimize).

    Returns a dict with the VMEM footprint of one grid step and the
    arithmetic intensity of the traversal (ops per byte fetched from HBM,
    assuming node tables stay resident)."""
    bytes_tables = (4 * T * N) * 4 + 4 * T * N * C  # feat/thresh/left/right + leaves
    bytes_x = 4 * block_b * F
    bytes_out = 4 * block_b * C
    bytes_ptr = 4 * block_b * T
    vmem = bytes_tables + bytes_x + bytes_out + 2 * bytes_ptr
    # per sample: depth * T compares/selects + T*C adds; HBM traffic per
    # sample: its features + its output (tables amortized across batch).
    ops = depth * T * 4 + T * C
    hbm_bytes = 4 * F + 4 * C
    return {
        "vmem_bytes": vmem,
        "vmem_fits_16mb": vmem <= 16 * 1024 * 1024,
        "ops_per_sample": ops,
        "hbm_bytes_per_sample": hbm_bytes,
        "arith_intensity": ops / hbm_bytes,
    }
