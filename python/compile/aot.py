"""AOT lowering: JAX/Pallas forest inference -> HLO text artifacts.

Run once at build time (``make artifacts``); python never appears on the
request path. The rust runtime (rust/src/runtime) loads the HLO text via
``HloModuleProto::from_text_file``, compiles it with the PJRT CPU client
and executes it with concrete forest tensors.

Interchange format is HLO TEXT, not a serialized proto: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.
(See /opt/xla-example/README.md.)

Artifact tiers are fixed-shape compilations; the rust side pads a model
into the smallest tier that fits (leaves self-loop, padding trees
contribute zero, so extra capacity is semantically inert). A manifest
JSON describes every emitted artifact.

Usage: python -m compile.aot [--out DIR] [--quick]
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model

# (name, B, F, T, N, C, depth, block_b, use_pallas)
TIERS = [
    # Quick tier: used by unit/integration tests everywhere.
    dict(name="quick", B=64, F=8, T=16, N=63, C=8, depth=6, block_b=32, use_pallas=True),
    # Same shape through the pure-jnp path: runtime cross-check artifact.
    dict(name="quick_jnp", B=64, F=8, T=16, N=63, C=8, depth=6, block_b=32, use_pallas=False),
    # Shuttle-shaped serving tier (7 features / 7 classes, <=64 trees).
    dict(name="shuttle", B=256, F=8, T=64, N=255, C=8, depth=8, block_b=64, use_pallas=True),
    # ESA-shaped serving tier (87 features / 2 classes).
    dict(name="esa", B=256, F=88, T=64, N=255, C=2, depth=8, block_b=64, use_pallas=True),
    # Small-batch latency tier.
    dict(name="shuttle_b16", B=16, F=8, T=64, N=255, C=8, depth=8, block_b=16, use_pallas=True),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "intreeger-artifacts-v1", "tiers": []}
    tiers = [t for t in TIERS if t["name"].startswith("quick")] if quick else TIERS
    for tier in tiers:
        name = tier["name"]
        lowered = model.lower_fn(
            B=tier["B"],
            F=tier["F"],
            T=tier["T"],
            N=tier["N"],
            C=tier["C"],
            depth=tier["depth"],
            block_b=tier["block_b"],
            use_pallas=tier["use_pallas"],
        )
        text = to_hlo_text(lowered)
        fname = f"forest_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = dict(tier)
        entry["file"] = fname
        entry["hlo_bytes"] = len(text)
        manifest["tiers"].append(entry)
        print(f"  wrote {fname}: {len(text)} chars "
              f"(B={tier['B']} F={tier['F']} T={tier['T']} N={tier['N']} "
              f"C={tier['C']} depth={tier['depth']})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="only the quick tiers (tests)")
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
