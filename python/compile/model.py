"""L2 JAX model: the batched forest-inference graph the rust runtime
executes via AOT-compiled artifacts.

The graph is deliberately integer-only end to end (the paper's defining
property): inputs are order-preserved u32 feature words, the traversal
compares u32, and the output is the u32 fixed-point class accumulator.
Argmax/probability conversion happens in rust (or not at all — ranking
needs no conversion).

Two interchangeable implementations:

* :func:`forest_infer_pallas` — the L1 Pallas kernel (production graph);
* :func:`forest_infer_jnp` — the pure-jnp oracle (compiled as a
  cross-check artifact and used by pytest).

Both lower to the same interface: ``f(x, feat, thresh, left, right,
leaf_val) -> u32[B, C]`` with all shapes static per artifact tier.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import forest as forest_kernel
from .kernels import ref as forest_ref


def forest_infer_pallas(x, feat, thresh, left, right, leaf_val, *, depth, block_b=64):
    """Production forest inference (Pallas kernel inside)."""
    return forest_kernel.forest_infer(
        x, feat, thresh, left, right, leaf_val, depth=depth, block_b=block_b
    )


def forest_infer_jnp(x, feat, thresh, left, right, leaf_val, *, depth):
    """Oracle forest inference (pure jnp)."""
    return forest_ref.forest_infer_ref(x, feat, thresh, left, right, leaf_val, depth=depth)


def lower_fn(*, B, F, T, N, C, depth, block_b=64, use_pallas=True):
    """Build and lower the jitted inference function for one artifact
    tier. Returns the jax ``Lowered`` object."""
    if use_pallas:
        fn = functools.partial(forest_infer_pallas, depth=depth, block_b=block_b)
    else:
        fn = functools.partial(forest_infer_jnp, depth=depth)

    def wrapped(x, feat, thresh, left, right, leaf_val):
        # Tuple output: the rust loader unwraps with to_tuple1().
        return (fn(x, feat, thresh, left, right, leaf_val),)

    specs = (
        jax.ShapeDtypeStruct((B, F), jnp.uint32),
        jax.ShapeDtypeStruct((T, N), jnp.int32),
        jax.ShapeDtypeStruct((T, N), jnp.uint32),
        jax.ShapeDtypeStruct((T, N), jnp.int32),
        jax.ShapeDtypeStruct((T, N), jnp.int32),
        jax.ShapeDtypeStruct((T, N, C), jnp.uint32),
    )
    return jax.jit(wrapped).lower(*specs)
