//! Quickstart: dataset → trained Random Forest → integer-only C, in
//! under a minute. (`cargo run --release --example quickstart`)
//!
//! This is the paper's Fig 1 pipeline at its smallest: train on a
//! Shuttle-shaped dataset, verify that the integer-only model predicts
//! *identically* to the float model, and emit the architecture-agnostic
//! C file a user would drop into their firmware.

use intreeger::codegen::{generate, Layout};
use intreeger::data::shuttle_like;
use intreeger::inference::{Engine, FloatEngine, IntEngine, Variant};
use intreeger::trees::{accuracy, ForestParams, RandomForest};
use intreeger::util::Rng;

fn main() {
    // 1. Dataset in (here: the synthetic Shuttle stand-in; use
    //    `data::csv::read_file` for your own CSV).
    let ds = shuttle_like(8_000, 42);
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(1));
    println!("dataset: {} rows train / {} test, {} features, {} classes",
        train.n_rows(), test.n_rows(), ds.n_features, ds.n_classes);

    // 2. Train.
    let model = RandomForest::train(
        &train,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        7,
    );
    println!("holdout accuracy: {:.4}", accuracy(&model, &test));

    // 3. No-loss check: float vs integer-only predictions are identical.
    let fe = FloatEngine::compile(&model);
    let ie = IntEngine::compile(&model);
    let mismatches = (0..test.n_rows())
        .filter(|&i| fe.predict(test.row(i)) != ie.predict(test.row(i)))
        .count();
    println!("prediction mismatches float vs integer-only: {mismatches} (paper: always 0)");
    assert_eq!(mismatches, 0);

    // 4. Integer-only architecture-agnostic C out.
    let c = generate(&model, Layout::IfElse, Variant::IntTreeger);
    let path = std::env::temp_dir().join("intreeger_quickstart.c");
    std::fs::write(&path, &c).expect("write C");
    println!("wrote {} ({} bytes of freestanding C, zero float ops)", path.display(), c.len());
    println!("compile it anywhere: gcc -O3 {} -o model && ./model bench 100 1000", path.display());
}
