//! Quickstart: the paper's Fig 1 loop in one call — dataset → trained
//! Random Forest → quantized IR → **verified** integer-only C + report.
//! (`cargo run --release --example quickstart`)
//!
//! This drives the same `pipeline` module the `intreeger pipeline` CLI
//! command uses: train on a Shuttle-shaped dataset, machine-check that
//! the integer-only model predicts *identically* to the float model on
//! a stratified holdout (every engine × traversal kernel), and emit the
//! architecture-agnostic C file plus `report.json` / `REPORT.md`.

use intreeger::data::shuttle_like;
use intreeger::pipeline::{run, PipelineConfig};

fn main() {
    // 1. Dataset in (synthetic Shuttle stand-in; point the CLI at any
    //    CSV with `intreeger pipeline --csv data.csv --target label`).
    let ds = shuttle_like(8_000, 42);

    // 2..6. Split, train, quantize, verify, emit, report — one call.
    let out = std::env::temp_dir().join("intreeger_quickstart");
    let cfg = PipelineConfig {
        n_trees: 10,
        max_depth: 6,
        source: "synthetic:shuttle".to_string(),
        ..Default::default()
    };
    let outcome = run(&ds, &out, &cfg).expect("pipeline (an Err here means parity FAILED)");

    // `run` returning Ok IS the machine-checked "no loss of precision"
    // verdict; unpack the numbers for show.
    let r = &outcome.report;
    let rf = &r.models[0];
    println!(
        "dataset: {} rows ({} train / {} holdout), {} features, {} classes",
        r.dataset.rows, r.dataset.train_rows, r.dataset.holdout_rows,
        r.dataset.features, r.dataset.classes
    );
    println!(
        "verified: float vs integer-only argmax-identical on all {} holdout rows \
         ({} engines x {} kernels, 0 mismatches)",
        rf.parity.rows,
        rf.parity.engines.len(),
        rf.parity.kernels.len()
    );
    assert!(r.all_verified());
    assert_eq!(rf.parity.mismatches, 0);
    println!(
        "accuracy: float {:.4} / integer-only {:.4}; max fixed-point error {:.2e} \
         (paper bound n/2^32 = {:.2e})",
        rf.parity.accuracy_float, rf.parity.accuracy_int,
        rf.parity.max_abs_error, rf.parity.error_bound
    );
    let c = rf.codegen.as_ref().expect("RF emits C");
    println!(
        "artifacts in {}: {} ({} bytes of freestanding C, zero float ops{}), \
         report.json, REPORT.md, manifest.json",
        outcome.out_dir.display(),
        c.file,
        c.bytes,
        if c.gcc_checked { ", gcc parity checked" } else { "" }
    );
    println!(
        "compile it anywhere: gcc -O3 {} -o model && ./model bench 100 1000",
        outcome.out_dir.join(&c.file).display()
    );
    println!(
        "serve it: intreeger serve --pipeline {} --requests 1000",
        outcome.out_dir.display()
    );
}
