//! Serving scenario: the framework as a deployed inference service —
//! multiple models behind a router, dynamic batching, scalar/XLA
//! routing, live metrics. (`cargo run --release --example serve`)
//!
//! Workload: a bursty mix of single telemetry readings (latency-bound →
//! scalar route) and bulk re-scoring batches (throughput-bound → XLA
//! route when artifacts are built).

use intreeger::coordinator::{BatchPolicy, Router, ServerConfig};
use intreeger::data::{esa_like, shuttle_like};
use intreeger::trees::{ForestParams, RandomForest};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    println!("=== InTreeger serving demo ===\n");
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let artifacts = intreeger::runtime::artifacts_available(&artifacts).then_some(artifacts);
    if artifacts.is_none() {
        println!("(artifacts not built — all traffic takes the scalar route)\n");
    }

    // Two tenants: a Shuttle classifier and an ESA anomaly detector.
    let shuttle = shuttle_like(10_000, 1);
    let esa = esa_like(5_000, 1);
    let m_shuttle = RandomForest::train(
        &shuttle,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        2,
    );
    let m_esa = RandomForest::train(
        &esa,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        2,
    );

    let router = Arc::new(Router::new());
    let config = ServerConfig {
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(250) },
        xla_threshold: 16,
        queue_depth: 8192,
        // Demo the batched XLA route even on this 1-core host; production
        // deployments would set auto_calibrate: true (see shuttle_e2e).
        auto_calibrate: false,
        // XLA offload rides shard 0 only, so when artifacts exist keep a
        // single worker (sharding would starve the XLA route of batch
        // volume); without artifacts, demo the scalar pool instead.
        n_workers: if artifacts.is_some() {
            1
        } else {
            std::thread::available_parallelism().map_or(1, |n| n.get().min(4))
        },
    };
    let swap_config = config.clone();
    router.register("shuttle", &m_shuttle, artifacts.clone(), config.clone());
    router.register("esa", &m_esa, artifacts, config);
    println!("registered models: {:?}\n", router.names());

    // Bursty mixed workload from two client threads.
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (name, ds, n) in [("shuttle", shuttle.clone(), 3000usize), ("esa", esa.clone(), 1500)] {
        let router = Arc::clone(&router);
        handles.push(std::thread::spawn(move || {
            let server = router.server(name).unwrap();
            let mut answered = 0usize;
            let mut i = 0usize;
            while answered < n {
                // burst of 1..64 requests, then a short gap
                let burst = 1 + (i * 7919) % 64;
                let burst = burst.min(n - answered);
                let rows: Vec<Vec<f32>> =
                    (0..burst).map(|k| ds.row((i + k) % ds.n_rows()).to_vec()).collect();
                // Every request resolves — count only the Ok ones as
                // answered (typed failures would be retried next burst).
                let rs = server.infer_many(rows);
                answered += rs.iter().filter(|r| r.is_ok()).count();
                i += burst;
                std::thread::sleep(Duration::from_micros(200));
            }
            answered
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();

    println!("served {total} requests across 2 models in {:.2}s ({:.0} req/s aggregate)\n", wall, total as f64 / wall);
    for name in router.names() {
        let snap = router.server(&name).unwrap().metrics();
        println!("model '{name}':");
        println!("  requests {} / responses {}", snap.requests, snap.responses);
        println!(
            "  batches: {} scalar ({} rows), {} xla ({} rows); mean batch {:.1}",
            snap.batches_scalar, snap.rows_scalar, snap.batches_xla, snap.rows_xla, snap.mean_batch
        );
        println!(
            "  flush reasons: {} full / {} deadline / {} drain",
            snap.flush_full, snap.flush_deadline, snap.flush_drain
        );
        println!(
            "  latency: mean {:.0} us, p50 {:.0} us, p99 {:.0} us",
            snap.latency_mean_us, snap.latency_p50_us, snap.latency_p99_us
        );
        println!(
            "  per-batch: size p50 {:.0} / p99 {:.0}, service p50 {:.0} us / p99 {:.0} us",
            snap.batch_p50, snap.batch_p99, snap.batch_latency_p50_us, snap.batch_latency_p99_us
        );
        println!(
            "  failure model: shed {} expired {} rejected {} lost {} (degraded: {})\n",
            snap.shed, snap.expired, snap.rejected, snap.lost, snap.degraded
        );
    }

    // Hot-swap demo: retrain shuttle with more trees, re-register live.
    println!("hot-swapping 'shuttle' with a 20-tree retrain...");
    let m2 = RandomForest::train(
        &shuttle,
        &ForestParams { n_trees: 20, max_depth: 6, ..Default::default() },
        3,
    );
    // Re-register under the same serving config so post-swap behaviour is
    // comparable to the pre-swap run (no artifacts: the retrain serves
    // scalar-only either way).
    router.register("shuttle", &m2, None, swap_config);
    let r = router.infer("shuttle", shuttle.row(0).to_vec()).unwrap();
    println!("post-swap inference OK (class {}, {:?} route)", r.class, r.route);
}
