//! END-TO-END DRIVER (E8) — exercises every layer of the stack on the
//! paper-scale workload and proves they compose:
//!
//!   1. data substrate: full-size Shuttle-shaped dataset (58,000 rows);
//!   2. training: Random Forest, paper's 75/25 protocol;
//!   3. IR: serialize → reload → revalidate;
//!   4. engines: float / FlInt / integer-only parity on the whole test set;
//!   5. codegen + gcc: the generated integer-only C, compiled -O3 and
//!      executed, bit-identical to the engines AND measured (real x86);
//!   6. XLA/PJRT: the AOT Pallas artifact, bit-identical on a batch;
//!   7. coordinator: batched serving with scalar/XLA routing;
//!   8. simulators: Fig 3 headline (ARMv7 speedup), FE310, energy.
//!
//! Output of a full run is recorded in EXPERIMENTS.md.
//! (`cargo run --release --example shuttle_e2e`)

use intreeger::codegen::{self, CBinary, Layout};
use intreeger::coordinator::{BatchPolicy, InferenceServer, ServerConfig};
use intreeger::data::shuttle_like;
use intreeger::energy::{self, PowerModel};
use intreeger::inference::{Engine, FlIntEngine, FloatEngine, IntEngine, Variant};
use intreeger::ir::Model;
use intreeger::simarch::{self, fe310, Core};
use intreeger::trees::{accuracy, ForestParams, RandomForest};
use intreeger::util::Rng;
use std::time::{Duration, Instant};

fn main() {
    let t_start = Instant::now();
    println!("=== InTreeger end-to-end driver (shuttle workload) ===\n");

    // -- 1+2: data + training ---------------------------------------------
    let ds = shuttle_like(58_000, 42); // paper-scale: 58,000 rows
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(9));
    println!("[1] dataset: {} train / {} test rows, 7 features, 7 classes", train.n_rows(), test.n_rows());
    let t0 = Instant::now();
    let model = RandomForest::train(
        &train,
        &ForestParams { n_trees: 50, max_depth: 7, ..Default::default() },
        7,
    );
    let stats = intreeger::ir::stats::stats(&model);
    println!(
        "[2] trained RF: 50 trees, {} nodes, depth {} in {:.1}s; holdout accuracy {:.4}",
        stats.n_nodes,
        stats.max_depth,
        t0.elapsed().as_secs_f64(),
        accuracy(&model, &test)
    );

    // -- 3: IR round-trip ---------------------------------------------------
    let json = model.to_json();
    let model = Model::from_json(&json).expect("IR roundtrip");
    println!("[3] IR serialize/reload: {} bytes JSON, revalidated OK", json.len());

    // -- 4: engine parity on the full test set ------------------------------
    let fe = FloatEngine::compile(&model);
    let fl = FlIntEngine::compile(&model);
    let ie = IntEngine::compile(&model);
    let mut mismatches = 0usize;
    for i in 0..test.n_rows() {
        let a = fe.predict(test.row(i));
        if a != fl.predict(test.row(i)) || a != ie.predict(test.row(i)) {
            mismatches += 1;
        }
    }
    println!(
        "[4] engine parity over {} test rows: {} mismatches (paper §IV-B: 0)",
        test.n_rows(),
        mismatches
    );
    assert_eq!(mismatches, 0);

    // -- 5: generated C, compiled and executed ------------------------------
    if codegen::compile::gcc_available() {
        let n_c = 2_000.min(test.n_rows());
        let rows: Vec<f32> = test.features[..n_c * 7].to_vec();
        let src = codegen::generate(&model, Layout::IfElse, Variant::IntTreeger);
        let bin = CBinary::compile(&src, Variant::IntTreeger, 7, 7, "e2e_int").expect("gcc");
        let out = bin.predict_u32(&rows).expect("run generated C");
        let mut c_mismatch = 0usize;
        for (i, fixed) in out.iter().enumerate() {
            if fixed != &ie.predict_fixed(test.row(i)) {
                c_mismatch += 1;
            }
        }
        let src_f = codegen::generate(&model, Layout::IfElse, Variant::Float);
        let bin_f = CBinary::compile(&src_f, Variant::Float, 7, 7, "e2e_float").expect("gcc");
        let ns_f = bin_f.bench_ns(&rows, 30).expect("bench float");
        let ns_i = bin.bench_ns(&rows, 30).expect("bench int");
        println!(
            "[5] generated C (gcc -O3): {c_mismatch}/{n_c} mismatches vs engine (must be 0); \
             measured x86: float {ns_f:.0} ns/inf, intreeger {ns_i:.0} ns/inf => {:.2}x",
            ns_f / ns_i
        );
        assert_eq!(c_mismatch, 0);
    } else {
        println!("[5] gcc unavailable — generated-C step skipped");
    }

    // -- 6: XLA/PJRT artifact parity ----------------------------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if intreeger::runtime::artifacts_available(&artifacts) {
        match intreeger::runtime::engine_for_model(&artifacts, &model, 1) {
            Ok(xla) => {
                let b = xla.max_batch().min(128);
                let rows: Vec<f32> = test.features[..b * 7].to_vec();
                let got = xla.execute(&rows, 7).expect("xla execute");
                let mut x_mismatch = 0usize;
                for (i, fixed) in got.iter().enumerate() {
                    if fixed != &ie.predict_fixed(test.row(i)) {
                        x_mismatch += 1;
                    }
                }
                println!(
                    "[6] XLA/PJRT (AOT Pallas artifact, tier '{}'): {x_mismatch}/{b} mismatches (must be 0)",
                    xla.tier().name
                );
                assert_eq!(x_mismatch, 0);
            }
            Err(e) => println!("[6] no fitting artifact tier ({e}) — skipped"),
        }
    } else {
        println!("[6] artifacts not built (`make artifacts`) — XLA step skipped");
    }

    // -- 7: serving ----------------------------------------------------------
    let server = InferenceServer::start(
        &model,
        Some(artifacts.clone()),
        ServerConfig {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(300) },
            xla_threshold: 16,
            queue_depth: 4096,
            // route honestly: on this 1-core host the scalar engine wins,
            // on an accelerator the XLA path would be kept.
            auto_calibrate: true,
            n_workers: 2,
        },
    );
    let n_req = 4_000usize;
    let reqs: Vec<Vec<f32>> = (0..n_req).map(|i| test.row(i % test.n_rows()).to_vec()).collect();
    let t0 = Instant::now();
    let responses = server.infer_many(reqs);
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    // Requests now resolve as Result: a typed failure counts as a
    // mismatch here — the closed-loop driver expects every row answered.
    let serve_mismatch = responses
        .iter()
        .enumerate()
        .filter(|(i, r)| match r {
            Ok(r) => r.fixed != ie.predict_fixed(test.row(i % test.n_rows())),
            Err(_) => true,
        })
        .count();
    println!(
        "[7] served {n_req} reqs at {:.0} req/s (p50 {:.0} us, p99 {:.0} us; {} rows scalar / {} rows xla); {} mismatches",
        n_req as f64 / wall,
        snap.latency_p50_us,
        snap.latency_p99_us,
        snap.rows_scalar,
        snap.rows_xla,
        serve_mismatch
    );
    assert_eq!(serve_mismatch, 0);

    // -- 8: simulated headline metrics ---------------------------------------
    let f_arm = simarch::simulate(&model, &test, Variant::Float, Core::CortexA72, 250);
    let i_arm = simarch::simulate(&model, &test, Variant::IntTreeger, Core::CortexA72, 250);
    let headline = f_arm.cycles / i_arm.cycles;
    println!(
        "[8] Fig3 headline (Shuttle/ARMv7/50 trees): {:.2}x speedup (paper: 2.1x; runtime reduction {:.0}%)",
        headline,
        (1.0 - 1.0 / headline) * 100.0
    );
    let fp = fe310::footprint(&model);
    println!("    FE310 footprint of this model: {} B text (30-tree paper model: 42,382 B)", fp.text_bytes);
    let t_f = f_arm.seconds() * 14_500_000.0;
    let t_i = i_arm.seconds() * 14_500_000.0;
    let e = energy::evaluate(t_f, t_i, &PowerModel::default());
    println!(
        "    energy (14.5M inferences): float {:.1}s / int {:.1}s => E_saved {:.1}% (paper: 21.3%)",
        t_f,
        t_i,
        e.e_saved * 100.0
    );

    println!("\nall layers compose; total driver time {:.1}s", t_start.elapsed().as_secs_f64());
}
