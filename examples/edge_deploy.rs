//! Edge deployment scenario (§IV-E): prepare a model for the SiFive
//! FE310 (RV32IMAC, 16 MHz, no FPU) — the class of device the paper's
//! integer-only inference unlocks.
//!
//! Produces the deployable C file, checks it against the FE310's memory
//! map, and reports the simulated on-device performance.
//! (`cargo run --release --example edge_deploy`)

use intreeger::codegen::{generate, Layout};
use intreeger::data::shuttle_like;
use intreeger::inference::Variant;
use intreeger::simarch::fe310;
use intreeger::trees::{accuracy, ForestParams, RandomForest};
use intreeger::util::Rng;

/// FE310 / SparkFun RED-V memory budget.
const QSPI_FLASH_BYTES: u64 = 32 * 1024 * 1024;
const DTIM_BYTES: u64 = 16 * 1024;

fn main() {
    println!("=== edge deployment: Shuttle RF on the SiFive FE310 ===\n");
    let ds = shuttle_like(58_000, 42);
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(3));

    // The paper's §IV-E configuration: 30 trees, max depth 5.
    let model = RandomForest::train(
        &train,
        &ForestParams { n_trees: 30, max_depth: 5, ..Default::default() },
        11,
    );
    println!("model: 30 trees, depth<=5; holdout accuracy {:.4}", accuracy(&model, &test));

    // Integer-only C — the only variant an FPU-less core can run natively.
    let c = generate(&model, Layout::IfElse, Variant::IntTreeger);
    let out = std::env::temp_dir().join("intreeger_fe310.c");
    std::fs::write(&out, &c).expect("write");
    println!("\ndeployable C: {} ({} bytes of source)", out.display(), c.len());
    println!("cross-compile: riscv32-unknown-elf-gcc -O3 -march=rv32imac_zicsr_zifencei -mabi=ilp32 \\");
    println!("               -DINTREEGER_NO_MAIN -c {}", out.display());

    let r = fe310::use_case(&model, &test, 400);
    println!("\nestimated firmware footprint:");
    println!("  text {} B + data {} B + bss {} B = {} B total",
        r.footprint.text_bytes, r.footprint.data_bytes, r.footprint.bss_bytes, r.footprint.total());
    assert!(r.footprint.text_bytes < QSPI_FLASH_BYTES, "does not fit flash!");
    assert!(r.footprint.bss_bytes + 2048 < DTIM_BYTES, "does not fit DTIM!");
    println!("  fits: {} MB QSPI flash ({}% used), 16 KiB DTIM",
        QSPI_FLASH_BYTES / (1024 * 1024),
        r.footprint.text_bytes * 100 / QSPI_FLASH_BYTES
    );

    println!("\nsimulated on-device performance @ 16 MHz (XIP from QSPI):");
    println!("  {:.0} instructions/inference, IPC {:.3} (paper: 0.746)", r.instructions_per_inference, r.ipc);
    println!("  {:.1} inferences/second ({:.2} ms each)", r.inferences_per_second, r.seconds_per_inference * 1e3);

    println!("\nwhy integer-only matters here: the FE310 has no FPU — a float model would");
    println!("run through libgcc soft-float calls at ~10x the cycles (see `cargo bench --bench fe310_usecase`).");
}
