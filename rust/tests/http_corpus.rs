//! HTTP front-end corpus: malformed-input robustness, loopback
//! bit-identity against the engine oracle, and the zero-allocation
//! steady-state guarantee.
//!
//! Three layers:
//!
//! 1. **Corpus over a real socket** — truncated requests, byte-by-byte
//!    split reads, oversized heads/bodies, pipelined keep-alive,
//!    unsupported framing, and NaN / `1e999` smuggling all resolve to
//!    the documented status codes; nothing panics and the server keeps
//!    serving afterwards.
//! 2. **Loopback e2e** — `POST /predict` responses carry exactly the
//!    oracle engine's fixed-point accumulators (the kernels' parity
//!    invariant, observed through the whole socket → parse → scan →
//!    coordinator → render stack).
//! 3. **Allocation counting** — a global counting allocator verifies
//!    both the per-request parse → scan → render path **and** the full
//!    admission → batch → respond loop (slab-row checkout,
//!    [`ReplySlot`] submission, worker flush, fixed-buffer recycle)
//!    perform zero heap allocations once their reused buffers are warm.

use intreeger::coordinator::{
    BatchPolicy, FaultPlan, InferenceServer, ServerConfig,
};
use intreeger::data::{shuttle_like, Dataset};
use intreeger::inference::{Engine as _, IntEngine};
use intreeger::ir::Model;
use intreeger::net::{parse_head, HttpConfig, HttpServer};
use intreeger::net::server::{render_head, render_predict_body};
use intreeger::net::extract_features;
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::json::Json;
use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Counting allocator: every alloc/realloc bumps a counter so tests can
// assert an exact zero over a code region.

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------------
// Harness

fn model() -> (Dataset, Model) {
    let ds = shuttle_like(600, 77);
    let m = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 5, max_depth: 5, ..Default::default() },
        7,
    );
    (ds, m)
}

fn serve() -> (HttpServer, Arc<InferenceServer>, Dataset, Model) {
    let (ds, m) = model();
    let server = Arc::new(InferenceServer::start(
        &m,
        None,
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
            n_workers: 1,
            faults: Some(FaultPlan::none()),
            ..Default::default()
        },
    ));
    let http = HttpServer::start(
        Arc::clone(&server),
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            conn_workers: 2,
            keep_alive_timeout: Duration::from_millis(500),
        },
    )
    .expect("bind loopback");
    (http, server, ds, m)
}

/// Send raw bytes, half-close the write side, read everything the
/// server answers until it closes. Exercises the full socket path.
fn roundtrip(addr: SocketAddr, raw: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(raw).expect("send");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("read response");
    out
}

fn predict_request(features: &[f32]) -> Vec<u8> {
    let body = format!(
        "{{\"features\":[{}]}}",
        features.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
    );
    format!(
        "POST /predict HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes()
}

fn body_of(response: &str) -> &str {
    let (head, body) = response.split_once("\r\n\r\n").expect("head/body split");
    assert!(head.starts_with("HTTP/1.1 "), "not an HTTP response: {head}");
    body
}

fn status_of(response: &str) -> u16 {
    response["HTTP/1.1 ".len()..].split(' ').next().unwrap().parse().unwrap()
}

// ---------------------------------------------------------------------------
// 2. Loopback bit-identity

#[test]
fn predict_is_bit_identical_to_the_engine_oracle() {
    let (http, _server, ds, m) = serve();
    let oracle = IntEngine::compile(&m);
    let addr = http.local_addr();
    for i in 0..24 {
        let row = ds.row(i);
        let response = roundtrip(addr, &predict_request(row));
        assert_eq!(status_of(&response), 200, "row {i}: {response}");
        let json = Json::parse(body_of(&response)).expect("valid response JSON");
        let class = json.get("class").and_then(Json::as_usize).expect("class field");
        let fixed: Vec<u32> = json
            .get("fixed")
            .and_then(Json::as_arr)
            .expect("fixed field")
            .iter()
            .map(|v| v.as_f64().unwrap() as u32)
            .collect();
        assert_eq!(fixed, oracle.predict_fixed(row), "row {i} accumulators");
        assert_eq!(class as u32, oracle.predict(row), "row {i} class");
        // Probabilities are derived from the same accumulators and must
        // sum to ~1 over a normalized forest.
        let proba: Vec<f64> = json
            .get("proba")
            .and_then(Json::as_arr)
            .expect("proba field")
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let sum: f64 = proba.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "row {i} proba sum {sum}");
    }
}

#[test]
fn keep_alive_serves_many_requests_on_one_connection() {
    let (http, _server, ds, m) = serve();
    let oracle = IntEngine::compile(&m);
    let mut stream = TcpStream::connect(http.local_addr()).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = vec![0u8; 64 * 1024];
    for i in 0..8 {
        let row = ds.row(i);
        stream.write_all(&predict_request(row)).expect("send");
        // Read one full response (head + declared body).
        let response = read_one_response(&mut stream, &mut buf);
        assert_eq!(status_of(&response), 200, "request {i} on kept-alive conn");
        let json = Json::parse(body_of(&response)).expect("valid JSON");
        assert_eq!(
            json.get("class").and_then(Json::as_usize).unwrap() as u32,
            oracle.predict(row),
            "request {i}"
        );
    }
}

/// Read exactly one HTTP response using its Content-Length framing.
fn read_one_response(stream: &mut TcpStream, buf: &mut [u8]) -> String {
    let mut filled = 0;
    loop {
        let head = std::str::from_utf8(&buf[..filled]).ok().and_then(|s| {
            s.find("\r\n\r\n").map(|p| (s[..p].to_string(), p + 4))
        });
        if let Some((head_text, body_start)) = head {
            let clen = head_text
                .lines()
                .find_map(|l| l.to_ascii_lowercase().strip_prefix("content-length:").map(|v| v.trim().parse::<usize>().unwrap()))
                .unwrap_or(0);
            if filled >= body_start + clen {
                return String::from_utf8_lossy(&buf[..body_start + clen]).into_owned();
            }
        }
        let n = stream.read(&mut buf[filled..]).expect("read");
        assert!(n > 0, "server closed mid-response");
        filled += n;
    }
}

#[test]
fn pipelined_requests_answer_in_order() {
    let (http, _server, ds, m) = serve();
    let oracle = IntEngine::compile(&m);
    // Two requests in one write; responses must come back in order on
    // the same connection.
    let mut raw = predict_request(ds.row(0));
    raw.extend_from_slice(&predict_request(ds.row(1)));
    let mut stream = TcpStream::connect(http.local_addr()).expect("connect");
    stream.write_all(&raw).expect("send");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut all = String::new();
    stream.read_to_string(&mut all).expect("read");
    let statuses: Vec<&str> = all.matches("HTTP/1.1 200 OK").collect();
    assert_eq!(statuses.len(), 2, "both pipelined requests answered: {all}");
    // Order: first body's class is row 0's prediction, second is row 1's.
    let classes: Vec<u32> = all
        .match_indices("\"class\":")
        .map(|(p, _)| {
            all[p + "\"class\":".len()..].split(|c: char| !c.is_ascii_digit()).next().unwrap().parse().unwrap()
        })
        .collect();
    assert_eq!(classes, vec![oracle.predict(ds.row(0)), oracle.predict(ds.row(1))]);
}

#[test]
fn split_reads_reassemble_into_one_request() {
    let (http, _server, ds, m) = serve();
    let oracle = IntEngine::compile(&m);
    let raw = predict_request(ds.row(3));
    let mut stream = TcpStream::connect(http.local_addr()).expect("connect");
    // Drip the request in five fragments with pauses — the parser must
    // treat partial heads and partial bodies as "read more", never as
    // errors.
    let step = raw.len().div_ceil(5);
    for chunk in raw.chunks(step) {
        stream.write_all(chunk).expect("send fragment");
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read");
    assert_eq!(status_of(&response), 200, "{response}");
    let json = Json::parse(body_of(&response)).expect("valid JSON");
    assert_eq!(json.get("class").and_then(Json::as_usize).unwrap() as u32, oracle.predict(ds.row(3)));
}

// ---------------------------------------------------------------------------
// 1. Malformed-input corpus

#[test]
fn truncated_request_closes_cleanly_and_server_survives() {
    let (http, _server, ds, _m) = serve();
    let addr = http.local_addr();
    let full = predict_request(ds.row(0));
    // Truncate at several depths: mid-request-line, mid-headers,
    // mid-body. The server must close without answering garbage and —
    // crucially — keep serving new connections.
    for cut in [4, 20, full.len() - 3] {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(&full[..cut]).expect("send truncated");
        stream.shutdown(Shutdown::Write).expect("half-close");
        let mut out = String::new();
        stream.read_to_string(&mut out).expect("read");
        assert!(out.is_empty(), "truncated request (cut {cut}) must get no reply, got: {out}");
    }
    let response = roundtrip(addr, &full);
    assert_eq!(status_of(&response), 200, "server must survive truncation: {response}");
}

#[test]
fn oversized_heads_and_bodies_are_rejected_with_typed_statuses() {
    let (http, _server, _ds, _m) = serve();
    let addr = http.local_addr();
    // A header stream that never terminates within the cap → 431.
    let mut huge_head = b"GET /healthz HTTP/1.1\r\nX-Padding: ".to_vec();
    huge_head.resize(intreeger::net::MAX_HEAD_BYTES + 64, b'a');
    let response = roundtrip(addr, &huge_head);
    assert_eq!(status_of(&response), 431, "{response}");
    assert!(body_of(&response).contains("headers_too_large"), "{response}");
    // A declared body over the cap → 413 before any body byte is read.
    let huge_body = format!(
        "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        intreeger::net::MAX_BODY_BYTES + 1
    );
    let response = roundtrip(addr, huge_body.as_bytes());
    assert_eq!(status_of(&response), 413, "{response}");
    // Chunked framing is deliberately unimplemented → 501.
    let chunked = "POST /predict HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
    let response = roundtrip(addr, chunked.as_bytes());
    assert_eq!(status_of(&response), 501, "{response}");
}

#[test]
fn nan_and_overflow_smuggling_resolve_to_typed_400s() {
    let (http, _server, _ds, _m) = serve();
    let addr = http.local_addr();
    // A NaN literal is not JSON: rejected by the scanner.
    let body = "{\"features\":[1,2,NaN,4,5,6,7]}";
    let raw = format!(
        "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let response = roundtrip(addr, raw.as_bytes());
    assert_eq!(status_of(&response), 400, "{response}");
    assert!(body_of(&response).contains("bad_number"), "{response}");
    // 1e999 IS valid JSON; it overflows to +inf and the coordinator's
    // finiteness validation answers with the typed error — no panic,
    // no poisoned batch.
    let body = "{\"features\":[1,2,1e999,4,5,6,7]}";
    let raw = format!(
        "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let response = roundtrip(addr, raw.as_bytes());
    assert_eq!(status_of(&response), 400, "{response}");
    assert!(body_of(&response).contains("non_finite_feature"), "{response}");
    // Wrong arity → the coordinator's typed validation error.
    let body = "{\"features\":[1,2]}";
    let raw = format!(
        "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let response = roundtrip(addr, raw.as_bytes());
    assert_eq!(status_of(&response), 400, "{response}");
    assert!(body_of(&response).contains("wrong_feature_count"), "{response}");
    // Not-an-object and missing-key bodies.
    for body in ["[1,2,3]", "{\"rows\":[1,2,3]}", "{\"features\":\"x\"}", "not json at all"] {
        let raw = format!(
            "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let response = roundtrip(addr, raw.as_bytes());
        assert_eq!(status_of(&response), 400, "body {body:?}: {response}");
    }
}

#[test]
fn unknown_paths_and_methods_get_404_and_405() {
    let (http, _server, _ds, _m) = serve();
    let addr = http.local_addr();
    let response = roundtrip(addr, b"GET /nope HTTP/1.1\r\n\r\n");
    assert_eq!(status_of(&response), 404, "{response}");
    let response = roundtrip(addr, b"GET /predict HTTP/1.1\r\n\r\n");
    assert_eq!(status_of(&response), 405, "{response}");
    let response = roundtrip(addr, b"DELETE /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(status_of(&response), 405, "{response}");
}

#[test]
fn healthz_and_metrics_render_valid_json_with_slo_fields() {
    let (http, _server, ds, _m) = serve();
    let addr = http.local_addr();
    // Traffic first, so the SLO histograms have samples.
    for i in 0..5 {
        let response = roundtrip(addr, &predict_request(ds.row(i)));
        assert_eq!(status_of(&response), 200);
    }
    let response = roundtrip(addr, b"GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(status_of(&response), 200, "{response}");
    let response = roundtrip(addr, b"GET /metrics HTTP/1.1\r\n\r\n");
    assert_eq!(status_of(&response), 200, "{response}");
    let json = Json::parse(body_of(&response)).expect("metrics must be valid JSON");
    for field in
        ["e2e_mean_us", "e2e_p50_us", "e2e_p99_us", "max_batch", "max_batch_delay_us", "flush_ttl"]
    {
        assert!(json.get(field).is_some(), "metrics missing {field}");
    }
    assert!(json.get("http_requests").and_then(Json::as_f64).unwrap() >= 6.0);
    assert_eq!(json.get("max_batch").and_then(Json::as_usize), Some(8));
    assert_eq!(json.get("max_batch_delay_us").and_then(Json::as_usize), Some(200));
    // Real traffic flowed, so the e2e SLO percentiles are live.
    assert!(json.get("e2e_p99_us").and_then(Json::as_f64).unwrap() > 0.0);
}

// ---------------------------------------------------------------------------
// 3. Zero allocations on the steady-state request path

/// The per-request hot path — parse head, scan features, render the
/// response — must not touch the allocator once its reused buffers are
/// warm. This drives the exact production functions over the exact
/// production buffer types; the coordinator half of the loop (slab
/// admission through worker flush) is covered by
/// `full_serving_loop_is_zero_alloc_in_steady_state` below.
#[test]
#[cfg(debug_assertions)]
fn request_hot_path_is_zero_alloc_in_steady_state() {
    use intreeger::coordinator::{Response, Route};

    let body = "{\"features\":[1,2.5,3,4,5,6,7.25]}";
    let raw = format!(
        "POST /predict HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes();
    let resp = Response {
        fixed: vec![123456, u32::MAX / 3, 7, 0, 42, 9999, 1],
        class: 1,
        route: Route::Scalar,
        latency: Duration::from_micros(10),
    };
    let mut features: Vec<f32> = Vec::new();
    let mut head_out: Vec<u8> = Vec::new();
    let mut body_out: Vec<u8> = Vec::new();

    let hot_path = |features: &mut Vec<f32>, head_out: &mut Vec<u8>, body_out: &mut Vec<u8>| {
        let head = parse_head(&raw).unwrap().expect("complete request");
        assert_eq!(head.method, "POST");
        extract_features(&raw[head.head_len..head.total_len()], features).unwrap();
        assert_eq!(features.len(), 7);
        body_out.clear();
        render_predict_body(body_out, &resp);
        render_head(head_out, 200, "OK", body_out.len(), true);
    };

    // Warm-up: buffers grow to steady-state capacity.
    for _ in 0..16 {
        hot_path(&mut features, &mut head_out, &mut body_out);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        hot_path(&mut features, &mut head_out, &mut body_out);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "parse→scan→render must be allocation-free in steady state, saw {delta} allocations \
         over 100 requests"
    );
}

/// The **full** serving loop — slab-row checkout, pooled submission,
/// batch formation, kernel execution, response delivery, fixed-buffer
/// recycle — must be allocation-free in steady state: the admission
/// clone is gone (rows live in the coordinator's `FeatureSlab`), the
/// response channel and fixed-point buffer are recycled through a
/// `ReplySlot`, the batcher swaps a spare backing `Vec`, and the
/// metrics histograms are fixed arrays.
///
/// `ALLOCS` is process-global and other tests run concurrently, so one
/// polluted window must not fail the build: the assertion is "at least
/// one of several measurement windows is clean". A *systematic*
/// per-request allocation would dirty every window and still fail.
#[test]
#[cfg(debug_assertions)]
fn full_serving_loop_is_zero_alloc_in_steady_state() {
    let (ds, m) = model();
    // max_batch 1 on one shard: every submit flushes immediately, so a
    // clean window proves the whole submit→flush→respond chain clean.
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            n_workers: 1,
            faults: Some(FaultPlan::none()),
            ..Default::default()
        },
    );
    let mut slot = intreeger::coordinator::ReplySlot::new();
    let row = ds.row(0);

    let mut one_request = |slot: &mut intreeger::coordinator::ReplySlot| {
        let mut slab_row = server.checkout_row().expect("slab must have capacity");
        slab_row.copy_from(row);
        server.submit_pooled(slab_row, slot).expect("admission");
        let resp = slot.recv().expect("serve ok");
        slot.recycle(resp.fixed);
    };

    // Warm-up: slab free-list, batcher spare, scratch buffers, reply
    // slot spare, and the metrics histograms all reach steady state.
    for _ in 0..32 {
        one_request(&mut slot);
    }
    let mut deltas = Vec::new();
    for _attempt in 0..5 {
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..100 {
            one_request(&mut slot);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        if delta == 0 {
            return;
        }
        deltas.push(delta);
    }
    panic!(
        "admission→batch→respond loop allocated in every measurement window \
         (allocation deltas per 100-request window: {deltas:?}) — the steady-state \
         zero-allocation guarantee is broken"
    );
}
