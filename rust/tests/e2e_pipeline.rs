//! Integration: the full Fig 1 pipeline — dataset → train → IR
//! serialize/reload → codegen → gcc → execute — with cross-layer parity
//! assertions at every seam.

use intreeger::codegen::{self, CBinary, Layout};
use intreeger::data::{esa_like, shuttle_like};
use intreeger::inference::{Engine, FlIntEngine, FloatEngine, IntEngine, Variant};
use intreeger::ir::Model;
use intreeger::trees::{accuracy, train_gbt, ForestParams, GbtParams, RandomForest};
use intreeger::util::Rng;

#[test]
fn full_pipeline_shuttle() {
    let ds = shuttle_like(6_000, 201);
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(5));
    let model = RandomForest::train(
        &train,
        &ForestParams { n_trees: 12, max_depth: 6, ..Default::default() },
        5,
    );
    // must actually learn something
    let majority = *test.class_counts().iter().max().unwrap() as f64 / test.n_rows() as f64;
    assert!(accuracy(&model, &test) > majority, "model did not learn");

    // IR round trip
    let model = Model::from_json(&model.to_json()).expect("roundtrip");

    // engine parity across the whole test set
    let fe = FloatEngine::compile(&model);
    let fl = FlIntEngine::compile(&model);
    let ie = IntEngine::compile(&model);
    for i in 0..test.n_rows() {
        let a = fe.predict(test.row(i));
        assert_eq!(a, fl.predict(test.row(i)), "flint row {i}");
        assert_eq!(a, ie.predict(test.row(i)), "int row {i}");
    }

    // generated C (all four layouts, including the predicated
    // child-adjacent form and the QuickScorer bitvector form) matches
    // the integer engine bit-exactly
    if codegen::compile::gcc_available() {
        let rows: Vec<f32> = test.features[..200 * 7].to_vec();
        for layout in
            [Layout::IfElse, Layout::Native, Layout::NativePredicated, Layout::QuickScorer]
        {
            let src = codegen::generate(&model, layout, Variant::IntTreeger);
            let bin = CBinary::compile(&src, Variant::IntTreeger, 7, 7, "e2e_test").unwrap();
            let out = bin.predict_u32(&rows).unwrap();
            for (i, fixed) in out.iter().enumerate() {
                assert_eq!(fixed, &ie.predict_fixed(test.row(i)), "{} row {i}", layout.name());
            }
        }
    }
}

#[test]
fn full_pipeline_esa() {
    let ds = esa_like(3_000, 202);
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(6));
    let model = RandomForest::train(
        &train,
        &ForestParams { n_trees: 8, max_depth: 6, ..Default::default() },
        8,
    );
    let model = Model::from_json(&model.to_json()).expect("roundtrip");
    let fe = FloatEngine::compile(&model);
    let ie = IntEngine::compile(&model);
    for i in 0..test.n_rows() {
        assert_eq!(fe.predict(test.row(i)), ie.predict(test.row(i)), "row {i}");
    }
}

#[test]
fn gbt_pipeline_integer_only() {
    let ds = shuttle_like(2_500, 203);
    let (train, test) = ds.train_test_split(0.25, &mut Rng::new(7));
    let model = train_gbt(
        &train,
        &GbtParams { n_rounds: 4, max_depth: 3, ..Default::default() },
        3,
    );
    let model = Model::from_json(&model.to_json()).expect("roundtrip");
    let gie = intreeger::inference::GbtIntEngine::compile(&model);
    for i in 0..test.n_rows() {
        assert_eq!(model.predict(test.row(i)), gie.predict(test.row(i)), "row {i}");
    }
}

#[test]
fn csv_roundtrip_through_training() {
    // CSV in → train → predict: the "application domain expert" path.
    let ds = shuttle_like(800, 204);
    let dir = std::env::temp_dir().join("intreeger_e2e_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("train.csv");
    intreeger::data::csv::write_file(&p, &ds).unwrap();
    let loaded = intreeger::data::csv::read_file(&p, false).unwrap();
    assert_eq!(loaded.n_rows(), ds.n_rows());
    let model = RandomForest::train(
        &loaded,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        1,
    );
    assert!(model.validate().is_ok());
}
