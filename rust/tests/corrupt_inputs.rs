//! Corrupt-input corpus: every model-loading front door — the IR JSON
//! deserializer, the LightGBM/XGBoost importers, both manifest parsers,
//! and the INTB zero-copy binary loader — must turn arbitrary broken
//! input into a typed error. No panic, no hang, no over-read, no
//! pathological allocation driven by a hostile header. (ISSUE 7
//! satellite: harden model-loading inputs; ISSUE 9 satellite: the
//! hostile-binary corpus.)

use intreeger::data::shuttle_like;
use intreeger::inference::{GbtIntEngine, IntEngine};
use intreeger::ir::import::{lightgbm, xgboost};
use intreeger::ir::{IrError, Model, MAX_CLASSES, MAX_FEATURES, MAX_TREES};
use intreeger::runtime::binfmt::{
    self, BinError, BinKind, OwnedBin, ENDIAN_TAG, HEADER_LEN, VERSION,
};
use intreeger::runtime::{Manifest, PipelineManifest};
use intreeger::trees::{train_gbt, ForestParams, GbtParams, RandomForest};

fn trained_model_json() -> String {
    let ds = shuttle_like(400, 13);
    RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        5,
    )
    .to_json()
}

/// Truncating a valid model file at any byte must produce an error,
/// never a panic (and never an accepted model).
#[test]
fn truncated_model_json_always_errors() {
    let json = trained_model_json();
    // Every prefix is overkill (the file is tens of KB); sample a spread
    // of cut points plus the tail region where the object almost closes.
    let cuts: Vec<usize> = (0..json.len()).step_by(json.len() / 97 + 1).collect();
    for cut in cuts.into_iter().chain(json.len() - 10..json.len()) {
        assert!(
            Model::from_json(&json[..cut]).is_err(),
            "truncation at byte {cut}/{} must not yield a model",
            json.len()
        );
    }
    // The untruncated text still loads (the corpus is testing the cuts,
    // not the model).
    assert!(Model::from_json(&json).is_ok());
}

/// Byte-level mutations of a valid file: flip a character at a spread of
/// positions. Most mutations break JSON or the format; *none* may panic,
/// and whatever still parses must also pass structural validation.
#[test]
fn mutated_model_json_never_panics() {
    let json = trained_model_json();
    for pos in (0..json.len()).step_by(json.len() / 211 + 1) {
        let mut bytes = json.clone().into_bytes();
        bytes[pos] = match bytes[pos] {
            b'0'..=b'9' => b'x',
            _ => b'9',
        };
        if let Ok(s) = String::from_utf8(bytes) {
            // Either outcome is fine; panicking is not.
            let _ = Model::from_json(&s);
        }
    }
}

/// Hostile headers: declared counts beyond the capacity limits fail as
/// typed errors before any per-node work.
#[test]
fn oversized_declared_counts_are_rejected() {
    let stump_trees =
        r#"[{"feature":[-1],"threshold":[0],"left":[0],"right":[0],"leaf":[[1,0]]}]"#;
    let with_counts = |nf: usize, nc: usize| {
        format!(
            r#"{{"format":"intreeger-ir-v1","kind":"rf","n_features":{nf},
            "n_classes":{nc},"base_score":[0,0],"trees":{stump_trees}}}"#
        )
    };
    assert!(Model::from_json(&with_counts(MAX_FEATURES + 1, 2)).is_err());
    assert!(Model::from_json(&with_counts(4_000_000_000, 2)).is_err());
    assert!(Model::from_json(&with_counts(1, MAX_CLASSES + 1)).is_err());
    assert!(Model::from_json(&with_counts(1, 0)).is_err());
    // In-bounds control: the same skeleton with sane counts loads.
    assert!(Model::from_json(&with_counts(1, 2)).is_ok());
}

/// NaN / infinity smuggled through JSON numbers (1e999 parses to f64
/// infinity; 1e300 overflows the f32 narrowing) must be typed errors in
/// thresholds, leaf values and base scores alike.
#[test]
fn non_finite_numbers_are_rejected_everywhere() {
    let model_with = |threshold: &str, leaf: &str, base: &str| {
        format!(
            r#"{{"format":"intreeger-ir-v1","kind":"rf","n_features":1,
            "n_classes":2,"base_score":{base},
            "trees":[{{"feature":[0,-1,-1],"threshold":[{threshold},0,0],
            "left":[1,0,0],"right":[2,0,0],
            "leaf":[[],[0.9,0.1],{leaf}]}}]}}"#
        )
    };
    // control
    assert!(Model::from_json(&model_with("0.5", "[0.2,0.8]", "[0,0]")).is_ok());
    for bad in ["1e999", "-1e999", "1e300"] {
        assert!(
            Model::from_json(&model_with(bad, "[0.2,0.8]", "[0,0]")).is_err(),
            "threshold {bad}"
        );
        assert!(
            Model::from_json(&model_with("0.5", &format!("[0.2,{bad}]"), "[0,0]")).is_err(),
            "leaf {bad}"
        );
        assert!(
            Model::from_json(&model_with("0.5", "[0.2,0.8]", &format!("[0,{bad}]"))).is_err(),
            "base_score {bad}"
        );
    }
}

#[test]
fn validate_reports_typed_capacity_errors() {
    let mut m = Model::from_json(&trained_model_json()).unwrap();
    m.n_features = MAX_FEATURES + 1;
    assert_eq!(m.validate(), Err(IrError::TooManyFeatures { got: MAX_FEATURES + 1 }));
    let mut m = Model::from_json(&trained_model_json()).unwrap();
    m.trees.clear();
    assert_eq!(m.validate(), Err(IrError::NoTrees));
}

/// LightGBM corpus: truncations, NaN payloads, and hostile headers.
#[test]
fn lightgbm_corrupt_dumps_error_cleanly() {
    let valid = "\
num_class=1\nmax_feature_idx=1\n\n\
Tree=0\nnum_leaves=3\nsplit_feature=0 1\nthreshold=0.5 -1.25\n\
decision_type=2 2\nleft_child=1 -1\nright_child=-2 -3\nleaf_value=0.1 -0.2 0.3\n\nend of trees\n";
    assert!(lightgbm::import(valid).is_ok(), "control dump must import");

    // Truncations at every line boundary.
    let lines: Vec<&str> = valid.lines().collect();
    for cut in 0..lines.len() {
        let partial = lines[..cut].join("\n");
        // Either a typed error or (for cuts that still form a complete
        // dump) a valid model; never a panic.
        let _ = lightgbm::import(&partial);
    }

    // NaN threshold and NaN leaf value ("nan" parses as f64 NaN).
    let nan_threshold = valid.replace("threshold=0.5 -1.25", "threshold=nan -1.25");
    assert!(lightgbm::import(&nan_threshold).is_err());
    let nan_leaf = valid.replace("leaf_value=0.1 -0.2 0.3", "leaf_value=0.1 nan 0.3");
    assert!(lightgbm::import(&nan_leaf).is_err());
    let inf_leaf = valid.replace("leaf_value=0.1 -0.2 0.3", "leaf_value=0.1 inf 0.3");
    assert!(lightgbm::import(&inf_leaf).is_err());

    // Hostile headers: feature/class counts beyond the limits.
    let huge_features = valid.replace("max_feature_idx=1", "max_feature_idx=4000000000");
    assert!(lightgbm::import(&huge_features).is_err());
    let huge_classes = valid.replace("num_class=1", &format!("num_class={}", MAX_CLASSES + 1));
    assert!(lightgbm::import(&huge_classes).is_err());

    // Dangling child references.
    let dangling = valid.replace("right_child=-2 -3", "right_child=-2 -99");
    assert!(lightgbm::import(&dangling).is_err());
}

/// XGBoost corpus: malformed JSON, non-finite conditions, hostile counts.
#[test]
fn xgboost_corrupt_dumps_error_cleanly() {
    let valid = r#"[
      {"nodeid":0,"split":"f0","split_condition":0.5,"yes":1,"no":2,"missing":1,
       "children":[{"nodeid":1,"leaf":-0.4},{"nodeid":2,"leaf":0.6}]}
    ]"#;
    assert!(xgboost::import(valid, 2, 2, 0.0).is_ok(), "control dump must import");

    // Truncations.
    for cut in (0..valid.len()).step_by(7) {
        let _ = xgboost::import(&valid[..cut], 2, 2, 0.0);
    }

    // Infinity via exponent overflow in split_condition and leaf.
    let inf_cond = valid.replace("\"split_condition\":0.5", "\"split_condition\":1e999");
    assert!(xgboost::import(&inf_cond, 2, 2, 0.0).is_err());
    let inf_leaf = valid.replace("\"leaf\":0.6", "\"leaf\":1e999");
    assert!(xgboost::import(&inf_leaf, 2, 2, 0.0).is_err());

    // Non-finite base score and hostile caller-declared counts.
    assert!(xgboost::import(valid, 2, 2, f32::NAN).is_err());
    assert!(xgboost::import(valid, MAX_FEATURES + 1, 2, 0.0).is_err());
    assert!(xgboost::import(valid, 2, MAX_CLASSES + 1, 0.0).is_err());
    // A nodeid the children array does not contain.
    let dangling = valid.replace("\"yes\":1", "\"yes\":42");
    assert!(xgboost::import(&dangling, 2, 2, 0.0).is_err());
}

/// The tree-count limit holds even when every tree is tiny (a dump that
/// declares a million stumps is refused on count, not materialized).
#[test]
fn tree_count_limit_enforced() {
    let mut dump = String::from("[");
    for i in 0..=MAX_TREES {
        if i > 0 {
            dump.push(',');
        }
        dump.push_str("{\"nodeid\":0,\"leaf\":0.1}");
    }
    dump.push(']');
    assert!(xgboost::import(&dump, 2, 2, 0.0).is_err());
}

/// Cross-format manifest confusion: the XLA artifact manifest and the
/// pipeline bundle manifest share a file name (`manifest.json`); each
/// parser must reject the other's format with a typed error.
#[test]
fn manifest_cross_format_confusion_is_rejected() {
    let xla = r#"{
        "format": "intreeger-artifacts-v1",
        "tiers": [{"name":"quick","file":"f.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"use_pallas":true}]}"#;
    let bundle = r#"{
        "format": "intreeger-pipeline-v1",
        "seed": 42, "report": "report.json",
        "models": [{"kind":"rf","model":"model_rf.json","c":null,"layout":"ifelse","variant":"intreeger"}]}"#;
    assert!(Manifest::parse(xla).is_ok());
    assert!(PipelineManifest::parse(bundle).is_ok());
    assert!(Manifest::parse(bundle).is_err(), "tier parser must reject bundles");
    assert!(PipelineManifest::parse(xla).is_err(), "bundle parser must reject tier manifests");

    // And the serving boot path surfaces it as an error, not a panic:
    // a directory holding an XLA manifest is not a pipeline bundle.
    let dir = std::env::temp_dir()
        .join(format!("intreeger_confused_manifest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), xla).unwrap();
    assert!(intreeger::coordinator::server_from_pipeline(
        &dir,
        intreeger::coordinator::ServerConfig::default()
    )
    .is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A pipeline bundle whose model file is corrupt must fail at load with
/// a located error (file name in the message), not serve garbage.
#[test]
fn bundle_with_corrupt_model_file_errors() {
    let dir = std::env::temp_dir()
        .join(format!("intreeger_corrupt_bundle_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
        "format": "intreeger-pipeline-v1",
        "seed": 1, "report": "report.json",
        "models": [{"kind":"rf","model":"model_rf.json","c":null,"layout":"ifelse","variant":"intreeger"}]}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let json = trained_model_json();
    std::fs::write(dir.join("model_rf.json"), &json[..json.len() / 2]).unwrap();
    let m = PipelineManifest::load(&dir).unwrap();
    let err = m.load_model(&dir, "rf").unwrap_err().to_string();
    assert!(err.contains("model_rf.json"), "error must locate the file: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Hostile INTB binaries (ISSUE 9 satellite). The binary loader's
// contract is sharper than the JSON one's: the input is attacker-shaped
// *pointer math*, so every mutation below must surface as a typed
// `BinError` from bounds/validation code — never a panic, never a read
// past the buffer.

fn rf_bin() -> Vec<u8> {
    let ds = shuttle_like(500, 61);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        61,
    );
    binfmt::write_forest(IntEngine::compile(&model).forest())
}

fn gbt_bin() -> Vec<u8> {
    let ds = shuttle_like(500, 62);
    let model =
        train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() }, 62);
    binfmt::write_gbt(&GbtIntEngine::compile(&model))
}

/// Run hostile bytes through the aligned owned path and, when the view
/// parses, on into engine materialization. The typed error may surface
/// at either stage; `None` means the artifact was fully accepted.
fn reject(bytes: &[u8]) -> Option<BinError> {
    let owned = OwnedBin::from_bytes(bytes);
    match owned.view() {
        Err(e) => Some(e),
        Ok(v) => match v.kind() {
            BinKind::Rf => v.to_forest().err(),
            BinKind::Gbt => v.to_gbt().err(),
        },
    }
}

fn patched32(bytes: &[u8], off: usize, v: u32) -> Vec<u8> {
    let mut b = bytes.to_vec();
    b[off..off + 4].copy_from_slice(&v.to_ne_bytes());
    b
}

fn patched64(bytes: &[u8], off: usize, v: u64) -> Vec<u8> {
    let mut b = bytes.to_vec();
    b[off..off + 8].copy_from_slice(&v.to_ne_bytes());
    b
}

/// Decode the section table: `(offset, len)` per section, in file order.
fn sections(bytes: &[u8]) -> Vec<(usize, usize)> {
    let n = u32::from_ne_bytes(bytes[60..64].try_into().unwrap()) as usize;
    (0..n)
        .map(|i| {
            let at = HEADER_LEN + i * 16;
            (
                u64::from_ne_bytes(bytes[at..at + 8].try_into().unwrap()) as usize,
                u64::from_ne_bytes(bytes[at + 8..at + 16].try_into().unwrap()) as usize,
            )
        })
        .collect()
}

/// Truncating an artifact at every structurally interesting byte — mid
/// magic, mid header, at the section table edge, and at both edges of
/// every section — must produce a typed error, never an accepted model.
#[test]
fn truncated_binaries_error_at_every_section_boundary() {
    for bytes in [rf_bin(), gbt_bin()] {
        assert!(reject(&bytes).is_none(), "control artifact must load");
        let mut cuts = vec![0, 1, 3, 4, HEADER_LEN - 1, HEADER_LEN];
        for (off, len) in sections(&bytes) {
            cuts.extend([off.saturating_sub(1), off, off + 1, (off + len).saturating_sub(1), off + len]);
        }
        cuts.push(bytes.len() - 1);
        for cut in cuts {
            if cut >= bytes.len() {
                continue;
            }
            assert!(
                reject(&bytes[..cut]).is_some(),
                "truncation at byte {cut}/{} must not yield a model",
                bytes.len()
            );
        }
    }
}

/// Fixed-header forgeries: wrong magic, unknown version, foreign
/// endianness, unknown kind code, a lying file length, and dirty
/// reserved bytes each map to their specific error variant.
#[test]
fn forged_binary_headers_are_typed_errors() {
    let bytes = rf_bin();
    let mut bad_magic = bytes.clone();
    bad_magic[0] = b'J';
    assert!(matches!(reject(&bad_magic), Some(BinError::BadMagic(_))));
    assert!(matches!(
        reject(&patched32(&bytes, 4, VERSION + 1)),
        Some(BinError::BadVersion(v)) if v == VERSION + 1
    ));
    assert!(matches!(
        reject(&patched32(&bytes, 8, ENDIAN_TAG.swap_bytes())),
        Some(BinError::BadEndianness(_))
    ));
    assert!(matches!(reject(&patched32(&bytes, 12, 7)), Some(BinError::BadKind(7))));
    assert!(matches!(
        reject(&patched64(&bytes, 64, bytes.len() as u64 + 64)),
        Some(BinError::BadHeader(_))
    ));
    let mut dirty_reserved = bytes.clone();
    dirty_reserved[100] = 1;
    assert!(matches!(reject(&dirty_reserved), Some(BinError::BadHeader(_))));
    // An RF artifact claiming a GBT margin scale is inconsistent.
    assert!(matches!(reject(&patched32(&bytes, 40, 1)), Some(BinError::BadHeader(_))));
}

/// Header counts beyond the IR capacity limits (or zero where zero is
/// meaningless) are refused before any per-node work — the same
/// `MAX_*` gates the JSON door enforces.
#[test]
fn oversized_binary_header_counts_are_rejected() {
    let bytes = rf_bin();
    for (off, val, what) in [
        (16, MAX_FEATURES as u32 + 1, "n_features over cap"),
        (16, 0, "zero features"),
        (20, MAX_CLASSES as u32 + 1, "n_classes over cap"),
        (20, 0, "zero classes"),
        (24, MAX_TREES as u32 + 1, "n_trees over cap"),
        (24, 0, "zero trees"),
        (28, u32::MAX, "node count not matching any section"),
        (32, 0, "zero leaves"),
        (36, 9, "unknown node-order code"),
        (60, 0, "zero sections"),
        (60, 1000, "wrong section count"),
    ] {
        assert!(reject(&patched32(&bytes, off, val)).is_some(), "{what} must error");
    }
}

/// Section-table mutations: misaligned starts, out-of-bounds offsets,
/// off-by-one lengths, overlapping/backward sections, and a length
/// chosen to bait an over-read. All contained, all typed.
#[test]
fn mutated_section_tables_are_contained() {
    for bytes in [rf_bin(), gbt_bin()] {
        let table = sections(&bytes);
        // Section 0 pointed back into the header (backward/overlapping).
        assert!(reject(&patched64(&bytes, HEADER_LEN, 0)).is_some());
        for (i, &(off, len)) in table.iter().enumerate() {
            let at = HEADER_LEN + i * 16;
            assert!(
                reject(&patched64(&bytes, at, off as u64 + 1)).is_some(),
                "section {i}: misaligned start must error"
            );
            assert!(
                reject(&patched64(&bytes, at, bytes.len() as u64 + 64)).is_some(),
                "section {i}: start beyond EOF must error"
            );
            assert!(
                reject(&patched64(&bytes, at + 8, len as u64 + 1)).is_some(),
                "section {i}: length +1 must error"
            );
            if len > 0 {
                assert!(
                    reject(&patched64(&bytes, at + 8, len as u64 - 1)).is_some(),
                    "section {i}: length -1 must error"
                );
            }
            assert!(
                reject(&patched64(&bytes, at + 8, u64::MAX / 2)).is_some(),
                "section {i}: huge length must be bounds-checked, not trusted"
            );
            if i > 0 {
                assert!(
                    reject(&patched64(&bytes, at, table[i - 1].0 as u64)).is_some(),
                    "section {i}: overlap with section {} must error",
                    i - 1
                );
            }
        }
    }
}

/// Blind byte flips across the whole artifact — header, table, and
/// payload: any outcome is fine except a panic. (Payload flips that
/// survive structural validation load; most trip the SoA-mirror or
/// topology checks.)
#[test]
fn binary_byte_flips_never_panic() {
    for bytes in [rf_bin(), gbt_bin()] {
        for pos in (0..bytes.len()).step_by(bytes.len() / 331 + 1) {
            let mut b = bytes.clone();
            b[pos] ^= 0x41;
            let _ = reject(&b);
        }
    }
}

/// Format confusion in both directions: INTB bytes handed to the JSON
/// deserializer and JSON text handed to the binary loader are each a
/// typed rejection, and the cheap `is_binary` sniff agrees with both.
#[test]
fn json_and_binary_front_doors_reject_each_other() {
    let bin = rf_bin();
    assert!(binfmt::is_binary(&bin));
    let as_text = String::from_utf8_lossy(&bin).into_owned();
    assert!(Model::from_json(&as_text).is_err(), "JSON door must refuse INTB bytes");

    let json = trained_model_json();
    assert!(!binfmt::is_binary(json.as_bytes()));
    assert!(matches!(
        OwnedBin::from_bytes(json.as_bytes()).view(),
        Err(BinError::BadMagic(_))
    ));
    assert!(matches!(OwnedBin::from_bytes(b"{}").view(), Err(BinError::TooShort { .. })));
}
