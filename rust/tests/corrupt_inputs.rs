//! Corrupt-input corpus: every model-loading front door — the IR JSON
//! deserializer, the LightGBM/XGBoost importers, and both manifest
//! parsers — must turn arbitrary broken input into a typed error. No
//! panic, no hang, no pathological allocation driven by a hostile
//! header. (ISSUE 7 satellite: harden model-loading inputs.)

use intreeger::data::shuttle_like;
use intreeger::ir::import::{lightgbm, xgboost};
use intreeger::ir::{IrError, Model, MAX_CLASSES, MAX_FEATURES, MAX_TREES};
use intreeger::runtime::{Manifest, PipelineManifest};
use intreeger::trees::{ForestParams, RandomForest};

fn trained_model_json() -> String {
    let ds = shuttle_like(400, 13);
    RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        5,
    )
    .to_json()
}

/// Truncating a valid model file at any byte must produce an error,
/// never a panic (and never an accepted model).
#[test]
fn truncated_model_json_always_errors() {
    let json = trained_model_json();
    // Every prefix is overkill (the file is tens of KB); sample a spread
    // of cut points plus the tail region where the object almost closes.
    let cuts: Vec<usize> = (0..json.len()).step_by(json.len() / 97 + 1).collect();
    for cut in cuts.into_iter().chain(json.len() - 10..json.len()) {
        assert!(
            Model::from_json(&json[..cut]).is_err(),
            "truncation at byte {cut}/{} must not yield a model",
            json.len()
        );
    }
    // The untruncated text still loads (the corpus is testing the cuts,
    // not the model).
    assert!(Model::from_json(&json).is_ok());
}

/// Byte-level mutations of a valid file: flip a character at a spread of
/// positions. Most mutations break JSON or the format; *none* may panic,
/// and whatever still parses must also pass structural validation.
#[test]
fn mutated_model_json_never_panics() {
    let json = trained_model_json();
    for pos in (0..json.len()).step_by(json.len() / 211 + 1) {
        let mut bytes = json.clone().into_bytes();
        bytes[pos] = match bytes[pos] {
            b'0'..=b'9' => b'x',
            _ => b'9',
        };
        if let Ok(s) = String::from_utf8(bytes) {
            // Either outcome is fine; panicking is not.
            let _ = Model::from_json(&s);
        }
    }
}

/// Hostile headers: declared counts beyond the capacity limits fail as
/// typed errors before any per-node work.
#[test]
fn oversized_declared_counts_are_rejected() {
    let stump_trees =
        r#"[{"feature":[-1],"threshold":[0],"left":[0],"right":[0],"leaf":[[1,0]]}]"#;
    let with_counts = |nf: usize, nc: usize| {
        format!(
            r#"{{"format":"intreeger-ir-v1","kind":"rf","n_features":{nf},
            "n_classes":{nc},"base_score":[0,0],"trees":{stump_trees}}}"#
        )
    };
    assert!(Model::from_json(&with_counts(MAX_FEATURES + 1, 2)).is_err());
    assert!(Model::from_json(&with_counts(4_000_000_000, 2)).is_err());
    assert!(Model::from_json(&with_counts(1, MAX_CLASSES + 1)).is_err());
    assert!(Model::from_json(&with_counts(1, 0)).is_err());
    // In-bounds control: the same skeleton with sane counts loads.
    assert!(Model::from_json(&with_counts(1, 2)).is_ok());
}

/// NaN / infinity smuggled through JSON numbers (1e999 parses to f64
/// infinity; 1e300 overflows the f32 narrowing) must be typed errors in
/// thresholds, leaf values and base scores alike.
#[test]
fn non_finite_numbers_are_rejected_everywhere() {
    let model_with = |threshold: &str, leaf: &str, base: &str| {
        format!(
            r#"{{"format":"intreeger-ir-v1","kind":"rf","n_features":1,
            "n_classes":2,"base_score":{base},
            "trees":[{{"feature":[0,-1,-1],"threshold":[{threshold},0,0],
            "left":[1,0,0],"right":[2,0,0],
            "leaf":[[],[0.9,0.1],{leaf}]}}]}}"#
        )
    };
    // control
    assert!(Model::from_json(&model_with("0.5", "[0.2,0.8]", "[0,0]")).is_ok());
    for bad in ["1e999", "-1e999", "1e300"] {
        assert!(
            Model::from_json(&model_with(bad, "[0.2,0.8]", "[0,0]")).is_err(),
            "threshold {bad}"
        );
        assert!(
            Model::from_json(&model_with("0.5", &format!("[0.2,{bad}]"), "[0,0]")).is_err(),
            "leaf {bad}"
        );
        assert!(
            Model::from_json(&model_with("0.5", "[0.2,0.8]", &format!("[0,{bad}]"))).is_err(),
            "base_score {bad}"
        );
    }
}

#[test]
fn validate_reports_typed_capacity_errors() {
    let mut m = Model::from_json(&trained_model_json()).unwrap();
    m.n_features = MAX_FEATURES + 1;
    assert_eq!(m.validate(), Err(IrError::TooManyFeatures { got: MAX_FEATURES + 1 }));
    let mut m = Model::from_json(&trained_model_json()).unwrap();
    m.trees.clear();
    assert_eq!(m.validate(), Err(IrError::NoTrees));
}

/// LightGBM corpus: truncations, NaN payloads, and hostile headers.
#[test]
fn lightgbm_corrupt_dumps_error_cleanly() {
    let valid = "\
num_class=1\nmax_feature_idx=1\n\n\
Tree=0\nnum_leaves=3\nsplit_feature=0 1\nthreshold=0.5 -1.25\n\
decision_type=2 2\nleft_child=1 -1\nright_child=-2 -3\nleaf_value=0.1 -0.2 0.3\n\nend of trees\n";
    assert!(lightgbm::import(valid).is_ok(), "control dump must import");

    // Truncations at every line boundary.
    let lines: Vec<&str> = valid.lines().collect();
    for cut in 0..lines.len() {
        let partial = lines[..cut].join("\n");
        // Either a typed error or (for cuts that still form a complete
        // dump) a valid model; never a panic.
        let _ = lightgbm::import(&partial);
    }

    // NaN threshold and NaN leaf value ("nan" parses as f64 NaN).
    let nan_threshold = valid.replace("threshold=0.5 -1.25", "threshold=nan -1.25");
    assert!(lightgbm::import(&nan_threshold).is_err());
    let nan_leaf = valid.replace("leaf_value=0.1 -0.2 0.3", "leaf_value=0.1 nan 0.3");
    assert!(lightgbm::import(&nan_leaf).is_err());
    let inf_leaf = valid.replace("leaf_value=0.1 -0.2 0.3", "leaf_value=0.1 inf 0.3");
    assert!(lightgbm::import(&inf_leaf).is_err());

    // Hostile headers: feature/class counts beyond the limits.
    let huge_features = valid.replace("max_feature_idx=1", "max_feature_idx=4000000000");
    assert!(lightgbm::import(&huge_features).is_err());
    let huge_classes = valid.replace("num_class=1", &format!("num_class={}", MAX_CLASSES + 1));
    assert!(lightgbm::import(&huge_classes).is_err());

    // Dangling child references.
    let dangling = valid.replace("right_child=-2 -3", "right_child=-2 -99");
    assert!(lightgbm::import(&dangling).is_err());
}

/// XGBoost corpus: malformed JSON, non-finite conditions, hostile counts.
#[test]
fn xgboost_corrupt_dumps_error_cleanly() {
    let valid = r#"[
      {"nodeid":0,"split":"f0","split_condition":0.5,"yes":1,"no":2,"missing":1,
       "children":[{"nodeid":1,"leaf":-0.4},{"nodeid":2,"leaf":0.6}]}
    ]"#;
    assert!(xgboost::import(valid, 2, 2, 0.0).is_ok(), "control dump must import");

    // Truncations.
    for cut in (0..valid.len()).step_by(7) {
        let _ = xgboost::import(&valid[..cut], 2, 2, 0.0);
    }

    // Infinity via exponent overflow in split_condition and leaf.
    let inf_cond = valid.replace("\"split_condition\":0.5", "\"split_condition\":1e999");
    assert!(xgboost::import(&inf_cond, 2, 2, 0.0).is_err());
    let inf_leaf = valid.replace("\"leaf\":0.6", "\"leaf\":1e999");
    assert!(xgboost::import(&inf_leaf, 2, 2, 0.0).is_err());

    // Non-finite base score and hostile caller-declared counts.
    assert!(xgboost::import(valid, 2, 2, f32::NAN).is_err());
    assert!(xgboost::import(valid, MAX_FEATURES + 1, 2, 0.0).is_err());
    assert!(xgboost::import(valid, 2, MAX_CLASSES + 1, 0.0).is_err());
    // A nodeid the children array does not contain.
    let dangling = valid.replace("\"yes\":1", "\"yes\":42");
    assert!(xgboost::import(&dangling, 2, 2, 0.0).is_err());
}

/// The tree-count limit holds even when every tree is tiny (a dump that
/// declares a million stumps is refused on count, not materialized).
#[test]
fn tree_count_limit_enforced() {
    let mut dump = String::from("[");
    for i in 0..=MAX_TREES {
        if i > 0 {
            dump.push(',');
        }
        dump.push_str("{\"nodeid\":0,\"leaf\":0.1}");
    }
    dump.push(']');
    assert!(xgboost::import(&dump, 2, 2, 0.0).is_err());
}

/// Cross-format manifest confusion: the XLA artifact manifest and the
/// pipeline bundle manifest share a file name (`manifest.json`); each
/// parser must reject the other's format with a typed error.
#[test]
fn manifest_cross_format_confusion_is_rejected() {
    let xla = r#"{
        "format": "intreeger-artifacts-v1",
        "tiers": [{"name":"quick","file":"f.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"use_pallas":true}]}"#;
    let bundle = r#"{
        "format": "intreeger-pipeline-v1",
        "seed": 42, "report": "report.json",
        "models": [{"kind":"rf","model":"model_rf.json","c":null,"layout":"ifelse","variant":"intreeger"}]}"#;
    assert!(Manifest::parse(xla).is_ok());
    assert!(PipelineManifest::parse(bundle).is_ok());
    assert!(Manifest::parse(bundle).is_err(), "tier parser must reject bundles");
    assert!(PipelineManifest::parse(xla).is_err(), "bundle parser must reject tier manifests");

    // And the serving boot path surfaces it as an error, not a panic:
    // a directory holding an XLA manifest is not a pipeline bundle.
    let dir = std::env::temp_dir()
        .join(format!("intreeger_confused_manifest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), xla).unwrap();
    assert!(intreeger::coordinator::server_from_pipeline(
        &dir,
        intreeger::coordinator::ServerConfig::default()
    )
    .is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A pipeline bundle whose model file is corrupt must fail at load with
/// a located error (file name in the message), not serve garbage.
#[test]
fn bundle_with_corrupt_model_file_errors() {
    let dir = std::env::temp_dir()
        .join(format!("intreeger_corrupt_bundle_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
        "format": "intreeger-pipeline-v1",
        "seed": 1, "report": "report.json",
        "models": [{"kind":"rf","model":"model_rf.json","c":null,"layout":"ifelse","variant":"intreeger"}]}"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let json = trained_model_json();
    std::fs::write(dir.join("model_rf.json"), &json[..json.len() / 2]).unwrap();
    let m = PipelineManifest::load(&dir).unwrap();
    let err = m.load_model(&dir, "rf").unwrap_err().to_string();
    assert!(err.contains("model_rf.json"), "error must locate the file: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
