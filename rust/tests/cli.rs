//! Integration: the `intreeger` CLI binary — the user-facing face of the
//! end-to-end framework (train → codegen → predict from the shell).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_intreeger")
}

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("intreeger_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn no_args_prints_usage() {
    let out = Command::new(bin()).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn train_codegen_predict_roundtrip() {
    let dir = tmpdir();
    let model = dir.join("model.json");
    let code = dir.join("model.c");
    let csv = dir.join("data.csv");

    // train on the synthetic shuttle dataset
    let out = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "1500", "--trees", "4",
               "--depth", "4", "--seed", "5", "--out"])
        .arg(&model)
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(model.is_file());
    assert!(String::from_utf8_lossy(&out.stderr).contains("holdout accuracy"));

    // codegen (integer-only if-else)
    let out = Command::new(bin())
        .args(["codegen", "--model"])
        .arg(&model)
        .args(["--variant", "intreeger", "--out"])
        .arg(&code)
        .output()
        .unwrap();
    assert!(out.status.success(), "codegen failed: {}", String::from_utf8_lossy(&out.stderr));
    let src = std::fs::read_to_string(&code).unwrap();
    assert!(src.contains("void predict(const float *data, uint32_t *result)"));

    // predict over a CSV
    let ds = intreeger::data::shuttle_like(50, 6);
    intreeger::data::csv::write_file(&csv, &ds).unwrap();
    let out = Command::new(bin())
        .args(["predict", "--model"])
        .arg(&model)
        .arg("--csv")
        .arg(&csv)
        .output()
        .unwrap();
    assert!(out.status.success(), "predict failed: {}", String::from_utf8_lossy(&out.stderr));
    let lines: Vec<&str> = std::str::from_utf8(&out.stdout).unwrap().lines().collect();
    assert_eq!(lines.len(), 50);
    assert!(lines.iter().all(|l| l.parse::<u32>().map(|c| c < 7).unwrap_or(false)));
}

#[test]
fn simulate_outputs_all_cores_and_variants() {
    let dir = tmpdir();
    let model = dir.join("sim_model.json");
    Command::new(bin())
        .args(["train", "--dataset", "esa", "--rows", "800", "--trees", "3", "--depth", "4", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    let out = Command::new(bin())
        .args(["simulate", "--model"])
        .arg(&model)
        .args(["--dataset", "esa", "--rows", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["EPYC 7282", "Cortex-A72", "U74-MC", "FE310", "float", "flint", "intreeger"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    // 4 cores x 3 variants + header
    assert_eq!(text.lines().count(), 13);
}

#[test]
fn inspect_reports_quickscorer_eligibility() {
    let dir = tmpdir();
    let model = dir.join("inspect_model.json");
    let st = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "1000", "--trees", "3", "--depth", "5",
               "--seed", "9", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    assert!(st.success());
    let out = Command::new(bin())
        .args(["inspect", "--model"])
        .arg(&model)
        .arg("--trees")
        .output()
        .unwrap();
    assert!(out.status.success(), "inspect failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("quickscorer:"), "missing eligibility summary in:\n{text}");
    assert!(text.contains("3/3 trees eligible"), "depth-5 trees must all be eligible:\n{text}");
    assert!(text.contains("tree   0:"), "missing per-tree table:\n{text}");
    assert!(text.contains("qs-eligible"), "missing per-tree verdict:\n{text}");
}

#[test]
fn tablei_prints_table() {
    let out = Command::new(bin()).arg("tablei").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("EPYC 7282") && text.contains("RV32IMAC"));
}
