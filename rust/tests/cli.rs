//! Integration: the `intreeger` CLI binary — the user-facing face of the
//! end-to-end framework (train → codegen → predict from the shell).

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_intreeger")
}

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("intreeger_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn no_args_prints_usage() {
    let out = Command::new(bin()).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

/// The usage text is generated from the same command table `main`
/// dispatches on; this pins the full subcommand set (including flags
/// that drifted out of the old hand-written USAGE string) so a new or
/// renamed command must show up in `--help`.
#[test]
fn help_lists_every_subcommand_and_flag_enumeration() {
    let out = Command::new(bin()).arg("--help").output().unwrap();
    assert!(out.status.success(), "--help must exit 0");
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in [
        "pipeline",
        "train",
        "import",
        "codegen",
        "predict",
        "inspect",
        "simulate",
        "serve",
        "serve-http",
        "tablei",
    ] {
        assert!(text.contains(cmd), "missing subcommand '{cmd}' in help:\n{text}");
    }
    // Flags the old hand-written USAGE drifted on, plus generated lists.
    for needle in [
        "--trees",            // inspect per-tree table
        "--workers",          // serve worker pool
        "--calibrate",        // serve auto-calibration
        "--backend",          // serve SIMD backend override
        "--threads",          // serve/inspect intra-batch thread override
        "--pipeline",         // serve from a bundle
        "--target",           // pipeline label column
        "--holdout",          // pipeline split fraction
        "--addr",             // serve-http listen address
        "--max-batch-delay",  // serve-http adaptive-batching age bound
        "ifelse|native|native-predicated|quickscorer", // full layout list, generated
        "float|flint|intreeger",                       // full variant list, generated
        "scalar|avx2|neon",                            // full backend list, generated
    ] {
        assert!(text.contains(needle), "missing '{needle}' in help:\n{text}");
    }
    // `help` and `-h` behave identically.
    let h2 = Command::new(bin()).arg("help").output().unwrap();
    assert!(h2.status.success());
    assert_eq!(out.stdout, h2.stdout);
    // `--help` after a subcommand prints usage too — it must not
    // dispatch (pipeline would panic on the missing --out; train would
    // silently run a full training job).
    let h3 = Command::new(bin()).args(["pipeline", "--help"]).output().unwrap();
    assert!(h3.status.success(), "subcommand --help must exit 0");
    assert_eq!(out.stdout, h3.stdout);
}

/// The headline command: CSV in -> verified integer-only C + report out,
/// then `serve --pipeline` boots straight from the bundle.
#[test]
fn pipeline_cli_end_to_end_and_serve_from_bundle() {
    let dir = tmpdir();
    let csv = dir.join("pipe_data.csv");
    let out_dir = dir.join("pipe_out");
    let ds = intreeger::data::shuttle_like(600, 16);
    intreeger::data::csv::write_file(&csv, &ds).unwrap();

    let out = Command::new(bin())
        .args(["pipeline", "--csv"])
        .arg(&csv)
        .args(["--trees", "3", "--depth", "4", "--seed", "9", "--out"])
        .arg(&out_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "pipeline failed: {}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("pipeline PASS"), "missing verdict in:\n{stderr}");
    for f in ["model_rf.json", "model_rf.c", "report.json", "REPORT.md", "manifest.json", "holdout.csv"] {
        assert!(out_dir.join(f).is_file(), "missing artifact {f}");
    }
    let report = std::fs::read_to_string(out_dir.join("report.json")).unwrap();
    assert!(report.contains("\"format\":\"intreeger-pipeline-report-v1\""));
    assert!(report.contains("\"argmax_identical\":true"));
    assert!(report.contains("\"verified\":true"));

    // Serve boots from the bundle and answers the demo workload.
    let serve = Command::new(bin())
        .args(["serve", "--pipeline"])
        .arg(&out_dir)
        .args(["--requests", "50"])
        .output()
        .unwrap();
    assert!(serve.status.success(), "serve failed: {}", String::from_utf8_lossy(&serve.stderr));
    let text = String::from_utf8_lossy(&serve.stdout);
    assert!(text.contains("served 50 requests"), "unexpected serve output:\n{text}");
    assert!(
        text.contains("outcomes: 50 ok / 0 failed"),
        "serve must report per-request outcomes:\n{text}"
    );
    assert!(
        text.contains("execution: kernel"),
        "serve must surface the execution strategy:\n{text}"
    );
    assert!(
        text.contains("intra-batch thread(s)"),
        "serve must surface the thread count:\n{text}"
    );
    // report.json carries the additive execution object (schema v1).
    assert!(report.contains("\"backend\":"), "missing execution backend in report");
    assert!(report.contains("\"threads\":"), "missing execution threads in report");
    assert!(report.contains("\"detected_features\":"), "missing detected_features in report");
}

/// `--target` selects a non-last label column by header name.
#[test]
fn pipeline_cli_target_column_by_name() {
    let dir = tmpdir();
    let csv = dir.join("target_data.csv");
    let out_dir = dir.join("target_out");
    // Rebuild a shuttle-like CSV with the label as the FIRST column.
    let ds = intreeger::data::shuttle_like(400, 17);
    let mut text = String::from("label,f0,f1,f2,f3,f4,f5,f6\n");
    for i in 0..ds.n_rows() {
        text.push_str(&ds.labels[i].to_string());
        for v in ds.row(i) {
            text.push_str(&format!(",{v}"));
        }
        text.push('\n');
    }
    std::fs::write(&csv, text).unwrap();

    let out = Command::new(bin())
        .args(["pipeline", "--csv"])
        .arg(&csv)
        .args(["--header", "--target", "label", "--trees", "2", "--depth", "3", "--out"])
        .arg(&out_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "pipeline failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(out_dir.join("report.json").is_file());
}

#[test]
fn train_codegen_predict_roundtrip() {
    let dir = tmpdir();
    let model = dir.join("model.json");
    let code = dir.join("model.c");
    let csv = dir.join("data.csv");

    // train on the synthetic shuttle dataset
    let out = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "1500", "--trees", "4",
               "--depth", "4", "--seed", "5", "--out"])
        .arg(&model)
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(model.is_file());
    assert!(String::from_utf8_lossy(&out.stderr).contains("holdout accuracy"));

    // codegen (integer-only if-else)
    let out = Command::new(bin())
        .args(["codegen", "--model"])
        .arg(&model)
        .args(["--variant", "intreeger", "--out"])
        .arg(&code)
        .output()
        .unwrap();
    assert!(out.status.success(), "codegen failed: {}", String::from_utf8_lossy(&out.stderr));
    let src = std::fs::read_to_string(&code).unwrap();
    assert!(src.contains("void predict(const float *data, uint32_t *result)"));

    // predict over a CSV
    let ds = intreeger::data::shuttle_like(50, 6);
    intreeger::data::csv::write_file(&csv, &ds).unwrap();
    let out = Command::new(bin())
        .args(["predict", "--model"])
        .arg(&model)
        .arg("--csv")
        .arg(&csv)
        .output()
        .unwrap();
    assert!(out.status.success(), "predict failed: {}", String::from_utf8_lossy(&out.stderr));
    let lines: Vec<&str> = std::str::from_utf8(&out.stdout).unwrap().lines().collect();
    assert_eq!(lines.len(), 50);
    assert!(lines.iter().all(|l| l.parse::<u32>().map(|c| c < 7).unwrap_or(false)));
}

#[test]
fn simulate_outputs_all_cores_and_variants() {
    let dir = tmpdir();
    let model = dir.join("sim_model.json");
    Command::new(bin())
        .args(["train", "--dataset", "esa", "--rows", "800", "--trees", "3", "--depth", "4", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    let out = Command::new(bin())
        .args(["simulate", "--model"])
        .arg(&model)
        .args(["--dataset", "esa", "--rows", "500"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["EPYC 7282", "Cortex-A72", "U74-MC", "FE310", "float", "flint", "intreeger"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    // 4 cores x 3 variants + header
    assert_eq!(text.lines().count(), 13);
}

#[test]
fn inspect_reports_quickscorer_eligibility_and_simd() {
    let dir = tmpdir();
    let model = dir.join("inspect_model.json");
    let st = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "1000", "--trees", "3", "--depth", "5",
               "--seed", "9", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    assert!(st.success());
    let out = Command::new(bin())
        .args(["inspect", "--model"])
        .arg(&model)
        .arg("--trees")
        .output()
        .unwrap();
    assert!(out.status.success(), "inspect failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("quickscorer:"), "missing eligibility summary in:\n{text}");
    assert!(text.contains("3/3 trees eligible"), "depth-5 trees must all be eligible:\n{text}");
    assert!(text.contains("tree   0:"), "missing per-tree table:\n{text}");
    assert!(text.contains("qs-eligible"), "missing per-tree verdict:\n{text}");
    // SIMD backend section: host features, available backends, and the
    // calibration preview (this model is RF, so the probe runs).
    assert!(text.contains("simd:"), "missing SIMD summary in:\n{text}");
    assert!(text.contains("backends available [scalar"), "missing backend list in:\n{text}");
    // Core topology + threads default (the per-machine half of scaling).
    assert!(text.contains("cores:"), "missing core summary in:\n{text}");
    assert!(text.contains("logical"), "missing logical core count in:\n{text}");
    assert!(
        text.contains("default intra-batch threads"),
        "missing threads default in:\n{text}"
    );
    assert!(text.contains("calibration:     would pick"), "missing calibration preview:\n{text}");
    // Cache topology + pin plan: printed on every host — either the
    // parsed LLC groups and the plan INTREEGER_PIN=1 would apply, or an
    // explicit "unavailable" line (the loud-no-op contract made
    // visible).
    assert!(text.contains("topology:"), "missing cache topology line in:\n{text}");
    assert!(
        text.contains("LLC group") || text.contains("LLC groups unavailable"),
        "topology line must name LLC groups or say they are unavailable:\n{text}"
    );
    assert!(
        text.contains("pin plan"),
        "missing pin plan (or its unavailable fallback) in:\n{text}"
    );

    // A forced backend flows through `inspect --backend` into the
    // resolved default and the calibration sweep.
    let out = Command::new(bin())
        .args(["inspect", "--model"])
        .arg(&model)
        .args(["--backend", "scalar"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("default scalar"), "override must pin the default:\n{text}");
    assert!(text.contains("@ scalar"), "calibration must collapse to scalar:\n{text}");
}

/// `--threads 1` (and equivalently `INTREEGER_THREADS=1`) pins the
/// intra-batch thread count: the inspect default collapses to 1 and the
/// calibration preview's winner label carries `@ 1t`.
#[test]
fn inspect_threads_flag_and_env_pin_single_thread() {
    let dir = tmpdir();
    let model = dir.join("threads_model.json");
    let st = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "800", "--trees", "3", "--depth", "4",
               "--seed", "11", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    assert!(st.success());
    // Flag form.
    let out = Command::new(bin())
        .args(["inspect", "--model"])
        .arg(&model)
        .args(["--threads", "1"])
        .output()
        .unwrap();
    assert!(out.status.success(), "inspect failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("default intra-batch threads 1"),
        "--threads 1 must pin the default:\n{text}"
    );
    assert!(text.contains("@ 1t"), "calibration winner must carry the thread count:\n{text}");
    // Env form — same pin without the flag.
    let out = Command::new(bin())
        .args(["inspect", "--model"])
        .arg(&model)
        .env("INTREEGER_THREADS", "1")
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("default intra-batch threads 1"),
        "INTREEGER_THREADS=1 must pin the default:\n{text}"
    );
    assert!(text.contains("@ 1t"), "calibration sweep must collapse to 1 thread:\n{text}");
}

/// The serve demo reports the failure-model counters, and a pinned
/// `INTREEGER_FAULTS` plan drives them deterministically: the blocking
/// demo client retries injected queue-fulls, so every request still
/// resolves ok, while the shed counter records each refused admission.
#[test]
fn serve_reports_overload_counters_under_fault_plan() {
    let dir = tmpdir();
    let model = dir.join("faults_model.json");
    let st = Command::new(bin())
        .args(["train", "--dataset", "shuttle", "--rows", "900", "--trees", "3", "--depth", "4",
               "--seed", "21", "--out"])
        .arg(&model)
        .status()
        .unwrap();
    assert!(st.success());

    // Fault-free control: the outcomes line is present with zero failures.
    let out = Command::new(bin())
        .args(["serve", "--model"])
        .arg(&model)
        .args(["--requests", "40"])
        .env("INTREEGER_FAULTS", "")
        .output()
        .unwrap();
    assert!(out.status.success(), "serve failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("served 40 requests"), "unexpected serve output:\n{text}");
    assert!(text.contains("outcomes: 40 ok / 0 failed"), "missing outcomes line:\n{text}");
    assert!(text.contains("shed 0 expired 0 rejected 0 lost 0"), "counters must be zero:\n{text}");

    // Pinned fault plan: exactly 3 injected queue-fulls, all absorbed by
    // the closed-loop client's retry, all recorded by the shed counter.
    let out = Command::new(bin())
        .args(["serve", "--model"])
        .arg(&model)
        .args(["--requests", "40"])
        .env("INTREEGER_FAULTS", "queue_full_n=3")
        .output()
        .unwrap();
    assert!(out.status.success(), "serve failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("outcomes: 40 ok / 0 failed"), "requests must all resolve:\n{text}");
    assert!(text.contains("shed 3"), "the injected sheds must be reported:\n{text}");
}

/// CLI error paths exit(1) with a rendered `error:` line — no panic
/// backtraces for predictable failures (missing files, corrupt models,
/// non-bundle directories).
#[test]
fn cli_errors_are_graceful_not_panics() {
    let check = |out: std::process::Output, what: &str| {
        assert!(!out.status.success(), "{what}: must fail");
        assert_eq!(out.status.code(), Some(1), "{what}: must exit(1), not abort");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("error:"), "{what}: missing rendered error:\n{err}");
        assert!(!err.contains("panicked"), "{what}: must not panic:\n{err}");
    };
    check(
        Command::new(bin())
            .args(["codegen", "--model", "/nonexistent/model.json"])
            .output()
            .unwrap(),
        "missing model file",
    );
    let dir = tmpdir();
    let not_a_bundle = dir.join("not_a_bundle");
    std::fs::create_dir_all(&not_a_bundle).unwrap();
    check(
        Command::new(bin())
            .args(["serve", "--pipeline"])
            .arg(&not_a_bundle)
            .output()
            .unwrap(),
        "serve from a non-bundle dir",
    );
    let corrupt = dir.join("corrupt_model.json");
    std::fs::write(&corrupt, "{\"format\":\"intreeger-ir-v1\",\"kind\":\"rf\"").unwrap();
    check(
        Command::new(bin())
            .args(["codegen", "--model"])
            .arg(&corrupt)
            .output()
            .unwrap(),
        "corrupt model file",
    );
    let bad_dump = dir.join("bad_dump.txt");
    std::fs::write(&bad_dump, "not a lightgbm dump").unwrap();
    check(
        Command::new(bin())
            .args(["import", "--file"])
            .arg(&bad_dump)
            .output()
            .unwrap(),
        "malformed import dump",
    );
}

#[test]
fn tablei_prints_table() {
    let out = Command::new(bin()).arg("tablei").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("EPYC 7282") && text.contains("RV32IMAC"));
}
