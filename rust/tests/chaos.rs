//! Chaos suite: deterministic fault injection against the serving
//! stack, asserting the failure-model invariants end to end:
//!
//! 1. **No lost reply**: every submitted request resolves with a
//!    `Response` or a typed `ServeError` (all receives use bounded
//!    timeouts — a hang is a failure, not a wait).
//! 2. **No caller panic**: faults surface as values, never unwinding.
//! 3. **Bit-identity of survivors**: requests that serve under a fault
//!    plan produce exactly the oracle engine's fixed-point accumulators
//!    (the same parity invariant the kernels guarantee), at every
//!    worker count — and the whole suite runs under the CI
//!    `INTREEGER_THREADS` / `INTREEGER_BACKEND` legs, covering thread
//!    counts and backends.
//! 4. **Counters consistent**: admitted = served + expired + lost, with
//!    shed/rejected accounted at admission.
//!
//! Every test pins an explicit `FaultPlan` (`ServerConfig::faults:
//! Some(..)`), so the suite is immune to a process-wide
//! `INTREEGER_FAULTS` (the CI chaos leg sets one to exercise the env
//! path; `env_plan_drives_injection` covers it hermetically here).

use intreeger::coordinator::{
    BatchPolicy, FaultPlan, InferenceServer, Metrics, ModelRegistry, RegistryError, ReplySlot,
    ServeError, ServerConfig, DEGRADE_AFTER, FAULTS_ENV,
};
use intreeger::data::{shuttle_like, Dataset};
use intreeger::inference::IntEngine;
use intreeger::ir::Model;
use intreeger::trees::{ForestParams, RandomForest};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const RESOLVE: Duration = Duration::from_secs(10);

fn model() -> (Dataset, Model) {
    let ds = shuttle_like(1000, 41);
    let m = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() },
        7,
    );
    (ds, m)
}

fn no_faults() -> Option<FaultPlan> {
    Some(FaultPlan::none())
}

/// Invariant 3 baseline: with faults pinned off, results are
/// bit-identical to the oracle engine at every worker count, and the
/// failure counters stay at zero.
#[test]
fn fault_free_run_bit_identical_across_worker_counts() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    for n_workers in [1usize, 2, 4] {
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) },
                n_workers,
                faults: no_faults(),
                ..Default::default()
            },
        );
        let rows: Vec<Vec<f32>> = (0..200).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        for (i, r) in server.infer_many(rows).into_iter().enumerate() {
            let r = r.expect("fault-free request must serve");
            assert_eq!(
                r.fixed,
                oracle.predict_fixed(ds.row(i % ds.n_rows())),
                "row {i} parity at {n_workers} workers"
            );
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 200);
        assert_eq!(snap.responses, 200);
        assert_eq!((snap.shed, snap.expired, snap.rejected, snap.lost), (0, 0, 0, 0));
        assert_eq!((snap.worker_panics, snap.worker_restarts), (0, 0));
        assert!(!snap.degraded);
    }
}

/// A scripted worker panic on the first batch: every in-flight request
/// resolves as `WorkerLost` (no hang, no caller panic), the supervisor
/// restarts the shard, and the server keeps serving bit-identically.
#[test]
fn worker_panic_resolves_all_requests_and_recovers() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            // One deadline-flushed batch holds the whole first wave.
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(20) },
            n_workers: 1,
            faults: Some(FaultPlan { panic_batches: vec![1], ..FaultPlan::none() }),
            ..Default::default()
        },
    );
    // Wave 1: all land in batch #1, which panics mid-execution.
    let rxs: Vec<_> = (0..8)
        .map(|i| server.submit(ds.row(i).to_vec()).expect("admitted"))
        .collect();
    for rx in rxs {
        let resolved = rx.recv_timeout(RESOLVE).expect("request must resolve, not hang");
        assert_eq!(resolved, Err(ServeError::WorkerLost));
    }
    // Wave 2: the restarted worker serves correctly.
    for i in 0..8 {
        let r = server.infer(ds.row(i).to_vec()).expect("post-restart serve");
        assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i} after restart");
    }
    let snap = server.metrics();
    assert_eq!(snap.worker_panics, 1);
    assert_eq!(snap.worker_restarts, 1);
    assert_eq!(snap.lost, 8);
    assert_eq!(snap.responses, 8);
    assert_eq!(snap.requests, 16);
    // One failure is below the degradation threshold.
    assert!(DEGRADE_AFTER > 1 && !snap.degraded);
}

/// Repeated execution-path failure degrades the shard to the
/// conservative fallback (scalar-branchless @ 1 thread), recorded in
/// metrics — and the fallback's answers are bit-identical to the
/// primary engine's (the parity invariant makes degradation lossless).
#[test]
fn repeated_panics_degrade_to_fallback_and_keep_serving() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) },
            n_workers: 1,
            faults: Some(FaultPlan {
                panic_batches: (1..=u64::from(DEGRADE_AFTER)).collect(),
                ..FaultPlan::none()
            }),
            ..Default::default()
        },
    );
    // Sequential blocking calls: each forms its own batch, so the first
    // DEGRADE_AFTER batches crash deterministically.
    for i in 0..DEGRADE_AFTER {
        assert_eq!(
            server.infer(ds.row(i as usize).to_vec()),
            Err(ServeError::WorkerLost),
            "scripted crash #{i}"
        );
    }
    // The shard is degraded now; serving continues bit-identically.
    for i in 0..30 {
        let r = server.infer(ds.row(i).to_vec()).expect("degraded serve");
        assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i} on fallback engine");
    }
    let snap = server.metrics();
    assert!(snap.degraded, "degraded flag must be recorded");
    assert_eq!(snap.worker_panics, u64::from(DEGRADE_AFTER));
    assert_eq!(snap.worker_restarts, u64::from(DEGRADE_AFTER));
    assert_eq!(snap.lost, u64::from(DEGRADE_AFTER));
    assert_eq!(snap.responses, 30);
    // The recorded execution strategy is the fallback's.
    assert_eq!(snap.kernel.as_deref(), Some("branchless"));
    assert_eq!(snap.backend.as_deref(), Some("scalar"));
    assert_eq!(snap.threads, Some(1));
}

/// Scripted service latency plus a short TTL: requests stuck behind a
/// slow batch expire at batch-formation time with `DeadlineExceeded`
/// instead of burning kernel time (and instead of hanging).
#[test]
fn latency_injection_expires_queued_requests() {
    let (ds, m) = model();
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            // Flush per request so the injected latency serializes them.
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            n_workers: 1,
            faults: Some(FaultPlan {
                latency: Some(Duration::from_millis(30)),
                ..FaultPlan::none()
            }),
            ..Default::default()
        },
    );
    // A (no TTL) enters batch #1; B (2 ms TTL) waits ≥30 ms behind A's
    // injected service latency — far past its deadline.
    let rx_a = server.submit_with_ttl(ds.row(0).to_vec(), None).expect("admitted A");
    let rx_b = server
        .submit_with_ttl(ds.row(1).to_vec(), Some(Duration::from_millis(2)))
        .expect("admitted B");
    let a = rx_a.recv_timeout(RESOLVE).expect("A resolves");
    let b = rx_b.recv_timeout(RESOLVE).expect("B resolves");
    assert!(a.is_ok(), "A was fresh at batch formation: {a:?}");
    assert_eq!(b, Err(ServeError::DeadlineExceeded));
    let snap = server.metrics();
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.responses, 1);
    assert_eq!(snap.requests, 2);
}

/// Forced queue-full sheds exactly the scripted number of submissions,
/// every shed resolves immediately as `QueueFull`, and the admitted
/// remainder serves normally.
#[test]
fn forced_queue_full_sheds_exactly_and_serves_the_rest() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            faults: Some(FaultPlan { queue_full_first: 5, ..FaultPlan::none() }),
            ..Default::default()
        },
    );
    let mut shed = 0u64;
    let mut rxs = Vec::new();
    for i in 0..20 {
        match server.submit(ds.row(i).to_vec()) {
            Ok(rx) => rxs.push((i, rx)),
            Err(e) => {
                assert_eq!(e, ServeError::QueueFull);
                shed += 1;
            }
        }
    }
    assert_eq!(shed, 5, "exactly the scripted sheds");
    assert_eq!(rxs.len(), 15);
    for (i, rx) in rxs {
        let r = rx.recv_timeout(RESOLVE).expect("resolves").expect("serves");
        assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i}");
    }
    let snap = server.metrics();
    assert_eq!(snap.shed, 5);
    assert_eq!(snap.requests, 15);
    assert_eq!(snap.responses, 15);
}

/// The counter accounting identity under a crash plan, at multiple
/// workers: admitted = served + expired + lost, and the Ok results stay
/// bit-identical to the oracle.
#[test]
fn accounting_identity_holds_under_panic_plan() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
            n_workers: 2,
            faults: Some(FaultPlan { panic_batches: vec![2], ..FaultPlan::none() }),
            ..Default::default()
        },
    );
    let rows: Vec<Vec<f32>> = (0..100).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
    let results = server.infer_many(rows);
    assert_eq!(results.len(), 100, "every request resolves");
    let mut ok = 0u64;
    let mut lost = 0u64;
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Ok(resp) => {
                ok += 1;
                assert_eq!(
                    resp.fixed,
                    oracle.predict_fixed(ds.row(i % ds.n_rows())),
                    "surviving row {i} parity"
                );
            }
            Err(ServeError::WorkerLost) => lost += 1,
            Err(other) => panic!("unexpected error under panic plan: {other}"),
        }
    }
    assert!(lost > 0, "the scripted crash must strand at least one request");
    let snap = server.metrics();
    assert_eq!(snap.responses, ok);
    assert_eq!(snap.lost, lost);
    assert_eq!(
        snap.requests,
        snap.responses + snap.expired + snap.lost,
        "admitted = served + expired + lost"
    );
    assert_eq!(snap.worker_panics, 1);
}

/// The `INTREEGER_FAULTS` env path: a server started with `faults: None`
/// picks the plan up from the environment. (Other tests pin explicit
/// plans, so this test owns the variable while it runs.)
#[test]
fn env_plan_drives_injection() {
    let (ds, m) = model();
    let prior = std::env::var(FAULTS_ENV).ok();
    std::env::set_var(FAULTS_ENV, "queue_full_n=2");
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig { faults: None, ..Default::default() },
    );
    // The plan was captured at start; release the variable immediately.
    match &prior {
        Some(v) => std::env::set_var(FAULTS_ENV, v),
        None => std::env::remove_var(FAULTS_ENV),
    }
    let mut shed = 0;
    for i in 0..4 {
        match server.submit(ds.row(i).to_vec()) {
            Ok(rx) => {
                rx.recv_timeout(RESOLVE).expect("resolves").expect("serves");
            }
            Err(ServeError::QueueFull) => shed += 1,
            Err(other) => panic!("unexpected: {other}"),
        }
    }
    assert_eq!(shed, 2, "env-scripted sheds");
    assert_eq!(server.metrics().shed, 2);
}

/// A malformed env plan is ignored loudly, never panics, and the server
/// serves normally.
#[test]
fn malformed_env_plan_is_ignored_not_fatal() {
    let (ds, m) = model();
    let prior = std::env::var(FAULTS_ENV).ok();
    std::env::set_var(FAULTS_ENV, "panic_batch=oops;;frobnicate");
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig { faults: None, ..Default::default() },
    );
    match &prior {
        Some(v) => std::env::set_var(FAULTS_ENV, v),
        None => std::env::remove_var(FAULTS_ENV),
    }
    let r = server.infer(ds.row(0).to_vec()).expect("serves despite bad plan");
    assert_eq!(r.fixed, IntEngine::compile(&m).predict_fixed(ds.row(0)));
    assert_eq!(server.metrics().shed, 0);
}

// ---------------------------------------------------------------------------
// Hot-swap chaos (ISSUE 9): version swaps under flood. The registry's
// swap-drain protocol promises that a publish is invisible to in-flight
// traffic — every admitted request is answered by the version that
// admitted it, nothing is dropped, and once the old version drains, all
// new traffic serves from the new one.

/// A second model on the same schema, trained differently enough that
/// the two versions are distinguishable by their fixed accumulators.
fn model_v2(ds: &Dataset) -> Model {
    RandomForest::train(
        ds,
        &ForestParams { n_trees: 8, max_depth: 4, ..Default::default() },
        19,
    )
}

fn swap_config() -> ServerConfig {
    ServerConfig {
        policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) },
        n_workers: 2,
        faults: no_faults(),
        ..Default::default()
    }
}

/// Swap v1 → v2 in the middle of a multi-threaded flood: no reply is
/// lost, every reply is bit-identical to one of the two versions'
/// oracles, post-drain traffic answers from v2 only, the per-version
/// accounting identity holds, and the memory gauges release v1.
#[test]
fn hot_swap_mid_flood_loses_no_replies() {
    let (ds, m1) = model();
    let m2 = model_v2(&ds);
    let o1 = IntEngine::compile(&m1);
    let o2 = IntEngine::compile(&m2);
    let n_probe = 100usize;
    let rows: Arc<Vec<Vec<f32>>> = Arc::new((0..n_probe).map(|i| ds.row(i).to_vec()).collect());
    let exp1: Arc<Vec<Vec<u32>>> =
        Arc::new((0..n_probe).map(|i| o1.predict_fixed(ds.row(i))).collect());
    let exp2: Arc<Vec<Vec<u32>>> =
        Arc::new((0..n_probe).map(|i| o2.predict_fixed(ds.row(i))).collect());
    assert!(exp1.iter().zip(exp2.iter()).any(|(a, b)| a != b), "versions must be distinguishable");

    let registry = Arc::new(ModelRegistry::new(Arc::new(Metrics::new())));
    registry
        .publish("m", 1, 4096, InferenceServer::start(&m1, None, swap_config()))
        .expect("publish v1");
    // Hold v1 so its metrics stay readable after the swap drops it from
    // the slot (in production this handle is an in-flight request's).
    let v1 = registry.resolve("m", None).expect("resolve v1");
    assert_eq!(v1.version(), 1);

    let n_threads = 4usize;
    let per_thread = 120usize;
    let done = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let registry = Arc::clone(&registry);
            let (rows, exp1, exp2) = (Arc::clone(&rows), Arc::clone(&exp1), Arc::clone(&exp2));
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut matched = 0usize;
                for k in 0..per_thread {
                    let i = (t + k * 4) % rows.len();
                    let r = registry
                        .infer("m", None, rows[i].clone())
                        .expect("no lost reply under a fault-free swap");
                    assert!(
                        r.fixed == exp1[i] || r.fixed == exp2[i],
                        "thread {t} row {i}: reply matches neither version's oracle"
                    );
                    matched += 1;
                    done.fetch_add(1, Ordering::Relaxed);
                }
                matched
            })
        })
        .collect();

    // Swap once a third of the flood has been answered — mid-stream, not
    // before or after it.
    let third = (n_threads * per_thread / 3) as u64;
    let deadline = Instant::now() + RESOLVE;
    while done.load(Ordering::Relaxed) < third {
        assert!(Instant::now() < deadline, "flood stalled before the swap point");
        std::thread::yield_now();
    }
    registry
        .publish("m", 2, 8192, InferenceServer::start(&m2, None, swap_config()))
        .expect("publish v2 mid-flood");

    let replies: usize = handles.into_iter().map(|h| h.join().expect("flood thread")).sum();
    assert_eq!(replies, n_threads * per_thread, "every flooded request replied");

    // Post-drain: unpinned traffic serves v2, bit-identically.
    let v2 = registry.resolve("m", None).expect("resolve after swap");
    assert_eq!(v2.version(), 2);
    for i in 0..20 {
        let r = registry.infer("m", None, rows[i].clone()).expect("post-swap serve");
        assert_eq!(r.fixed, exp2[i], "post-drain row {i} must answer from v2");
    }
    // The non-retaining publish dropped v1 from the slot: pinning it is
    // now a typed error, not a stale route.
    assert!(matches!(
        registry.infer("m", Some(1), rows[0].clone()),
        Err(RegistryError::UnknownVersion { .. })
    ));

    // Accounting identity per version, and totals across the swap.
    let s1 = v1.server().metrics();
    let s2 = v2.server().metrics();
    for (tag, s) in [("v1", &s1), ("v2", &s2)] {
        assert_eq!(
            s.requests,
            s.responses + s.expired + s.lost,
            "{tag}: admitted = served + expired + lost"
        );
        assert_eq!((s.expired, s.lost), (0, 0), "{tag}: fault-free swap loses nothing");
    }
    assert_eq!(
        s1.requests + s2.requests,
        (n_threads * per_thread + 20) as u64,
        "both versions together saw exactly the flood"
    );

    // Releasing the last v1 handle drains it and releases its gauges.
    drop(v1);
    let gauges = registry.metrics().snapshot();
    assert_eq!((gauges.model_count, gauges.model_bytes), (1, 8192));
}

/// The drain half of the protocol, isolated: a wave parked in v1's
/// batcher when the swap lands still completes *on v1* (flushed by the
/// drain, answered with v1's bits) while new traffic is already being
/// served by v2.
#[test]
fn in_flight_v1_batches_drain_on_v1_while_v2_takes_over() {
    let (ds, m1) = model();
    let m2 = model_v2(&ds);
    let o1 = IntEngine::compile(&m1);
    let o2 = IntEngine::compile(&m2);

    let registry = Arc::new(ModelRegistry::new(Arc::new(Metrics::new())));
    // A long deadline and a large batch: the wave below sits in the
    // batcher instead of flushing, so the swap provably overlaps it.
    let parked = ServerConfig {
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(500) },
        n_workers: 1,
        faults: no_faults(),
        ..Default::default()
    };
    registry.publish("m", 1, 4096, InferenceServer::start(&m1, None, parked)).expect("v1");
    let v1 = registry.resolve("m", None).expect("resolve v1");
    let rxs: Vec<_> = (0..12)
        .map(|i| v1.server().submit(ds.row(i).to_vec()).expect("admitted on v1"))
        .collect();

    registry
        .publish("m", 2, 4096, InferenceServer::start(&m2, None, swap_config()))
        .expect("publish v2 over a parked wave");
    for i in 0..8 {
        let r = registry.infer("m", None, ds.row(i).to_vec()).expect("v2 serves during drain");
        assert_eq!(r.fixed, o2.predict_fixed(ds.row(i)), "new row {i} answers from v2");
    }

    // Dropping the last handle runs the drain: the parked wave must be
    // flushed and answered — by v1 — not disconnected.
    drop(v1);
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx
            .recv_timeout(RESOLVE)
            .expect("drained request must resolve, not hang or disconnect")
            .expect("drained request serves");
        assert_eq!(r.fixed, o1.predict_fixed(ds.row(i)), "parked row {i} answers from v1");
    }
}

/// A swap prompted by the worst reason — the old version's worker is
/// crashing under a scripted fault plan: stranded v1 requests resolve as
/// typed `WorkerLost` (never hang), the accounting identity holds on
/// both sides, and the registry serves v2 cleanly afterwards.
#[test]
fn swap_away_from_a_crashing_version_keeps_the_identity() {
    let (ds, m1) = model();
    let m2 = model_v2(&ds);
    let o2 = IntEngine::compile(&m2);

    let registry = Arc::new(ModelRegistry::new(Arc::new(Metrics::new())));
    let crashing = ServerConfig {
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(20) },
        n_workers: 1,
        faults: Some(FaultPlan { panic_batches: vec![1], ..FaultPlan::none() }),
        ..Default::default()
    };
    registry.publish("m", 1, 4096, InferenceServer::start(&m1, None, crashing)).expect("v1");
    let v1 = registry.resolve("m", None).expect("resolve v1");
    let rxs: Vec<_> = (0..8)
        .map(|i| v1.server().submit(ds.row(i).to_vec()).expect("admitted"))
        .collect();
    for rx in rxs {
        let resolved = rx.recv_timeout(RESOLVE).expect("stranded request resolves, not hangs");
        assert_eq!(resolved, Err(ServeError::WorkerLost));
    }

    registry
        .publish("m", 2, 4096, InferenceServer::start(&m2, None, swap_config()))
        .expect("publish the replacement");
    for i in 0..8 {
        let r = registry.infer("m", None, ds.row(i).to_vec()).expect("replacement serves");
        assert_eq!(r.fixed, o2.predict_fixed(ds.row(i)), "row {i} from v2");
    }

    let s1 = v1.server().metrics();
    assert_eq!(s1.lost, 8, "every stranded v1 request accounted as lost");
    assert_eq!(s1.requests, s1.responses + s1.expired + s1.lost, "v1 identity under crash");
    let s2 = registry.resolve("m", None).expect("v2").server().metrics();
    assert_eq!(s2.requests, s2.responses + s2.expired + s2.lost, "v2 identity");
    assert_eq!(s2.responses, 8);
}

// ---------------------------------------------------------------------------
// Slab lifecycle under chaos (ISSUE 10): the arena-owned request slab's
// free-list must recover a row on *every* resolution path — served,
// shed, expired, lost — or steady-state serving eventually starves. The
// worker returns a served/expired/lost request's row just after sending
// the reply, so "fully refilled" is asserted with a bounded retry, not
// synchronously.

/// Poll until every slab row is back on the free-list; a leak shows up
/// as a stuck `available()` and fails loudly with the deficit.
fn wait_slab_full(server: &InferenceServer) {
    let total = server.slab().rows();
    let deadline = Instant::now() + RESOLVE;
    while server.slab().available() < total {
        assert!(
            Instant::now() < deadline,
            "slab rows leaked: {} of {total} available",
            server.slab().available()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Slab exhaustion sheds — immediately, without blocking and without
/// admitting — and checked-out rows recover the server completely once
/// returned.
#[test]
fn slab_exhaustion_sheds_never_blocks_and_recovers() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            n_workers: 1,
            faults: no_faults(),
            ..Default::default()
        },
    );
    let total = server.slab().rows();
    // Drain the free-list dry without submitting anything.
    let held: Vec<_> = (0..total).map(|k| {
        server.checkout_row().unwrap_or_else(|| panic!("row {k} of {total} must check out"))
    }).collect();
    // Exhausted: checkout returns None promptly (shed, not a wait)...
    let t0 = Instant::now();
    assert!(server.checkout_row().is_none(), "an exhausted slab must shed");
    assert!(t0.elapsed() < Duration::from_secs(1), "exhaustion must not block");
    let snap = server.metrics();
    assert_eq!(snap.shed, 1, "exhaustion is accounted as shed");
    assert_eq!(snap.requests, 0, "a shed checkout admits nothing");
    // ...and returning the rows restores full service.
    drop(held);
    assert_eq!(server.slab().available(), total, "dropped handles return synchronously");
    let mut slot = ReplySlot::new();
    let mut row = server.checkout_row().expect("recovered slab serves");
    row.copy_from(ds.row(0));
    server.submit_pooled(row, &mut slot).expect("admitted");
    let r = slot.recv().expect("served");
    assert_eq!(r.fixed, oracle.predict_fixed(ds.row(0)));
    slot.recycle(r.fixed);
    wait_slab_full(&server);
    let snap = server.metrics();
    assert_eq!(snap.requests, snap.responses + snap.expired + snap.lost, "identity");
}

/// Expired and crash-stranded pooled requests both return their slab
/// rows, and the accounting identity holds across all three outcomes
/// (served / expired / lost) of the pooled path.
#[test]
fn expired_and_lost_pooled_requests_return_every_slab_row() {
    let (ds, m) = model();
    let oracle = IntEngine::compile(&m);
    let server = InferenceServer::start(
        &m,
        None,
        ServerConfig {
            // Deadline-flushed batches; the first *executed* batch
            // panics (expired-only flushes resolve before execution, so
            // they don't advance the fault plan's batch counter).
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(20) },
            n_workers: 1,
            faults: Some(FaultPlan { panic_batches: vec![1], ..FaultPlan::none() }),
            ..Default::default()
        },
    );
    // Phase 1: a wave with an already-elapsed TTL — all expire at batch
    // formation, each expiry releasing its slab row.
    let mut slots: Vec<ReplySlot> = (0..4).map(|_| ReplySlot::new()).collect();
    for (i, slot) in slots.iter_mut().enumerate() {
        let mut row = server.checkout_row().expect("slab capacity");
        row.copy_from(ds.row(i));
        server
            .submit_pooled_with_ttl(row, slot, Some(Duration::ZERO))
            .expect("zero-TTL requests still admit");
    }
    for slot in &slots {
        assert_eq!(slot.recv(), Err(ServeError::DeadlineExceeded), "zero TTL must expire");
    }
    wait_slab_full(&server);
    // Phase 2: a wave stranded by the scripted worker panic — lost, and
    // the panic-unwound batch still releases every row.
    for (i, slot) in slots.iter_mut().enumerate() {
        let mut row = server.checkout_row().expect("slab capacity after expiry");
        row.copy_from(ds.row(i));
        server.submit_pooled(row, slot).expect("admitted");
    }
    for slot in &slots {
        assert_eq!(slot.recv(), Err(ServeError::WorkerLost), "crashed batch strands as lost");
    }
    wait_slab_full(&server);
    // Phase 3: the restarted worker serves from the fully-recovered slab.
    for (i, slot) in slots.iter_mut().enumerate() {
        let mut row = server.checkout_row().expect("slab capacity after crash");
        row.copy_from(ds.row(i));
        server.submit_pooled(row, slot).expect("admitted");
    }
    for (i, slot) in slots.iter_mut().enumerate() {
        let r = slot.recv().expect("post-restart serve");
        assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i} parity after restart");
        let fixed = r.fixed;
        slot.recycle(fixed);
    }
    wait_slab_full(&server);
    let snap = server.metrics();
    assert_eq!((snap.expired, snap.lost, snap.responses), (4, 4, 4));
    assert_eq!(snap.requests, snap.responses + snap.expired + snap.lost, "identity");
}

/// A hot swap landing mid-way through a pooled flood: the drained v1
/// returns every slab row, keeps the accounting identity, and v2 takes
/// over bit-identically — the swap-drain protocol and the slab
/// free-list compose.
#[test]
fn hot_swap_drain_returns_slab_rows_and_keeps_the_identity() {
    let (ds, m1) = model();
    let m2 = model_v2(&ds);
    let o1 = IntEngine::compile(&m1);
    let o2 = IntEngine::compile(&m2);

    let registry = Arc::new(ModelRegistry::new(Arc::new(Metrics::new())));
    registry
        .publish("m", 1, 4096, InferenceServer::start(&m1, None, swap_config()))
        .expect("publish v1");
    let v1 = registry.resolve("m", None).expect("resolve v1");

    // Pooled flood straight at v1's server handle; swap to v2 half-way.
    let mut slot = ReplySlot::new();
    let n_flood = 120usize;
    for k in 0..n_flood {
        if k == n_flood / 2 {
            registry
                .publish("m", 2, 4096, InferenceServer::start(&m2, None, swap_config()))
                .expect("publish v2 mid-flood");
        }
        let i = k % 50;
        let mut row = v1.server().checkout_row().expect("v1 slab capacity");
        row.copy_from(ds.row(i));
        v1.server().submit_pooled(row, &mut slot).expect("the held v1 handle still admits");
        let r = slot.recv().expect("v1 serves its own admissions across the swap");
        assert_eq!(r.fixed, o1.predict_fixed(ds.row(i)), "row {i} answered by v1's bits");
        let fixed = r.fixed;
        slot.recycle(fixed);
    }
    wait_slab_full(v1.server());
    let s1 = v1.server().metrics();
    assert_eq!(s1.requests, n_flood as u64);
    assert_eq!(s1.requests, s1.responses + s1.expired + s1.lost, "v1 identity across swap");

    // Unpinned registry traffic now serves from v2.
    for i in 0..10 {
        let r = registry.infer("m", None, ds.row(i).to_vec()).expect("v2 serves");
        assert_eq!(r.fixed, o2.predict_fixed(ds.row(i)), "post-swap row {i} from v2");
    }
    drop(v1);
}
