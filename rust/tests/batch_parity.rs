//! Batch-vs-scalar parity suite (ISSUE 1 + 2 + 3 + 5 + 6 acceptance):
//! for every engine variant, both node layouts, **all three kernels**
//! (branchy early-exit, predicated branchless fixed-trip, and the
//! QuickScorer bitvector evaluation), **every available SIMD backend**
//! (scalar, plus AVX2 / NEON where the CPU feature was detected) and
//! **every intra-batch thread count** (1/2/3/8 — see the dedicated
//! threads suite at the bottom), the batch kernel must be
//! **element-wise identical** to
//! the per-row path — including ragged final tiles (batch sizes 1, R−1,
//! R, R+1, and the exhaustive 1..=17 sweep) and a batch large enough to
//! cross many tiles (1000). Probabilities are compared with `assert_eq`
//! on the raw f32s: the invariant is bit-identity, not closeness.
//!
//! The randomized topology suite additionally sweeps hand-built models
//! with trees of depth 0..=16 — single-leaf trees, stumps, a
//! full-depth-16 chain, and random ragged mixtures — plus rows that land
//! *exactly on* split thresholds, the boundary the `<=`-goes-left /
//! `>`-goes-right negation must preserve, and boundary trees at
//! 63/64/65 leaves (the u64-mask QuickScorer eligibility edge).

use intreeger::data::{esa_like, shuttle_like, synth, SynthSpec};
use intreeger::inference::{
    compile_variant_with, Engine, GbtIntEngine, IntEngine, NodeOrder, SimdBackend,
    TraversalKernel, Variant, BACKEND_ENV, THREADS_ENV, TILE_ROWS,
};
use intreeger::ir::{Model, ModelKind, Node, Tree};
use intreeger::trees::{train_gbt, ForestParams, GbtParams, RandomForest};
use intreeger::util::check::{balanced_tree, random_dist};
use intreeger::util::Rng;

/// The sweep of batch sizes exercising empty, sub-tile, exact-tile,
/// tile+1 and many-tile shapes.
fn batch_sizes() -> [usize; 5] {
    [1, TILE_ROWS - 1, TILE_ROWS, TILE_ROWS + 1, 1000]
}

/// Assert batch == scalar bit-identically for a set of flat batches,
/// across variants × layouts × kernels × available SIMD backends, with
/// the integer variant's fixed accumulators included. Engines (and the
/// fixed-point oracle, only needed for the integer variant) compile once
/// per variant × layout, outside the batch/kernel/backend loops.
fn assert_parity(model: &Model, batches: &[&[f32]], tag0: &str) {
    let nf = model.n_features;
    for variant in Variant::all() {
        for order in NodeOrder::all() {
            let mut engine = compile_variant_with(model, variant, order);
            let fixed_oracle = (variant == Variant::IntTreeger)
                .then(|| IntEngine::compile_with(model, order));
            for kernel in TraversalKernel::all() {
                for &backend in SimdBackend::available() {
                    engine.set_kernel(kernel);
                    engine.set_backend(backend);
                    let tag = format!(
                        "{tag0}/{}/{}/{}/{}",
                        variant.name(),
                        order.name(),
                        kernel.name(),
                        backend.name()
                    );
                    for &flat in batches {
                        assert_eq!(flat.len() % nf, 0);
                        let n = flat.len() / nf;
                        let classes = engine.predict_batch(flat);
                        let probas = engine.predict_proba_batch(flat);
                        assert_eq!(classes.len(), n, "{tag}: class count");
                        assert_eq!(probas.len(), n, "{tag}: proba count");
                        for i in 0..n {
                            let row = &flat[i * nf..(i + 1) * nf];
                            assert_eq!(
                                classes[i],
                                engine.predict(row),
                                "{tag}: class row {i} (n={n})"
                            );
                            assert_eq!(
                                probas[i],
                                engine.predict_proba(row),
                                "{tag}: proba row {i} (n={n}) not bit-identical"
                            );
                        }
                        if let Some(oracle) = &fixed_oracle {
                            let fixed = engine
                                .predict_fixed_batch(flat)
                                .expect("integer variant has fixed path");
                            for i in 0..n {
                                let row = &flat[i * nf..(i + 1) * nf];
                                assert_eq!(
                                    fixed[i],
                                    oracle.predict_fixed(row),
                                    "{tag}: fixed row {i} (n={n})"
                                );
                            }
                        } else {
                            assert!(
                                engine.predict_fixed_batch(flat).is_none(),
                                "{tag}: float-accumulating variant must not claim a fixed path"
                            );
                        }
                    }
                }
            }
        }
    }
}

fn rf_parity_on(ds: &intreeger::data::Dataset, n_trees: usize, seed: u64) {
    let model = RandomForest::train(
        ds,
        &ForestParams { n_trees, max_depth: 6, ..Default::default() },
        seed,
    );
    let batches: Vec<&[f32]> = batch_sizes()
        .iter()
        .map(|&n| &ds.features[..n.min(ds.n_rows()) * ds.n_features])
        .collect();
    assert_parity(&model, &batches, "trained");
}

#[test]
fn rf_batch_parity_shuttle() {
    let ds = shuttle_like(1500, 31);
    rf_parity_on(&ds, 10, 31);
}

#[test]
fn rf_batch_parity_esa_wide() {
    let ds = esa_like(1200, 32);
    rf_parity_on(&ds, 6, 32);
}

/// ≥200-feature regression (the seed's 128-feature stack buffer is
/// gone): parity must hold on very wide rows for all variants.
#[test]
fn rf_batch_parity_200_features() {
    let spec = SynthSpec {
        n_rows: 1100,
        n_features: 230,
        n_classes: 4,
        teacher_depth: 6,
        label_noise: 0.04,
        class_prior: vec![0.4, 0.3, 0.2, 0.1],
        range: (-50.0, 50.0),
    };
    let ds = synth::generate(&spec, 33);
    rf_parity_on(&ds, 5, 33);
}

#[test]
fn rf_batch_parity_across_model_seeds() {
    // Several random models on the same data: the invariant is about the
    // kernel, not one lucky forest.
    let ds = shuttle_like(1024, 34);
    for seed in [1u64, 2, 3] {
        rf_parity_on(&ds, 4 + seed as usize * 3, seed);
    }
}

// ---------------------------------------------------------------------------
// Randomized tree-topology suite (hand-built IR models).

/// Random tree with maximum depth `max_depth` (pre-order IR layout;
/// interior nodes become leaves early with probability ~0.3, so trees
/// are ragged).
fn random_tree(rng: &mut Rng, max_depth: usize, nf: usize, nc: usize) -> Tree {
    fn build(nodes: &mut Vec<Node>, rng: &mut Rng, depth_left: usize, nf: usize, nc: usize) -> u32 {
        let idx = nodes.len() as u32;
        if depth_left == 0 || rng.chance(0.3) {
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
        } else {
            nodes.push(Node::Branch {
                feature: rng.below(nf) as u32,
                threshold: rng.uniform_in(-50.0, 50.0),
                left: 0,
                right: 0,
            });
            let l = build(nodes, rng, depth_left - 1, nf, nc);
            let r = build(nodes, rng, depth_left - 1, nf, nc);
            if let Node::Branch { left, right, .. } = &mut nodes[idx as usize] {
                *left = l;
                *right = r;
            }
        }
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, rng, max_depth, nf, nc);
    Tree { nodes }
}

/// A maximally-ragged chain of exactly `depth` branches: each branch has
/// one leaf child and one deeper child, alternating sides — one lane
/// exits at depth 1 while another runs the full trip, the worst case for
/// the branchless kernel's self-loop parking.
fn chain_tree(rng: &mut Rng, depth: usize, nf: usize, nc: usize) -> Tree {
    fn build(nodes: &mut Vec<Node>, rng: &mut Rng, depth_left: usize, nf: usize, nc: usize) -> u32 {
        let idx = nodes.len() as u32;
        if depth_left == 0 {
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            return idx;
        }
        nodes.push(Node::Branch {
            feature: rng.below(nf) as u32,
            threshold: rng.uniform_in(-20.0, 20.0),
            left: 0,
            right: 0,
        });
        // Alternate which side continues the chain.
        let deep_left = depth_left % 2 == 0;
        let (l, r) = if deep_left {
            let l = build(nodes, rng, depth_left - 1, nf, nc);
            let leaf = nodes.len() as u32;
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            (l, leaf)
        } else {
            let leaf = nodes.len() as u32;
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            let r = build(nodes, rng, depth_left - 1, nf, nc);
            (leaf, r)
        };
        if let Node::Branch { left, right, .. } = &mut nodes[idx as usize] {
            *left = l;
            *right = r;
        }
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, rng, depth, nf, nc);
    Tree { nodes }
}

/// Rows for a hand-built model: random values plus rows that hit split
/// thresholds exactly (the `<=` boundary), plus NaN rows — NaN is out of
/// the engines' data contract, but every kernel and backend must still
/// route it identically to its own per-row path (the literal `!(x <= t)`
/// negation the walkers, the SIMD compares and the generated C share).
fn probe_rows(rng: &mut Rng, model: &Model, n_rows: usize) -> Vec<f32> {
    let nf = model.n_features;
    let thresholds: Vec<(u32, f32)> = model
        .trees
        .iter()
        .flat_map(|t| &t.nodes)
        .filter_map(|n| match n {
            Node::Branch { feature, threshold, .. } => Some((*feature, *threshold)),
            _ => None,
        })
        .collect();
    let mut rows = Vec::with_capacity(n_rows * nf);
    for i in 0..n_rows {
        let mut row: Vec<f32> = (0..nf).map(|_| rng.uniform_in(-80.0, 80.0)).collect();
        // Every third row lands exactly on some threshold.
        if i % 3 == 0 && !thresholds.is_empty() {
            let (f, t) = thresholds[rng.below(thresholds.len())];
            row[f as usize] = t;
        }
        // Every seventh row carries a NaN (alternating sign bit — the
        // ordered-u32 transform maps the two differently, and both must
        // stay batch-vs-scalar consistent).
        if i % 7 == 1 {
            let f = rng.below(nf);
            row[f] = if i % 14 == 1 { f32::NAN } else { -f32::NAN };
        }
        rows.extend_from_slice(&row);
    }
    rows
}

/// Depth 0..=16 topology sweep: single-leaf trees, stumps, a depth-16
/// chain, and random ragged trees, mixed into one forest so tree depths
/// inside a single model are maximally uneven. Branchless must equal
/// branchy must equal per-row scalar, bit for bit.
#[test]
fn randomized_topology_parity_depth_0_to_16() {
    let nf = 5usize;
    let nc = 3usize;
    for seed in [7u64, 8, 9] {
        let mut rng = Rng::new(seed);
        let mut trees = vec![
            // depth 0: a single-leaf tree (the fixed trip count is 0).
            Tree { nodes: vec![Node::Leaf { values: random_dist(&mut rng, nc) }] },
            // depth 1: a stump.
            random_tree(&mut rng, 1, nf, nc),
            // depth 16: the full ragged chain.
            chain_tree(&mut rng, 16, nf, nc),
        ];
        for max_depth in [2usize, 3, 5, 8, 12, 16] {
            trees.push(random_tree(&mut rng, max_depth, nf, nc));
        }
        let model = Model {
            kind: ModelKind::RandomForest,
            n_features: nf,
            n_classes: nc,
            trees,
            base_score: vec![0.0; nc],
        };
        model.validate().expect("hand-built model must validate");
        assert!(model.max_depth() == 16, "chain tree must set the depth");
        let row_sets: Vec<Vec<f32>> = [1usize, TILE_ROWS, TILE_ROWS + 3, 61]
            .iter()
            .map(|&n| probe_rows(&mut rng, &model, n))
            .collect();
        let batches: Vec<&[f32]> = row_sets.iter().map(|r| r.as_slice()).collect();
        assert_parity(&model, &batches, &format!("topo{seed}"));
    }
}

/// A forest of only single-leaf trees (every fixed trip count is 0) and
/// only stumps — the degenerate extremes.
#[test]
fn degenerate_forests_parity() {
    let nc = 2usize;
    let mut rng = Rng::new(99);
    let leaf_only = Model {
        kind: ModelKind::RandomForest,
        n_features: 1,
        n_classes: nc,
        trees: (0..5)
            .map(|_| Tree { nodes: vec![Node::Leaf { values: random_dist(&mut rng, nc) }] })
            .collect(),
        base_score: vec![0.0; nc],
    };
    leaf_only.validate().unwrap();
    let rows = probe_rows(&mut rng, &leaf_only, 17);
    assert_parity(&leaf_only, &[rows.as_slice()], "leaf-only");

    let stumps = Model {
        kind: ModelKind::RandomForest,
        n_features: 2,
        n_classes: nc,
        trees: (0..6).map(|_| random_tree(&mut rng, 1, 2, nc)).collect(),
        base_score: vec![0.0; nc],
    };
    stumps.validate().unwrap();
    let rows = probe_rows(&mut rng, &stumps, 33);
    assert_parity(&stumps, &[rows.as_slice()], "stumps");
}

/// The u64-mask eligibility edge: one forest mixing trees of exactly 63,
/// 64 and 65 leaves (the last falls back to the walker inside the
/// QuickScorer driver) — classes, raw f32 probas and fixed accumulators
/// must stay bit-identical to the scalar walkers for every variant ×
/// layout × kernel, at ragged and tile-aligned batch sizes.
#[test]
fn qs_eligibility_boundary_63_64_65_leaves() {
    let nf = 6usize;
    let nc = 3usize;
    for seed in [21u64, 22] {
        let mut rng = Rng::new(seed);
        let model = Model {
            kind: ModelKind::RandomForest,
            n_features: nf,
            n_classes: nc,
            trees: vec![
                balanced_tree(&mut rng, 63, nf, nc),
                balanced_tree(&mut rng, 64, nf, nc),
                balanced_tree(&mut rng, 65, nf, nc),
                balanced_tree(&mut rng, 1, nf, nc),
            ],
            base_score: vec![0.0; nc],
        };
        model.validate().expect("hand-built boundary model must validate");
        let row_sets: Vec<Vec<f32>> = [1usize, TILE_ROWS, TILE_ROWS + 5, 41]
            .iter()
            .map(|&n| probe_rows(&mut rng, &model, n))
            .collect();
        let batches: Vec<&[f32]> = row_sets.iter().map(|r| r.as_slice()).collect();
        assert_parity(&model, &batches, &format!("qs-boundary{seed}"));
    }
}

/// Ragged-tail acceptance (satellite): every batch size 1..=17 — all
/// tail widths around one and two full tiles — must be element-wise
/// identical to the scalar path for every variant × layout × kernel.
/// Before the duplicated-lane tail fix, tails silently took the branchy
/// walker; this pins the whole batch to the selected kernel.
#[test]
fn ragged_tail_parity_sizes_1_to_17() {
    let ds = shuttle_like(600, 38);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 7, max_depth: 6, ..Default::default() },
        38,
    );
    let batches: Vec<&[f32]> =
        (1..=17).map(|n| &ds.features[..n * ds.n_features]).collect();
    assert_parity(&model, &batches, "tail");
}

#[test]
fn gbt_batch_parity_all_kernels_and_backends() {
    let ds = shuttle_like(1500, 35);
    let model =
        train_gbt(&ds, &GbtParams { n_rounds: 5, max_depth: 4, ..Default::default() }, 35);
    let mut engine = GbtIntEngine::compile(&model);
    for kernel in TraversalKernel::all() {
        engine.set_kernel(kernel);
        for &backend in SimdBackend::available() {
            engine.set_backend(backend);
            let tag = format!("{}/{}", kernel.name(), backend.name());
            for n in batch_sizes() {
                let n = n.min(ds.n_rows());
                let flat = &ds.features[..n * ds.n_features];
                let margins = engine.predict_fixed_batch(flat);
                let classes = engine.predict_batch(flat);
                for i in 0..n {
                    assert_eq!(
                        margins[i],
                        engine.predict_fixed(ds.row(i)),
                        "{tag} gbt margins row {i} (n={n})"
                    );
                    assert_eq!(
                        classes[i],
                        engine.predict(ds.row(i)),
                        "{tag} gbt class row {i} (n={n})"
                    );
                }
            }
        }
    }
}

/// The override env actually pins the backend: with
/// `INTREEGER_BACKEND=scalar` every engine compiled in the process gets
/// the Scalar backend (even on AVX2/NEON hosts) and calibration sweeps
/// collapse to that single candidate.
#[test]
fn backend_env_override_pins_scalar() {
    // Restore (not remove) afterwards: the forced-scalar CI leg sets
    // this variable for the whole test binary, and unconditionally
    // deleting it would un-pin every test that starts after this one.
    let prior = std::env::var(BACKEND_ENV).ok();
    std::env::set_var(BACKEND_ENV, "scalar");
    let ds = shuttle_like(300, 39);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        39,
    );
    let engine = compile_variant_with(&model, Variant::IntTreeger, NodeOrder::Depth);
    let pinned = engine.backend();
    let resolved = SimdBackend::resolve();
    let sweep = SimdBackend::sweep();
    match prior {
        Some(v) => std::env::set_var(BACKEND_ENV, v),
        None => std::env::remove_var(BACKEND_ENV),
    }
    assert_eq!(pinned, SimdBackend::Scalar, "engine default must honor the override");
    assert_eq!(resolved, SimdBackend::Scalar);
    assert_eq!(sweep, vec![SimdBackend::Scalar], "calibration sweep must collapse");
    // And the pinned engine still answers correctly.
    let flat = &ds.features[..16 * ds.n_features];
    let classes = engine.predict_batch(flat);
    for (i, &c) in classes.iter().enumerate() {
        assert_eq!(c, engine.predict(ds.row(i)), "row {i}");
    }
}

#[test]
fn layouts_agree_batched_and_scalar() {
    // Depth- and breadth-ordered forests must agree with each other in
    // both execution styles (layout is a pure performance knob).
    let ds = shuttle_like(800, 36);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 8, max_depth: 6, ..Default::default() },
        36,
    );
    for variant in Variant::all() {
        let depth = compile_variant_with(&model, variant, NodeOrder::Depth);
        let breadth = compile_variant_with(&model, variant, NodeOrder::Breadth);
        let flat = &ds.features[..200 * ds.n_features];
        assert_eq!(depth.predict_batch(flat), breadth.predict_batch(flat), "{}", variant.name());
        for i in 0..50 {
            assert_eq!(
                depth.predict_proba(ds.row(i)),
                breadth.predict_proba(ds.row(i)),
                "{} row {i}",
                variant.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Intra-batch threads dimension (ISSUE 6 acceptance): the thread count is
// a pure performance knob — bit-identical at every count.

/// For every node order × kernel × available SIMD backend, running the
/// batch accumulation with 2, 3 or 8 intra-batch threads must be
/// **bit-identical** to the single-thread result — raw f32 probabilities
/// (float and FlInt) and `u32` fixed accumulators — at every ragged
/// batch size 1..=17 and at a many-tile 4096-row batch, on rows that
/// include exact-threshold and NaN probes. Drives the public `*_exec`
/// funnels directly (the task scheduler caps at the task count, so the
/// parallel split runs even on single-core hosts where the engine-level
/// `set_threads` would clamp the request away).
#[test]
fn threads_parity_bit_identical_across_counts() {
    use intreeger::inference::batch::{
        float_proba_batch_exec, flint_proba_batch_exec, int_fixed_batch_exec,
    };
    use intreeger::inference::CompiledForest;

    let ds = shuttle_like(600, 40);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 9, max_depth: 6, ..Default::default() },
        40,
    );
    let mut rng = Rng::new(40);
    let mut row_sets: Vec<Vec<f32>> =
        (1..=17).map(|n| probe_rows(&mut rng, &model, n)).collect();
    row_sets.push(probe_rows(&mut rng, &model, 4096));
    for order in NodeOrder::all() {
        let f = CompiledForest::compile_with(&model, order);
        for kernel in TraversalKernel::all() {
            for &backend in SimdBackend::available() {
                for rows in &row_sets {
                    let n = rows.len() / model.n_features;
                    let float1 = float_proba_batch_exec(&f, rows, kernel, backend, 1);
                    let flint1 = flint_proba_batch_exec(&f, rows, kernel, backend, 1);
                    let int1 = int_fixed_batch_exec(&f, rows, kernel, backend, 1);
                    for threads in [2usize, 3, 8] {
                        let tag = format!(
                            "{}/{}/{}/{threads}t n={n}",
                            order.name(),
                            kernel.name(),
                            backend.name()
                        );
                        assert_eq!(
                            float1,
                            float_proba_batch_exec(&f, rows, kernel, backend, threads),
                            "{tag}: float probas not bit-identical"
                        );
                        assert_eq!(
                            flint1,
                            flint_proba_batch_exec(&f, rows, kernel, backend, threads),
                            "{tag}: flint probas not bit-identical"
                        );
                        assert_eq!(
                            int1,
                            int_fixed_batch_exec(&f, rows, kernel, backend, threads),
                            "{tag}: fixed accumulators not bit-identical"
                        );
                    }
                }
            }
        }
    }
}

/// The engine-level threads knob composes with kernels: `set_threads`
/// (clamped to this host's cores, so the larger counts only bite on
/// multi-core CI legs) must leave classes and probabilities bit-identical
/// to the per-row path for every variant, and GBT margins must apply the
/// pre-seeded base score exactly once at any count.
#[test]
fn engine_set_threads_is_a_pure_performance_knob() {
    let ds = shuttle_like(900, 41);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() },
        41,
    );
    let n = 137usize;
    let flat = &ds.features[..n * ds.n_features];
    for variant in Variant::all() {
        let mut engine = compile_variant_with(&model, variant, NodeOrder::Depth);
        for kernel in TraversalKernel::all() {
            engine.set_kernel(kernel);
            for threads in [1usize, 2, 3, 8] {
                engine.set_threads(threads);
                let tag = format!("{}/{}/{threads}t", variant.name(), kernel.name());
                let classes = engine.predict_batch(flat);
                let probas = engine.predict_proba_batch(flat);
                for i in 0..n {
                    assert_eq!(classes[i], engine.predict(ds.row(i)), "{tag}: class row {i}");
                    assert_eq!(
                        probas[i],
                        engine.predict_proba(ds.row(i)),
                        "{tag}: proba row {i} not bit-identical"
                    );
                }
            }
        }
    }
    let gbt = train_gbt(&ds, &GbtParams { n_rounds: 4, max_depth: 4, ..Default::default() }, 41);
    let mut e = GbtIntEngine::compile(&gbt);
    for threads in [1usize, 2, 3, 8] {
        e.set_threads(threads);
        let margins = e.predict_fixed_batch(flat);
        for i in 0..n {
            assert_eq!(margins[i], e.predict_fixed(ds.row(i)), "gbt {threads}t margin row {i}");
        }
    }
}

/// The override env actually pins the thread count: with
/// `INTREEGER_THREADS=1` every engine compiled in the process defaults
/// to single-thread execution and the calibration sweep collapses to
/// that single candidate (mirrors `backend_env_override_pins_scalar`).
#[test]
fn threads_env_override_pins_single_thread() {
    // Restore (not remove) afterwards: the pinned-threads CI legs set
    // this variable for the whole test binary, and unconditionally
    // deleting it would un-pin every test that starts after this one.
    let prior = std::env::var(THREADS_ENV).ok();
    std::env::set_var(THREADS_ENV, "1");
    let ds = shuttle_like(300, 42);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        42,
    );
    let engine = compile_variant_with(&model, Variant::IntTreeger, NodeOrder::Depth);
    let pinned = engine.threads();
    let resolved = intreeger::inference::parallel::resolve();
    let sweep = intreeger::inference::parallel::sweep();
    match prior {
        Some(v) => std::env::set_var(THREADS_ENV, v),
        None => std::env::remove_var(THREADS_ENV),
    }
    assert_eq!(pinned, 1, "engine default must honor the override");
    assert_eq!(resolved, 1);
    assert_eq!(sweep, vec![1], "calibration sweep must collapse");
    // And the pinned engine still answers correctly.
    let flat = &ds.features[..16 * ds.n_features];
    let classes = engine.predict_batch(flat);
    for (i, &c) in classes.iter().enumerate() {
        assert_eq!(c, engine.predict(ds.row(i)), "row {i}");
    }
}

#[test]
fn empty_batch_is_empty() {
    let ds = shuttle_like(300, 37);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        37,
    );
    for variant in Variant::all() {
        let engine = compile_variant_with(&model, variant, NodeOrder::Depth);
        assert!(engine.predict_batch(&[]).is_empty());
        assert!(engine.predict_proba_batch(&[]).is_empty());
    }
}
