//! Batch-vs-scalar parity suite (ISSUE 1 acceptance): for every engine
//! variant and both node layouts, the tiled batch kernel must be
//! **element-wise identical** to the per-row path — including ragged
//! final tiles (batch sizes 1, R−1, R, R+1) and a batch large enough to
//! cross many tiles (1000). Probabilities are compared with `assert_eq`
//! on the raw f32s: the invariant is bit-identity, not closeness.

use intreeger::data::{esa_like, shuttle_like, synth, SynthSpec};
use intreeger::inference::{
    compile_variant_with, Engine, GbtIntEngine, IntEngine, NodeOrder, Variant, TILE_ROWS,
};
use intreeger::trees::{train_gbt, ForestParams, GbtParams, RandomForest};

/// The sweep of batch sizes exercising empty, sub-tile, exact-tile,
/// tile+1 and many-tile shapes.
fn batch_sizes() -> [usize; 5] {
    [1, TILE_ROWS - 1, TILE_ROWS, TILE_ROWS + 1, 1000]
}

fn rf_parity_on(ds: &intreeger::data::Dataset, n_trees: usize, seed: u64) {
    let model = RandomForest::train(
        ds,
        &ForestParams { n_trees, max_depth: 6, ..Default::default() },
        seed,
    );
    for variant in Variant::all() {
        for order in NodeOrder::all() {
            let engine = compile_variant_with(&model, variant, order);
            let tag = format!("{}/{}", variant.name(), order.name());
            for n in batch_sizes() {
                let n = n.min(ds.n_rows());
                let flat = &ds.features[..n * ds.n_features];
                let classes = engine.predict_batch(flat);
                let probas = engine.predict_proba_batch(flat);
                assert_eq!(classes.len(), n, "{tag}: class count");
                assert_eq!(probas.len(), n, "{tag}: proba count");
                for i in 0..n {
                    let row = ds.row(i);
                    assert_eq!(classes[i], engine.predict(row), "{tag}: class row {i} (n={n})");
                    assert_eq!(
                        probas[i],
                        engine.predict_proba(row),
                        "{tag}: proba row {i} (n={n}) not bit-identical"
                    );
                }
                if variant == Variant::IntTreeger {
                    let fixed =
                        engine.predict_fixed_batch(flat).expect("integer variant has fixed path");
                    let oracle = IntEngine::compile_with(&model, order);
                    for i in 0..n {
                        assert_eq!(
                            fixed[i],
                            oracle.predict_fixed(ds.row(i)),
                            "{tag}: fixed row {i} (n={n})"
                        );
                    }
                } else {
                    assert!(
                        engine.predict_fixed_batch(flat).is_none(),
                        "{tag}: float-accumulating variant must not claim a fixed path"
                    );
                }
            }
        }
    }
}

#[test]
fn rf_batch_parity_shuttle() {
    let ds = shuttle_like(1500, 31);
    rf_parity_on(&ds, 10, 31);
}

#[test]
fn rf_batch_parity_esa_wide() {
    let ds = esa_like(1200, 32);
    rf_parity_on(&ds, 6, 32);
}

/// ≥200-feature regression (the seed's 128-feature stack buffer is
/// gone): parity must hold on very wide rows for all variants.
#[test]
fn rf_batch_parity_200_features() {
    let spec = SynthSpec {
        n_rows: 1100,
        n_features: 230,
        n_classes: 4,
        teacher_depth: 6,
        label_noise: 0.04,
        class_prior: vec![0.4, 0.3, 0.2, 0.1],
        range: (-50.0, 50.0),
    };
    let ds = synth::generate(&spec, 33);
    rf_parity_on(&ds, 5, 33);
}

#[test]
fn rf_batch_parity_across_model_seeds() {
    // Several random models on the same data: the invariant is about the
    // kernel, not one lucky forest.
    let ds = shuttle_like(1024, 34);
    for seed in [1u64, 2, 3] {
        rf_parity_on(&ds, 4 + seed as usize * 3, seed);
    }
}

#[test]
fn gbt_batch_parity() {
    let ds = shuttle_like(1500, 35);
    let model =
        train_gbt(&ds, &GbtParams { n_rounds: 5, max_depth: 4, ..Default::default() }, 35);
    let engine = GbtIntEngine::compile(&model);
    for n in batch_sizes() {
        let n = n.min(ds.n_rows());
        let flat = &ds.features[..n * ds.n_features];
        let margins = engine.predict_fixed_batch(flat);
        let classes = engine.predict_batch(flat);
        for i in 0..n {
            assert_eq!(margins[i], engine.predict_fixed(ds.row(i)), "gbt margins row {i} (n={n})");
            assert_eq!(classes[i], engine.predict(ds.row(i)), "gbt class row {i} (n={n})");
        }
    }
}

#[test]
fn layouts_agree_batched_and_scalar() {
    // Depth- and breadth-ordered forests must agree with each other in
    // both execution styles (layout is a pure performance knob).
    let ds = shuttle_like(800, 36);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 8, max_depth: 6, ..Default::default() },
        36,
    );
    for variant in Variant::all() {
        let depth = compile_variant_with(&model, variant, NodeOrder::Depth);
        let breadth = compile_variant_with(&model, variant, NodeOrder::Breadth);
        let flat = &ds.features[..200 * ds.n_features];
        assert_eq!(depth.predict_batch(flat), breadth.predict_batch(flat), "{}", variant.name());
        for i in 0..50 {
            assert_eq!(
                depth.predict_proba(ds.row(i)),
                breadth.predict_proba(ds.row(i)),
                "{} row {i}",
                variant.name()
            );
        }
    }
}

#[test]
fn empty_batch_is_empty() {
    let ds = shuttle_like(300, 37);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        37,
    );
    for variant in Variant::all() {
        let engine = compile_variant_with(&model, variant, NodeOrder::Depth);
        assert!(engine.predict_batch(&[]).is_empty());
        assert!(engine.predict_proba_batch(&[]).is_empty());
    }
}
