//! Integration: the AOT-compiled XLA/Pallas path vs the scalar engines —
//! across datasets, model sizes and batch shapes, everything must be
//! bit-identical (E9).
//!
//! Requires `make artifacts`; tests skip (with a note) when absent.

use intreeger::data::{esa_like, shuttle_like, Dataset};
use intreeger::inference::IntEngine;
use intreeger::runtime::{artifacts_available, engine_for_model, Manifest};
use intreeger::trees::{ForestParams, RandomForest};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("artifacts not built (make artifacts) — skipping");
        None
    }
}

fn check_parity(dir: &std::path::Path, ds: &Dataset, n_trees: usize, depth: usize, seed: u64) {
    let model = RandomForest::train(
        ds,
        &ForestParams { n_trees, max_depth: depth, ..Default::default() },
        seed,
    );
    let xla = engine_for_model(dir, &model, 1).expect("engine");
    let scalar = IntEngine::compile(&model);
    let b = xla.max_batch().min(ds.n_rows());
    let rows = &ds.features[..b * ds.n_features];
    let got = xla.execute(rows, ds.n_features).expect("execute");
    for (i, fixed) in got.iter().enumerate() {
        assert_eq!(fixed, &scalar.predict_fixed(ds.row(i)), "row {i} (trees={n_trees})");
    }
}

#[test]
fn parity_shuttle_sizes() {
    let Some(dir) = artifacts() else { return };
    let ds = shuttle_like(1_500, 301);
    for (n_trees, depth) in [(1usize, 3usize), (10, 6), (50, 7)] {
        check_parity(&dir, &ds, n_trees, depth, 301 + n_trees as u64);
    }
}

#[test]
fn parity_esa() {
    let Some(dir) = artifacts() else { return };
    let ds = esa_like(1_200, 302);
    check_parity(&dir, &ds, 10, 6, 99);
}

#[test]
fn parity_many_random_batches() {
    let Some(dir) = artifacts() else { return };
    let ds = shuttle_like(4_000, 303);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 12, max_depth: 6, ..Default::default() },
        11,
    );
    let xla = engine_for_model(&dir, &model, 1).expect("engine");
    let scalar = IntEngine::compile(&model);
    // sweep partial batch sizes incl. 1 and max
    for b in [1usize, 2, 7, 33, xla.max_batch()] {
        let b = b.min(xla.max_batch());
        let offset = b * 13 % (ds.n_rows() - xla.max_batch());
        let rows = &ds.features[offset * 7..(offset + b) * 7];
        let got = xla.execute(rows, 7).expect("execute");
        assert_eq!(got.len(), b);
        for (i, fixed) in got.iter().enumerate() {
            assert_eq!(fixed, &scalar.predict_fixed(ds.row(offset + i)), "b={b} row {i}");
        }
    }
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    // The quick tier exists in both pallas and pure-jnp lowering; both
    // must produce identical results for the same packed model.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let Some(jnp_tier) = manifest.tiers.iter().find(|t| t.name == "quick_jnp") else {
        eprintln!("quick_jnp tier missing — skipping");
        return;
    };
    let ds = shuttle_like(800, 304);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
        21,
    );
    let pack = intreeger::runtime::ForestPack::pack(&model, jnp_tier).expect("pack");
    let jnp = intreeger::runtime::PjrtEngine::load(&dir, jnp_tier.clone(), pack).expect("jnp");
    let pallas = engine_for_model(&dir, &model, 1).expect("pallas");
    assert!(pallas.tier().use_pallas);
    let b = jnp.max_batch().min(pallas.max_batch());
    let rows = &ds.features[..b * 7];
    assert_eq!(
        jnp.execute(rows, 7).expect("jnp exec"),
        pallas.execute(rows, 7).expect("pallas exec"),
        "pallas vs jnp artifact disagreement"
    );
}
