//! Golden end-to-end pipeline test: one `pipeline::run` on a small
//! synthetic dataset pins (a) the `report.json` schema — key sets at
//! every level — and (b) the float-vs-integer parity verdict, plus one
//! deliberately overflow-adjacent `n_trees` case exercising the quant
//! clamp documented in `quant/mod.rs`.

use intreeger::data::synth::{generate, SynthSpec};
use intreeger::data::Dataset;
use intreeger::ir::{Model, ModelKind, Node, Tree};
use intreeger::pipeline::{self, verify, PipelineConfig};
use intreeger::quant;
use intreeger::util::Json;
use std::path::PathBuf;

fn outdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("intreeger_golden_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Small synthetic dataset with a rare class (stratification matters).
fn small_synth() -> Dataset {
    generate(
        &SynthSpec {
            n_rows: 500,
            n_features: 5,
            n_classes: 3,
            teacher_depth: 4,
            label_noise: 0.03,
            class_prior: vec![0.7, 0.2, 0.1],
            range: (-10.0, 10.0),
        },
        0xC0FFEE,
    )
}

fn obj_keys(v: &Json) -> Vec<String> {
    match v {
        Json::Obj(m) => m.keys().cloned().collect(),
        other => panic!("expected object, got {other:?}"),
    }
}

#[test]
fn golden_report_schema_and_parity_verdict() {
    let ds = small_synth();
    let out = outdir("schema");
    let cfg = PipelineConfig {
        n_trees: 5,
        max_depth: 4,
        train_gbt: true,
        bench: true,
        simulate: true,
        seed: 7,
        source: "synthetic:golden".to_string(),
        ..Default::default()
    };
    let outcome = pipeline::run(&ds, &out, &cfg).expect("pipeline run");
    assert!(outcome.report.all_verified(), "parity verdict must pass");

    // --- report.json parses and the schema is pinned ------------------
    let text = std::fs::read_to_string(out.join("report.json")).unwrap();
    let v = Json::parse(&text).expect("report.json parses");
    assert_eq!(
        obj_keys(&v),
        ["dataset", "execution", "format", "models", "seed", "verified"],
        "top-level schema drifted"
    );
    assert_eq!(v.get("format").and_then(Json::as_str), Some(pipeline::REPORT_FORMAT));
    assert_eq!(v.get("verified"), Some(&Json::Bool(true)));
    assert_eq!(v.get("seed").and_then(Json::as_usize), Some(7));

    assert_eq!(
        obj_keys(v.get("dataset").unwrap()),
        ["classes", "features", "holdout_rows", "rows", "source", "train_rows"],
        "dataset schema drifted"
    );
    // The additive execution object: configured kernel, resolved SIMD
    // backend, resolved thread count, and host features (values are
    // host-dependent; the schema and executability are not).
    let exec = v.get("execution").unwrap();
    assert_eq!(
        obj_keys(exec),
        ["backend", "detected_features", "kernel", "threads"],
        "execution schema drifted"
    );
    assert_eq!(exec.get("kernel").and_then(Json::as_str), Some("branchless"));
    let backend = exec.get("backend").and_then(Json::as_str).unwrap();
    let backend = intreeger::inference::SimdBackend::from_name(backend)
        .unwrap_or_else(|| panic!("unknown backend '{backend}' in report"));
    assert!(backend.is_available(), "reported backend must be executable on this host");
    let threads = exec.get("threads").and_then(Json::as_usize).unwrap();
    assert!(
        (1..=intreeger::inference::parallel::detected()).contains(&threads),
        "reported thread count must be runnable on this host"
    );
    assert!(exec.get("detected_features").and_then(Json::as_arr).is_some());

    let d = v.get("dataset").unwrap();
    assert_eq!(d.get("rows").and_then(Json::as_usize), Some(500));
    assert_eq!(d.get("features").and_then(Json::as_usize), Some(5));
    let train = d.get("train_rows").and_then(Json::as_usize).unwrap();
    let hold = d.get("holdout_rows").and_then(Json::as_usize).unwrap();
    assert_eq!(train + hold, 500);
    assert!(hold > 100 && hold < 150, "~25% stratified holdout, got {hold}");

    let models = v.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(models.len(), 2, "rf + gbt");
    for m in models {
        assert_eq!(
            obj_keys(m),
            [
                "accuracy", "bench", "codegen", "kind", "model_file", "params", "parity",
                "quant", "simarch", "stats"
            ],
            "model schema drifted"
        );
        let p = m.get("parity").unwrap();
        assert_eq!(
            obj_keys(p),
            [
                "argmax_identical",
                "engines",
                "error_bound",
                "kernels",
                "max_abs_error",
                "mismatches",
                "per_class_max_error",
                "rows",
                "within_bound"
            ],
            "parity schema drifted"
        );
        // The machine-checked verdict itself.
        assert_eq!(p.get("argmax_identical"), Some(&Json::Bool(true)));
        assert_eq!(p.get("within_bound"), Some(&Json::Bool(true)));
        assert_eq!(p.get("mismatches").and_then(Json::as_usize), Some(0));
        assert_eq!(p.get("rows").and_then(Json::as_usize), Some(hold));
        // All three kernels swept.
        let kernels: Vec<&str> =
            p.get("kernels").and_then(Json::as_arr).unwrap().iter().filter_map(Json::as_str).collect();
        assert_eq!(kernels, ["branchy", "branchless", "quickscorer"]);
        let err = p.get("max_abs_error").and_then(Json::as_f64).unwrap();
        let bound = p.get("error_bound").and_then(Json::as_f64).unwrap();
        assert!(err <= bound, "err {err} > bound {bound}");
        // Bench rows (one per kernel) and simarch (RF only: 4 cores x 3
        // variants; GBT skips simulation).
        assert_eq!(m.get("bench").and_then(Json::as_arr).unwrap().len(), 3);
    }
    let rf = &models[0];
    assert_eq!(rf.get("kind").and_then(Json::as_str), Some("rf"));
    assert_eq!(rf.get("simarch").and_then(Json::as_arr).unwrap().len(), 12);
    let rf_quant = rf.get("quant").unwrap();
    assert_eq!(obj_keys(rf_quant), ["beats_f32", "error_bound", "scale_factor", "scheme"]);
    assert_eq!(rf_quant.get("scheme").and_then(Json::as_str), Some("prob-u32"));
    // 5 trees: scale 2^32/5, bound 5/2^32, well inside f32 territory.
    assert_eq!(rf_quant.get("beats_f32"), Some(&Json::Bool(true)));
    let cg = rf.get("codegen").unwrap();
    assert_eq!(obj_keys(cg), ["bytes", "file", "gcc_checked", "layout", "variant"]);
    assert_eq!(cg.get("variant").and_then(Json::as_str), Some("intreeger"));

    let gbt = &models[1];
    assert_eq!(gbt.get("kind").and_then(Json::as_str), Some("gbt"));
    assert_eq!(gbt.get("codegen"), Some(&Json::Null));
    assert_eq!(gbt.get("simarch").and_then(Json::as_arr).unwrap().len(), 0);
    assert_eq!(
        obj_keys(gbt.get("quant").unwrap()),
        ["scheme", "shift"],
        "gbt quant schema drifted"
    );

    // --- the generated C is integer-only ------------------------------
    let c = std::fs::read_to_string(out.join("model_rf.c")).unwrap();
    assert!(
        c.contains("void predict(const float *data, uint32_t *result)"),
        "integer-only entry point expected"
    );
    // No float probability average anywhere on the inference path (the
    // float/flint variants divide by (float)N_TREES; intreeger must not).
    assert!(!c.contains("/= (float)"), "float accumulation leaked into the integer-only C");

    // --- REPORT.md verdict --------------------------------------------
    let md = std::fs::read_to_string(out.join("REPORT.md")).unwrap();
    assert!(md.contains("overall verdict: **PASS**"));
    assert!(md.contains("Parity verdict: PASS"));
}

/// Determinism: same dataset + config => byte-identical report.json.
#[test]
fn golden_report_is_deterministic() {
    let ds = small_synth();
    let (o1, o2) = (outdir("det1"), outdir("det2"));
    // bench timings are non-deterministic by nature — keep them off here.
    let cfg = PipelineConfig { n_trees: 3, max_depth: 3, bench: false, ..Default::default() };
    pipeline::run(&ds, &o1, &cfg).unwrap();
    pipeline::run(&ds, &o2, &cfg).unwrap();
    let a = std::fs::read_to_string(o1.join("report.json")).unwrap();
    let b = std::fs::read_to_string(o2.join("report.json")).unwrap();
    assert_eq!(a, b, "report.json must be bit-reproducible from the seed");
    assert_eq!(
        std::fs::read_to_string(o1.join("model_rf.c")).unwrap(),
        std::fs::read_to_string(o2.join("model_rf.c")).unwrap()
    );
}

/// The overflow-adjacent case the paper glosses over: `n` trees with
/// `n | 2^32` and saturated `p = 1.0` leaves. Without the clamp in
/// `quant::prob_to_fixed`, four such trees would sum to exactly 2^32
/// and wrap a `u32` accumulator to 0, catastrophically mis-ranking the
/// class; with it, the sum parks at `2^32 - 4` and the parity harness
/// must still return a clean PASS.
#[test]
fn overflow_adjacent_trees_exercise_quant_clamp() {
    let n_trees = 4usize; // divides 2^32 exactly
    let cap = u32::MAX / n_trees as u32;
    assert_eq!(quant::prob_to_fixed(1.0, n_trees), cap, "clamp must engage at p = 1.0");

    // Hand-built forest: every tree routes x0 <= 0 to a PURE class-0
    // leaf and x0 > 0 to a pure class-1 leaf.
    let tree = Tree {
        nodes: vec![
            Node::Branch { feature: 0, threshold: 0.0, left: 1, right: 2 },
            Node::Leaf { values: vec![1.0, 0.0] },
            Node::Leaf { values: vec![0.0, 1.0] },
        ],
    };
    let model = Model {
        kind: ModelKind::RandomForest,
        n_features: 1,
        n_classes: 2,
        trees: vec![tree; n_trees],
        base_score: vec![0.0, 0.0],
    };
    model.validate().unwrap();

    // The quantized leaves hit the clamp exactly.
    let q = quant::quantize_forest(&model);
    let saturated = q
        .iter()
        .flatten()
        .flatten()
        .flat_map(|leaf| leaf.values.iter())
        .filter(|&&v| v == cap)
        .count();
    assert_eq!(saturated, 2 * n_trees, "every pure leaf must clamp");

    // The accumulated sum parks just under the wrap, never at 0.
    let ie = intreeger::inference::IntEngine::compile(&model);
    let fixed = ie.predict_fixed(&[-1.0]);
    assert_eq!(fixed, vec![u32::MAX - 3, 0], "4 * cap = 2^32 - 4, no wrap");

    // And the full parity harness agrees across engines and kernels.
    let holdout = Dataset::new(
        vec![-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0, 100.0],
        vec![0, 0, 0, 0, 1, 1, 1, 1],
        1,
        2,
    );
    let v = verify::verify_rf(&model, &holdout);
    assert!(v.passed(), "overflow-adjacent forest must verify: {v:?}");
    assert_eq!(v.mismatches, 0);
    assert_eq!(v.accuracy_int, 1.0);
    assert!(v.max_abs_error <= v.error_bound, "{v:?}");
}

/// Same clamp case end-to-end through `pipeline::run`: a separable
/// dataset trained with a power-of-two tree count produces pure leaves,
/// and the run must still PASS.
#[test]
fn pipeline_run_with_power_of_two_trees_passes() {
    // Zero label noise -> fully separable -> pure (p = 1.0) leaves.
    let ds = generate(
        &SynthSpec {
            n_rows: 400,
            n_features: 4,
            n_classes: 2,
            teacher_depth: 3,
            label_noise: 0.0,
            class_prior: vec![0.6, 0.4],
            range: (-5.0, 5.0),
        },
        99,
    );
    let out = outdir("pow2");
    let cfg = PipelineConfig {
        n_trees: 4,
        max_depth: 6,
        bench: false,
        seed: 99,
        ..Default::default()
    };
    let outcome = pipeline::run(&ds, &out, &cfg).expect("pipeline");
    assert!(outcome.report.all_verified());
    // The trained forest really does carry saturated leaves (otherwise
    // this test exercises nothing).
    let model = Model::from_json(&std::fs::read_to_string(out.join("model_rf.json")).unwrap()).unwrap();
    let cap = u32::MAX / 4;
    let any_saturated = quant::quantize_forest(&model)
        .iter()
        .flatten()
        .flatten()
        .any(|leaf| leaf.values.iter().any(|&v| v == cap));
    assert!(any_saturated, "expected at least one pure leaf hitting the clamp");
}
