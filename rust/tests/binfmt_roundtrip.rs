//! Binary-format round-trip suite (ISSUE 9 satellite): a model written
//! to the INTB zero-copy format and loaded back must be **bit-identical
//! in every observable output** to the same model loaded from JSON —
//! across every traversal kernel, every available SIMD backend, both
//! node orders and intra-batch thread counts 1/2. The topology corpus
//! mirrors the batch-parity suite: single-leaf trees, stumps, depth-16
//! ragged chains, random ragged mixtures, QuickScorer 63/64/65-leaf
//! boundary trees, a 230-feature-wide trained forest, and a trained
//! GBT. On top of prediction parity the serialization itself must be a
//! fixed point: `write → load → write` reproduces the input byte for
//! byte, so re-serializing a fleet never churns artifact fingerprints.

use intreeger::data::{shuttle_like, synth, SynthSpec};
use intreeger::inference::{
    Engine, FlIntEngine, FloatEngine, GbtIntEngine, IntEngine, NodeOrder, SimdBackend,
    TraversalKernel,
};
use intreeger::ir::{Model, ModelKind, Node, Tree};
use intreeger::runtime::binfmt::{self, BinError, BinKind, OwnedBin};
use intreeger::trees::{train_gbt, ForestParams, GbtParams, RandomForest};
use intreeger::util::check::{balanced_tree, random_dist};
use intreeger::util::Rng;

// ---------------------------------------------------------------------------
// Topology generators (same shapes as the batch-parity suite).

/// Random tree with maximum depth `max_depth`; interior nodes become
/// leaves early with probability ~0.3, so trees are ragged.
fn random_tree(rng: &mut Rng, max_depth: usize, nf: usize, nc: usize) -> Tree {
    fn build(nodes: &mut Vec<Node>, rng: &mut Rng, depth_left: usize, nf: usize, nc: usize) -> u32 {
        let idx = nodes.len() as u32;
        if depth_left == 0 || rng.chance(0.3) {
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
        } else {
            nodes.push(Node::Branch {
                feature: rng.below(nf) as u32,
                threshold: rng.uniform_in(-50.0, 50.0),
                left: 0,
                right: 0,
            });
            let l = build(nodes, rng, depth_left - 1, nf, nc);
            let r = build(nodes, rng, depth_left - 1, nf, nc);
            if let Node::Branch { left, right, .. } = &mut nodes[idx as usize] {
                *left = l;
                *right = r;
            }
        }
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, rng, max_depth, nf, nc);
    Tree { nodes }
}

/// A maximally-ragged chain of exactly `depth` branches: one lane exits
/// at depth 1 while another runs the full trip.
fn chain_tree(rng: &mut Rng, depth: usize, nf: usize, nc: usize) -> Tree {
    fn build(nodes: &mut Vec<Node>, rng: &mut Rng, depth_left: usize, nf: usize, nc: usize) -> u32 {
        let idx = nodes.len() as u32;
        if depth_left == 0 {
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            return idx;
        }
        nodes.push(Node::Branch {
            feature: rng.below(nf) as u32,
            threshold: rng.uniform_in(-20.0, 20.0),
            left: 0,
            right: 0,
        });
        let deep_left = depth_left % 2 == 0;
        let (l, r) = if deep_left {
            let l = build(nodes, rng, depth_left - 1, nf, nc);
            let leaf = nodes.len() as u32;
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            (l, leaf)
        } else {
            let leaf = nodes.len() as u32;
            nodes.push(Node::Leaf { values: random_dist(rng, nc) });
            let r = build(nodes, rng, depth_left - 1, nf, nc);
            (leaf, r)
        };
        if let Node::Branch { left, right, .. } = &mut nodes[idx as usize] {
            *left = l;
            *right = r;
        }
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, rng, depth, nf, nc);
    Tree { nodes }
}

/// Rows for a model: random values, rows landing exactly on split
/// thresholds (the `<=` boundary the ordered-u32 transform must
/// preserve through serialization), and NaN rows with both sign bits.
fn probe_rows(rng: &mut Rng, model: &Model, n_rows: usize) -> Vec<f32> {
    let nf = model.n_features;
    let thresholds: Vec<(u32, f32)> = model
        .trees
        .iter()
        .flat_map(|t| &t.nodes)
        .filter_map(|n| match n {
            Node::Branch { feature, threshold, .. } => Some((*feature, *threshold)),
            _ => None,
        })
        .collect();
    let mut rows = Vec::with_capacity(n_rows * nf);
    for i in 0..n_rows {
        let mut row: Vec<f32> = (0..nf).map(|_| rng.uniform_in(-80.0, 80.0)).collect();
        if i % 3 == 0 && !thresholds.is_empty() {
            let (f, t) = thresholds[rng.below(thresholds.len())];
            row[f as usize] = t;
        }
        if i % 7 == 1 {
            let f = rng.below(nf);
            row[f] = if i % 14 == 1 { f32::NAN } else { -f32::NAN };
        }
        rows.extend_from_slice(&row);
    }
    rows
}

fn hand_model(trees: Vec<Tree>, nf: usize, nc: usize) -> Model {
    let model = Model {
        kind: ModelKind::RandomForest,
        n_features: nf,
        n_classes: nc,
        trees,
        base_score: vec![0.0; nc],
    };
    model.validate().expect("hand-built model is valid");
    model
}

// ---------------------------------------------------------------------------
// The core comparator.

/// For every node order: serialize the JSON-loaded model's compiled
/// forest, reload it through [`OwnedBin`], and demand (a) byte-stable
/// re-serialization and (b) bit-identical `predict_fixed_batch` /
/// `predict_batch` / `predict_proba_batch` across kernels × available
/// backends × threads 1/2.
fn assert_bin_parity_rf(model: &Model, tag0: &str) {
    let mut rng = Rng::new(0xB15 ^ model.trees.len() as u64);
    let rows = probe_rows(&mut rng, model, 53);
    let json_model = Model::from_json(&model.to_json()).expect("JSON round-trip");
    for order in NodeOrder::all() {
        let mut json_engine = IntEngine::compile_with(&json_model, order);
        let bytes = binfmt::write_forest(json_engine.forest());
        assert!(binfmt::is_binary(&bytes), "{tag0}: magic sniff");
        let owned = OwnedBin::from_bytes(&bytes);
        let view = owned
            .view()
            .unwrap_or_else(|e| panic!("{tag0}/{}: load: {e}", order.name()));
        assert_eq!(view.kind(), BinKind::Rf, "{tag0}: kind");
        assert_eq!(view.resident_bytes(), bytes.len(), "{tag0}: resident bytes");
        let forest = view
            .to_forest()
            .unwrap_or_else(|e| panic!("{tag0}/{}: to_forest: {e}", order.name()));
        // write → load → write is a fixed point, byte for byte.
        assert_eq!(
            binfmt::write_forest(&forest),
            bytes,
            "{tag0}/{}: re-serialization not byte-stable",
            order.name()
        );
        let mut bin_engine = IntEngine::from_forest(forest);
        for kernel in TraversalKernel::all() {
            for &backend in SimdBackend::available() {
                for threads in [1usize, 2] {
                    for e in [&mut json_engine, &mut bin_engine] {
                        e.set_kernel(kernel);
                        e.set_backend(backend);
                        e.set_threads(threads);
                    }
                    let tag = format!(
                        "{tag0}/{}/{}/{}/{threads}t",
                        order.name(),
                        kernel.name(),
                        backend.name()
                    );
                    assert_eq!(
                        json_engine.predict_fixed_batch(&rows),
                        bin_engine.predict_fixed_batch(&rows),
                        "{tag}: fixed accumulators diverge"
                    );
                    assert_eq!(
                        json_engine.predict_batch(&rows),
                        bin_engine.predict_batch(&rows),
                        "{tag}: argmax classes diverge"
                    );
                    assert_eq!(
                        json_engine.predict_proba_batch(&rows),
                        bin_engine.predict_proba_batch(&rows),
                        "{tag}: probabilities diverge"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// RF topology corpus.

#[test]
fn stumps_leaf_only_and_qs_boundary_trees_round_trip() {
    let nf = 6usize;
    let nc = 3usize;
    let mut rng = Rng::new(901);
    let mut trees = vec![
        // depth 0: single-leaf tree (no branch rows in any section).
        Tree { nodes: vec![Node::Leaf { values: random_dist(&mut rng, nc) }] },
        // a stump.
        balanced_tree(&mut rng, 2, nf, nc),
    ];
    // QuickScorer u64-mask eligibility boundary: 63/64/65 leaves.
    for leaves in [63, 64, 65] {
        trees.push(balanced_tree(&mut rng, leaves, nf, nc));
    }
    assert_bin_parity_rf(&hand_model(trees, nf, nc), "stumps");
}

#[test]
fn ragged_random_topologies_round_trip() {
    let nf = 9usize;
    let nc = 4usize;
    for seed in [11u64, 12] {
        let mut rng = Rng::new(seed);
        let trees: Vec<Tree> =
            (0..6).map(|i| random_tree(&mut rng, 2 + i * 2, nf, nc)).collect();
        assert_bin_parity_rf(&hand_model(trees, nf, nc), &format!("ragged{seed}"));
    }
}

#[test]
fn chain_topologies_round_trip() {
    let nf = 5usize;
    let nc = 3usize;
    let mut rng = Rng::new(77);
    let trees = vec![
        chain_tree(&mut rng, 16, nf, nc),
        chain_tree(&mut rng, 9, nf, nc),
        random_tree(&mut rng, 4, nf, nc),
    ];
    assert_bin_parity_rf(&hand_model(trees, nf, nc), "chains");
}

/// ≥200-feature regression: the SoA feature planes and the header's
/// `n_features` must agree on very wide rows.
#[test]
fn wide_230_feature_forest_round_trips() {
    let spec = SynthSpec {
        n_rows: 900,
        n_features: 230,
        n_classes: 4,
        teacher_depth: 6,
        label_noise: 0.04,
        class_prior: vec![0.4, 0.3, 0.2, 0.1],
        range: (-50.0, 50.0),
    };
    let ds = synth::generate(&spec, 44);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 4, max_depth: 6, ..Default::default() },
        44,
    );
    assert_bin_parity_rf(&model, "wide230");
}

/// A trained forest on realistic data, plus the float and FlInt engine
/// families rebuilt from the same binary artifact: all three families
/// must match their JSON-compiled twins bit for bit.
#[test]
fn trained_rf_all_engine_families_agree_after_reload() {
    let ds = shuttle_like(1200, 91);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 8, max_depth: 6, ..Default::default() },
        91,
    );
    assert_bin_parity_rf(&model, "shuttle");

    let mut rng = Rng::new(91);
    let rows = probe_rows(&mut rng, &model, 40);
    let json_model = Model::from_json(&model.to_json()).expect("JSON round-trip");
    for order in NodeOrder::all() {
        let jf = FloatEngine::compile_with(&json_model, order);
        let bytes = binfmt::write_forest(jf.forest());
        let bf = FloatEngine::from_forest(
            OwnedBin::from_bytes(&bytes).view().expect("load").to_forest().expect("rf"),
        );
        assert_eq!(
            jf.predict_proba_batch(&rows),
            bf.predict_proba_batch(&rows),
            "float family diverges ({})",
            order.name()
        );

        let ji = FlIntEngine::compile_with(&json_model, order);
        let bytes = binfmt::write_forest(ji.forest());
        let bi = FlIntEngine::from_forest(
            OwnedBin::from_bytes(&bytes).view().expect("load").to_forest().expect("rf"),
        );
        assert_eq!(
            ji.predict_batch(&rows),
            bi.predict_batch(&rows),
            "flint family diverges ({})",
            order.name()
        );
    }
}

// ---------------------------------------------------------------------------
// GBT.

#[test]
fn gbt_round_trips_bit_identically() {
    let ds = shuttle_like(900, 55);
    let model =
        train_gbt(&ds, &GbtParams { n_rounds: 5, max_depth: 4, ..Default::default() }, 55);
    let mut rng = Rng::new(55);
    let rows = probe_rows(&mut rng, &model, 48);

    let mut json_engine =
        GbtIntEngine::compile(&Model::from_json(&model.to_json()).expect("JSON round-trip"));
    let bytes = binfmt::write_gbt(&json_engine);
    let owned = OwnedBin::from_bytes(&bytes);
    let view = owned.view().expect("load gbt");
    assert_eq!(view.kind(), BinKind::Gbt);
    // Kind confusion is a typed error, not a misinterpretation.
    assert!(matches!(view.to_forest(), Err(BinError::KindMismatch { .. })));
    let mut bin_engine = view.to_gbt().expect("to_gbt");
    assert_eq!(binfmt::write_gbt(&bin_engine), bytes, "gbt re-serialization not byte-stable");

    for kernel in TraversalKernel::all() {
        for &backend in SimdBackend::available() {
            for threads in [1usize, 2] {
                for e in [&mut json_engine, &mut bin_engine] {
                    e.set_kernel(kernel);
                    e.set_backend(backend);
                    e.set_threads(threads);
                }
                let tag = format!("gbt/{}/{}/{threads}t", kernel.name(), backend.name());
                assert_eq!(
                    json_engine.predict_fixed_batch(&rows),
                    bin_engine.predict_fixed_batch(&rows),
                    "{tag}: margins diverge"
                );
                assert_eq!(
                    json_engine.predict_batch(&rows),
                    bin_engine.predict_batch(&rows),
                    "{tag}: classes diverge"
                );
            }
        }
    }
    let nf = model.n_features;
    for i in 0..rows.len() / nf {
        let row = &rows[i * nf..(i + 1) * nf];
        assert_eq!(
            json_engine.predict_proba(row),
            bin_engine.predict_proba(row),
            "gbt probabilities diverge at row {i}"
        );
    }
}

// ---------------------------------------------------------------------------
// Alignment fallback at the integration level.

/// File reads land in `Vec<u8>` with no alignment promise. A deliberately
/// shifted buffer must either load (the allocator happened to align it)
/// or fail with exactly [`BinError::Unaligned`] — and [`OwnedBin`] must
/// always recover it with full prediction parity.
#[test]
fn unaligned_sources_recover_through_owned_copy() {
    let ds = shuttle_like(600, 21);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
        21,
    );
    let engine = IntEngine::compile(&model);
    let bytes = binfmt::write_forest(engine.forest());

    let mut shifted = vec![0u8; bytes.len() + 1];
    shifted[1..].copy_from_slice(&bytes);
    let slice = &shifted[1..];
    match binfmt::load(slice) {
        Err(BinError::Unaligned) | Ok(_) => {}
        Err(e) => panic!("shifted buffer must only fail as Unaligned, got {e}"),
    }

    let owned = OwnedBin::from_bytes(slice);
    let reloaded = IntEngine::from_forest(owned.view().expect("load").to_forest().expect("rf"));
    for i in 0..32 {
        assert_eq!(
            engine.predict_fixed(ds.row(i)),
            reloaded.predict_fixed(ds.row(i)),
            "row {i} diverges after the owned-copy recovery"
        );
    }
}
