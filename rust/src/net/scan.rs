//! Lazy JSON feature extraction: byte-scan the request body for the
//! top-level `"features"` array and parse its numbers straight into a
//! reused `Vec<f32>` arena — no DOM, no intermediate strings, no
//! per-request allocation once the arena is warm.
//!
//! This is the mik-sdk ADR-002 idiom (scan bytes → locate path →
//! extract only the requested field): for a predict request the server
//! needs exactly one field, so building a value tree for the whole
//! document is pure waste. Values under other keys are *skipped* with a
//! depth counter (string-aware, escape-aware) without being decoded,
//! and the scanner returns as soon as the features array is parsed —
//! bytes after it are never touched.
//!
//! Number handling is deliberately strict-JSON: `NaN` / `Infinity`
//! literals are not numbers and are rejected here with a scan error,
//! while overflowing decimal forms (`1e999`) parse to ±`inf` per IEEE
//! 754 and flow on to the coordinator, whose admission check rejects
//! them as `ServeError::NonFiniteFeature` — smuggling a non-finite
//! value past validation by spelling it creatively is not possible.

/// Typed scanner failures; each carries enough to produce a precise
/// 400 body without formatting machinery on the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanError {
    /// The body is not a JSON object (`{...}`).
    NotAnObject,
    /// The object has no top-level `"features"` key.
    MissingFeatures,
    /// The `"features"` value is not an array of JSON numbers; the
    /// payload is the byte offset of the offending token.
    BadNumber(usize),
    /// Structurally malformed JSON at the given byte offset.
    Syntax(usize),
}

impl ScanError {
    /// Machine-readable error kind for the JSON error body.
    pub fn kind(&self) -> &'static str {
        match self {
            ScanError::NotAnObject => "not_an_object",
            ScanError::MissingFeatures => "missing_features",
            ScanError::BadNumber(_) => "bad_number",
            ScanError::Syntax(_) => "bad_json",
        }
    }
}

impl std::fmt::Display for ScanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScanError::NotAnObject => write!(f, "body is not a JSON object"),
            ScanError::MissingFeatures => write!(f, "no top-level 'features' key"),
            ScanError::BadNumber(off) => {
                write!(f, "'features' must be an array of finite JSON numbers (byte {off})")
            }
            ScanError::Syntax(off) => write!(f, "malformed JSON at byte {off}"),
        }
    }
}

impl std::error::Error for ScanError {}

/// Extract the top-level `"features"` array of `body` into `out`
/// (cleared first, capacity reused). Returns as soon as the array has
/// been parsed; the remainder of the document is not validated — lazy
/// by design.
pub fn extract_features(body: &[u8], out: &mut Vec<f32>) -> Result<(), ScanError> {
    out.clear();
    let mut s = Scanner { buf: body, pos: 0 };
    s.skip_ws();
    if s.next() != Some(b'{') {
        return Err(ScanError::NotAnObject);
    }
    s.skip_ws();
    if s.peek() == Some(b'}') {
        return Err(ScanError::MissingFeatures);
    }
    loop {
        // key
        s.skip_ws();
        let (key_lo, key_hi) = s.scan_string()?;
        s.skip_ws();
        if s.next() != Some(b':') {
            return Err(ScanError::Syntax(s.pos));
        }
        s.skip_ws();
        if &s.buf[key_lo..key_hi] == b"features" {
            return s.parse_number_array(out);
        }
        s.skip_value()?;
        s.skip_ws();
        match s.next() {
            Some(b',') => continue,
            Some(b'}') => return Err(ScanError::MissingFeatures),
            _ => return Err(ScanError::Syntax(s.pos)),
        }
    }
}

struct Scanner<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Scanner<'_> {
    fn peek(&self) -> Option<u8> {
        self.buf.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Consume a JSON string, returning the byte range of its raw
    /// contents (between the quotes, escapes left as-is — key matching
    /// is against the literal spelling, which is exact for `features`).
    fn scan_string(&mut self) -> Result<(usize, usize), ScanError> {
        if self.next() != Some(b'"') {
            return Err(ScanError::Syntax(self.pos));
        }
        let lo = self.pos;
        loop {
            match self.next() {
                Some(b'"') => return Ok((lo, self.pos - 1)),
                Some(b'\\') => {
                    // Skip the escaped byte; \uXXXX needs no special
                    // care — its hex digits cannot contain '"' or '\'.
                    self.next().ok_or(ScanError::Syntax(self.pos))?;
                }
                Some(_) => {}
                None => return Err(ScanError::Syntax(self.pos)),
            }
        }
    }

    /// Skip one JSON value of any type without decoding it: strings are
    /// scanned escape-aware, containers with a depth counter, scalars by
    /// running to the next structural byte.
    fn skip_value(&mut self) -> Result<(), ScanError> {
        match self.peek().ok_or(ScanError::Syntax(self.pos))? {
            b'"' => {
                self.scan_string()?;
                Ok(())
            }
            b'{' | b'[' => {
                let mut depth = 0usize;
                loop {
                    match self.next().ok_or(ScanError::Syntax(self.pos))? {
                        b'{' | b'[' => depth += 1,
                        b'}' | b']' => {
                            depth -= 1;
                            if depth == 0 {
                                return Ok(());
                            }
                        }
                        b'"' => {
                            // Rewind onto the quote and reuse the
                            // escape-aware string scan.
                            self.pos -= 1;
                            self.scan_string()?;
                        }
                        _ => {}
                    }
                }
            }
            _ => {
                // Scalar: number / true / false / null. Run to the next
                // structural delimiter; the caller validates context.
                while let Some(b) = self.peek() {
                    if matches!(b, b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r') {
                        break;
                    }
                    self.pos += 1;
                }
                Ok(())
            }
        }
    }

    /// Parse `[n, n, ...]` into `out`. Each element must be a JSON
    /// number token; `str::parse::<f32>` does the decimal conversion in
    /// place over the borrowed token slice.
    fn parse_number_array(&mut self, out: &mut Vec<f32>) -> Result<(), ScanError> {
        if self.next() != Some(b'[') {
            return Err(ScanError::BadNumber(self.pos.saturating_sub(1)));
        }
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            let lo = self.pos;
            while let Some(b) = self.peek() {
                // JSON number alphabet only — 'N' (NaN), 'I' (Infinity)
                // and friends terminate the token and fail the parse.
                if matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                    self.pos += 1;
                } else {
                    break;
                }
            }
            if lo == self.pos {
                return Err(ScanError::BadNumber(lo));
            }
            let token =
                std::str::from_utf8(&self.buf[lo..self.pos]).map_err(|_| ScanError::BadNumber(lo))?;
            let v: f32 = token.parse().map_err(|_| ScanError::BadNumber(lo))?;
            out.push(v);
            self.skip_ws();
            match self.next() {
                Some(b',') => continue,
                Some(b']') => return Ok(()),
                _ => return Err(ScanError::Syntax(self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(body: &str) -> Result<Vec<f32>, ScanError> {
        let mut out = Vec::new();
        extract_features(body.as_bytes(), &mut out).map(|()| out)
    }

    #[test]
    fn extracts_a_plain_features_array() {
        assert_eq!(scan(r#"{"features": [1, 2.5, -3e2]}"#).unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(scan(r#"{"features":[]}"#).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn skips_other_keys_of_any_shape() {
        let body = r#"{
            "id": "req-42{\"}]",
            "nested": {"a": [1, {"b": "]}"}], "c": null},
            "flag": true,
            "features": [7.5, 8],
            "after": "never even scanned"
        }"#;
        assert_eq!(scan(body).unwrap(), vec![7.5, 8.0]);
    }

    #[test]
    fn is_lazy_after_the_features_array() {
        // Garbage *after* the extracted field is never touched — that is
        // the point of scanning instead of building a DOM.
        assert_eq!(scan(r#"{"features":[1,2] THIS IS NOT JSON"#).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn rejects_non_objects_and_missing_key() {
        assert_eq!(scan(r#"[1,2,3]"#), Err(ScanError::NotAnObject));
        assert_eq!(scan(r#""features""#), Err(ScanError::NotAnObject));
        assert_eq!(scan(r#"{}"#), Err(ScanError::MissingFeatures));
        assert_eq!(scan(r#"{"other": 1}"#), Err(ScanError::MissingFeatures));
    }

    #[test]
    fn rejects_nan_and_infinity_literals() {
        assert!(matches!(scan(r#"{"features": [NaN]}"#), Err(ScanError::BadNumber(_))));
        assert!(matches!(scan(r#"{"features": [Infinity]}"#), Err(ScanError::BadNumber(_))));
        assert!(matches!(scan(r#"{"features": [1, null]}"#), Err(ScanError::BadNumber(_))));
        assert!(matches!(scan(r#"{"features": "nope"}"#), Err(ScanError::BadNumber(_))));
    }

    #[test]
    fn overflowing_decimals_parse_to_infinity_for_downstream_rejection() {
        // 1e999 is valid JSON; IEEE 754 overflow makes it +inf, and the
        // coordinator's finiteness check turns that into a typed 400.
        let got = scan(r#"{"features": [1e999, -1e999]}"#).unwrap();
        assert!(got[0].is_infinite() && got[0] > 0.0);
        assert!(got[1].is_infinite() && got[1] < 0.0);
    }

    #[test]
    fn truncated_bodies_are_syntax_errors() {
        assert!(matches!(scan(r#"{"features": [1, 2"#), Err(ScanError::Syntax(_))));
        assert!(matches!(scan(r#"{"features"#), Err(ScanError::Syntax(_))));
        assert!(matches!(scan(r#"{"a": {"unclosed": 1}"#), Err(ScanError::Syntax(_))));
    }

    #[test]
    fn arena_is_cleared_and_reused() {
        let mut arena = vec![9.0f32; 100];
        extract_features(br#"{"features": [1]}"#, &mut arena).unwrap();
        assert_eq!(arena, vec![1.0]);
        assert!(arena.capacity() >= 100, "capacity must be reused, not shrunk");
    }
}
