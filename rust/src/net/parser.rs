//! Lazy zero-allocation HTTP/1.1 request-head parser.
//!
//! The parser never copies: [`parse_head`] borrows method and path as
//! `&str` slices straight out of the connection buffer, inspects only
//! the headers the server acts on, and reports how many bytes the head
//! consumed so the caller can frame the body (and the next pipelined
//! request) without re-scanning. Incomplete input is a normal state
//! (`Ok(None)` — read more), not an error; errors are typed so each
//! maps onto exactly one HTTP status.

/// Hard cap on the request head (request line + headers + terminator).
/// A head that grows past this without terminating is rejected with
/// `431 Request Header Fields Too Large` — the buffer never grows
/// unboundedly for a client that just streams header bytes.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Hard cap on the declared `Content-Length`. Larger bodies are
/// rejected up front with `413 Content Too Large` before any body byte
/// is buffered.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Typed request-head parse failures; [`HttpError::status`] maps each
/// to the one HTTP status it answers with. All of them are
/// connection-fatal: once framing is in doubt the server responds and
/// closes rather than guessing where the next request starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HttpError {
    /// Malformed request line or header (the `&str` names the offense).
    BadRequest(&'static str),
    /// The head exceeded [`MAX_HEAD_BYTES`] without terminating.
    HeadersTooLarge,
    /// The declared `Content-Length` exceeds [`MAX_BODY_BYTES`].
    BodyTooLarge,
    /// Valid HTTP the server deliberately does not implement
    /// (`Transfer-Encoding` framing, `Expect: 100-continue`).
    Unsupported(&'static str),
}

impl HttpError {
    /// The `(status code, reason phrase)` this error answers with.
    pub fn status(&self) -> (u16, &'static str) {
        match self {
            HttpError::BadRequest(_) => (400, "Bad Request"),
            HttpError::HeadersTooLarge => (431, "Request Header Fields Too Large"),
            HttpError::BodyTooLarge => (413, "Content Too Large"),
            HttpError::Unsupported(_) => (501, "Not Implemented"),
        }
    }

    /// A short human-readable detail string for the error body.
    pub fn detail(&self) -> &'static str {
        match self {
            HttpError::BadRequest(d) | HttpError::Unsupported(d) => d,
            HttpError::HeadersTooLarge => "request head exceeds the header size limit",
            HttpError::BodyTooLarge => "declared content-length exceeds the body size limit",
        }
    }
}

/// A parsed request head. `method` and `path` borrow from the
/// connection buffer — zero copies; the head is only valid until the
/// caller shifts or refills that buffer.
#[derive(Debug, Clone, Copy)]
pub struct RequestHead<'a> {
    /// Request method, verbatim (`GET`, `POST`, ...).
    pub method: &'a str,
    /// Request target, verbatim (`/predict`, `/metrics?x=1`, ...).
    pub path: &'a str,
    /// Whether the connection should stay open after the response
    /// (HTTP/1.1 default yes, HTTP/1.0 default no, `Connection` header
    /// overrides either way).
    pub keep_alive: bool,
    /// Declared body length (0 when no `Content-Length` header).
    pub content_length: usize,
    /// Bytes the head consumed, including the `\r\n\r\n` terminator;
    /// the body starts at this offset.
    pub head_len: usize,
}

impl RequestHead<'_> {
    /// Total framed size of this request: head plus declared body.
    pub fn total_len(&self) -> usize {
        self.head_len + self.content_length
    }
}

/// Try to parse one request head from the front of `buf`.
///
/// * `Ok(Some(head))` — a complete head; the body (if any) occupies
///   `buf[head.head_len .. head.total_len()]` once that many bytes have
///   been read.
/// * `Ok(None)` — incomplete; read more bytes and call again.
/// * `Err(e)` — malformed or over-limit; respond with `e.status()` and
///   close the connection.
pub fn parse_head(buf: &[u8]) -> Result<Option<RequestHead<'_>>, HttpError> {
    let head_end = match find_terminator(buf) {
        Some(end) => end,
        None => {
            // No terminator yet. Only an error if the head can no
            // longer terminate within the cap.
            if buf.len() >= MAX_HEAD_BYTES {
                return Err(HttpError::HeadersTooLarge);
            }
            return Ok(None);
        }
    };
    if head_end > MAX_HEAD_BYTES {
        return Err(HttpError::HeadersTooLarge);
    }

    let head = &buf[..head_end - 4]; // strip the \r\n\r\n terminator
    let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));

    // Request line: METHOD SP TARGET SP HTTP/1.x
    let request_line = lines.next().ok_or(HttpError::BadRequest("empty request line"))?;
    let line = std::str::from_utf8(request_line)
        .map_err(|_| HttpError::BadRequest("request line is not valid UTF-8"))?;
    let mut parts = line.split(' ');
    let method = parts.next().filter(|m| !m.is_empty()).ok_or(HttpError::BadRequest("missing method"))?;
    let path = parts.next().filter(|p| !p.is_empty()).ok_or(HttpError::BadRequest("missing request target"))?;
    let version = parts.next().ok_or(HttpError::BadRequest("missing HTTP version"))?;
    if parts.next().is_some() {
        return Err(HttpError::BadRequest("malformed request line"));
    }
    let mut keep_alive = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::BadRequest("unsupported HTTP version")),
    };

    // Headers: only the three the server acts on are inspected; the
    // rest are skipped without being materialized anywhere.
    let mut content_length = 0usize;
    for raw in lines {
        if raw.is_empty() {
            continue;
        }
        let colon = raw
            .iter()
            .position(|&b| b == b':')
            .ok_or(HttpError::BadRequest("header line without a colon"))?;
        let name = &raw[..colon];
        let value = trim_ascii(&raw[colon + 1..]);
        if eq_ignore_case(name, b"content-length") {
            let value = std::str::from_utf8(value)
                .map_err(|_| HttpError::BadRequest("invalid content-length"))?;
            content_length = value
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest("invalid content-length"))?;
        } else if eq_ignore_case(name, b"connection") {
            if eq_ignore_case(value, b"close") {
                keep_alive = false;
            } else if eq_ignore_case(value, b"keep-alive") {
                keep_alive = true;
            }
        } else if eq_ignore_case(name, b"transfer-encoding") {
            // Chunked (or any transfer coding) framing is out of scope:
            // refusing is safer than misframing the stream.
            return Err(HttpError::Unsupported("transfer-encoding is not supported"));
        } else if eq_ignore_case(name, b"expect") {
            return Err(HttpError::Unsupported("expect is not supported"));
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::BodyTooLarge);
    }

    Ok(Some(RequestHead { method, path, keep_alive, content_length, head_len: head_end }))
}

/// Offset one past the `\r\n\r\n` head terminator, if present.
fn find_terminator(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// ASCII case-insensitive equality without allocating lowercase copies.
fn eq_ignore_case(a: &[u8], b: &[u8]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.eq_ignore_ascii_case(y))
}

/// Trim ASCII spaces and tabs from both ends (header optional whitespace).
fn trim_ascii(mut v: &[u8]) -> &[u8] {
    while let [b' ' | b'\t', rest @ ..] = v {
        v = rest;
    }
    while let [rest @ .., b' ' | b'\t'] = v {
        v = rest;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_post() {
        let raw = b"POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let head = parse_head(raw).unwrap().unwrap();
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/predict");
        assert!(head.keep_alive);
        assert_eq!(head.content_length, 5);
        assert_eq!(&raw[head.head_len..head.total_len()], b"hello");
    }

    #[test]
    fn incomplete_head_asks_for_more() {
        let raw = b"POST /predict HTTP/1.1\r\nContent-Le";
        assert!(parse_head(raw).unwrap().is_none());
        assert!(parse_head(b"").unwrap().is_none());
    }

    #[test]
    fn header_names_and_values_are_case_insensitive() {
        let raw = b"GET / HTTP/1.1\r\ncOnNeCtIoN: CLOSE\r\n\r\n";
        let head = parse_head(raw).unwrap().unwrap();
        assert!(!head.keep_alive);
    }

    #[test]
    fn http_10_defaults_to_close_but_can_keep_alive() {
        let plain = parse_head(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!plain.keep_alive);
        let ka = parse_head(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(ka.keep_alive);
    }

    #[test]
    fn oversized_heads_and_bodies_are_typed_errors() {
        // A head that never terminates within the cap.
        let mut raw = b"GET / HTTP/1.1\r\nX: ".to_vec();
        raw.resize(MAX_HEAD_BYTES + 1, b'a');
        assert_eq!(parse_head(&raw), Err(HttpError::HeadersTooLarge));
        // A declared body over the cap.
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert_eq!(parse_head(raw.as_bytes()), Err(HttpError::BodyTooLarge));
    }

    #[test]
    fn malformed_lines_are_rejected() {
        assert!(matches!(
            parse_head(b"POST  HTTP/1.1\r\n\r\n"),
            Err(HttpError::BadRequest(_)) | Err(HttpError::Unsupported(_))
        ));
        assert!(matches!(parse_head(b"GET / SPDY/3\r\n\r\n"), Err(HttpError::BadRequest(_))));
        assert!(matches!(
            parse_head(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            parse_head(b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            parse_head(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Unsupported(_))
        ));
    }

    #[test]
    fn pipelined_heads_frame_back_to_back() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n";
        let first = parse_head(raw).unwrap().unwrap();
        assert_eq!(first.path, "/healthz");
        let second = parse_head(&raw[first.total_len()..]).unwrap().unwrap();
        assert_eq!(second.path, "/metrics");
    }
}
