//! Pure-Rust, std-only HTTP/1.1 serving front end for the coordinator —
//! the socket the ROADMAP's "millions of users" story was missing.
//!
//! Three pieces, one perf story:
//!
//! * [`parser`] — a lazy, zero-allocation HTTP/1.1 request parser:
//!   borrowed `&str` slices over a reused per-connection buffer, no
//!   header map, no copies. Only the three headers the server acts on
//!   (`Content-Length`, `Connection`, `Transfer-Encoding`) are even
//!   inspected; everything else is skipped byte-wise.
//! * [`scan`] — a lazy JSON scanner that extracts **only** the
//!   `features` array by byte-scanning, without building a DOM (the
//!   mik-sdk ADR-002 idiom: scan bytes → find path → extract, ~33x for
//!   partial field extraction), parsing `f32`s straight into a reused
//!   arena `Vec<f32>`.
//! * [`server`] — a sized acceptor plus connection-worker pool over
//!   non-blocking `std::net`, keep-alive and pipelining over one reused
//!   buffer per worker, vectored response writes, and `POST /predict`
//!   / `GET /metrics` routed into the existing
//!   [`InferenceServer`](crate::coordinator::InferenceServer). In
//!   **fleet mode** the same front end serves a versioned
//!   [`ModelRegistry`](crate::coordinator::ModelRegistry):
//!   `POST /predict/{id}` (or `{id}@{version}`), `GET /models`, and a
//!   `POST /admin/reload` hot-swap path.
//!
//! The request hot path — parse head, scan features, admit, batch,
//! respond, render — performs **zero heap allocations per request in
//! steady state**: the connection buffer, the feature arena, and both
//! response buffers are reused across requests; admission copies the
//! parsed row into a checked-out slab row of the coordinator's arena
//! ([`FeatureSlab`](crate::coordinator::FeatureSlab)) instead of
//! cloning a `Vec<f32>`; and the response's fixed-point buffer travels
//! with the request and is recycled through the connection's
//! [`ReplySlot`](crate::coordinator::ReplySlot) after rendering
//! (verified end to end by the debug-only allocation counter in
//! `tests/http_corpus.rs`).

pub mod parser;
pub mod scan;
pub mod server;

pub use parser::{parse_head, HttpError, RequestHead, MAX_BODY_BYTES, MAX_HEAD_BYTES};
pub use scan::{extract_features, ScanError};
pub use server::{HttpConfig, HttpServer};
