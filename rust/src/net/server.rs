//! The HTTP front end: a sized acceptor plus connection-worker pool
//! over non-blocking `std::net`, feeding the coordinator.
//!
//! ## Shape
//!
//! One acceptor thread owns the non-blocking listener and hands
//! accepted sockets to a fixed pool of connection workers over a
//! **sized** channel — when every worker is busy and the handoff queue
//! is full, the acceptor answers `503` and closes instead of queueing
//! unboundedly: connection-level admission control, mirroring the
//! coordinator's bounded request queue one layer down.
//!
//! Each worker owns one set of [`ConnBuffers`] — request buffer,
//! feature arena, reply slot, response head/body buffers — reused
//! across every request and every connection it ever serves.
//! Keep-alive and pipelining work over the same buffer: after each
//! response the consumed bytes are shifted out with `copy_within` and
//! the next request (possibly already buffered) parses in place.
//! Admission is zero-copy into the coordinator: the parsed row is
//! copied into a checked-out arena slab row
//! ([`InferenceServer::checkout_row`]) and submitted through the
//! connection's reusable [`ReplySlot`], whose response buffer is
//! recycled after rendering. In steady state the full
//! parse → scan → admit → batch → respond → render path performs
//! **zero heap allocations per request** (debug-build
//! allocation-counter test).
//!
//! Responses go out with a single vectored write (`write_vectored`
//! over head + body slices) with a write-all fallback for short
//! writes.
//!
//! ## Routes
//!
//! * `POST /predict` — body `{"features": [..]}` → `200` with
//!   `{"class", "route", "fixed", "proba"}`, or a typed error body.
//! * `GET /metrics` — the full coordinator metrics snapshot as JSON,
//!   including the e2e latency SLO percentiles, the batching policy
//!   knobs, and (fleet mode) the resident-model gauges.
//! * `GET /healthz` — `200 ok` liveness probe.
//!
//! A server started in **fleet mode** ([`HttpServer::start_fleet`])
//! serves a [`ModelRegistry`] instead of one pinned model and adds:
//!
//! * `POST /predict/{spec}` — `spec` is `id` (follow the fleet routing
//!   rule: A/B split if set, else current version) or `id@version`
//!   (pinned). The spec parse is the one deliberate allocation on this
//!   path (the id must outlive the request buffer).
//! * `GET /models` — the fleet listing: per model the serving version,
//!   feature arity, resident bytes, retained versions, and A/B split.
//! * `POST /admin/reload` — rescan the `--models` directory via the
//!   attached [`FleetLoader`], hot-swapping every changed artifact;
//!   answers the reload report.
//!
//! Error statuses: malformed HTTP or JSON and validation failures →
//! `400`/`413`/`431`/`501`; unknown model/version → `404`; shed
//! (`QueueFull`/`ShuttingDown`) → `503`; TTL expiry
//! (`DeadlineExceeded`) → `504`; `WorkerLost` → `500`.

use std::io::{self, IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::parser::{self, HttpError};
use super::scan;
use crate::coordinator::{
    FleetLoader, InferenceServer, MetricsSnapshot, ModelInfo, ModelRegistry, RegistryError,
    ReloadReport, ReplySlot, Response, Route, RouteError, RouteSpec, ServeError,
};
use crate::quant::fixed_to_prob;

/// HTTP front-end configuration.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Listen address, e.g. `127.0.0.1:8080` (port 0 picks a free one).
    pub addr: String,
    /// Connection-worker threads (each serves one connection at a
    /// time, keep-alive included). Clamped to at least 1.
    pub conn_workers: usize,
    /// Read timeout on idle keep-alive connections; a connection quiet
    /// for this long is closed so its worker can serve someone else.
    pub keep_alive_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:8080".to_string(),
            conn_workers: 4,
            keep_alive_timeout: Duration::from_secs(5),
        }
    }
}

/// A running HTTP front end. Dropping it stops the acceptor, drains
/// the workers, and joins every thread.
pub struct HttpServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// What the front end serves: one pinned model, or a versioned fleet.
enum ServeTarget {
    /// Classic single-model mode (`POST /predict`).
    Single(Arc<InferenceServer>),
    /// Fleet mode: `/predict/{spec}`, `/models`, and (with a loader)
    /// `/admin/reload`.
    Fleet {
        /// The versioned registry requests resolve against.
        registry: Arc<ModelRegistry>,
        /// Directory loader behind `POST /admin/reload` (absent when
        /// the fleet is managed programmatically).
        loader: Option<Arc<FleetLoader>>,
    },
}

impl ServeTarget {
    fn metrics(&self) -> Arc<crate::coordinator::Metrics> {
        match self {
            ServeTarget::Single(s) => s.metrics_handle(),
            ServeTarget::Fleet { registry, .. } => registry.metrics(),
        }
    }
}

impl HttpServer {
    /// Bind `config.addr` and start serving `server` over HTTP.
    pub fn start(server: Arc<InferenceServer>, config: HttpConfig) -> io::Result<HttpServer> {
        Self::start_target(ServeTarget::Single(server), config)
    }

    /// Bind `config.addr` and serve a model **fleet**: requests resolve
    /// against `registry` via `POST /predict/{spec}`, the fleet is
    /// listed at `GET /models`, and — when a `loader` is attached —
    /// `POST /admin/reload` rescans its directory and hot-swaps changed
    /// artifacts.
    pub fn start_fleet(
        registry: Arc<ModelRegistry>,
        loader: Option<Arc<FleetLoader>>,
        config: HttpConfig,
    ) -> io::Result<HttpServer> {
        Self::start_target(ServeTarget::Fleet { registry, loader }, config)
    }

    fn start_target(target: ServeTarget, config: HttpConfig) -> io::Result<HttpServer> {
        let target = Arc::new(target);
        let listener = TcpListener::bind(&config.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let n_workers = config.conn_workers.max(1);

        // Sized handoff: bounded queue between acceptor and workers.
        let (tx, rx) = sync_channel::<TcpStream>(n_workers * 2);
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let rx = Arc::clone(&rx);
            let target = Arc::clone(&target);
            let cfg = config.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("http-conn-{w}"))
                    .spawn(move || conn_worker(&rx, &target, &cfg))?,
            );
        }

        let stop_flag = Arc::clone(&stop);
        let acceptor = std::thread::Builder::new().name("http-acceptor".to_string()).spawn(
            move || {
                loop {
                    if stop_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => match tx.try_send(stream) {
                            Ok(()) => {}
                            Err(TrySendError::Full(stream)) => overloaded_close(stream),
                            Err(TrySendError::Disconnected(_)) => break,
                        },
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        // Transient accept errors (ECONNABORTED etc.):
                        // back off briefly and keep accepting.
                        Err(_) => std::thread::sleep(Duration::from_millis(1)),
                    }
                }
                // `tx` drops here; workers drain queued sockets, then
                // their recv() fails and they exit.
            },
        )?;

        Ok(HttpServer { local_addr, stop, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Every connection queue slot is taken: answer 503 and close. Off the
/// hot path by definition (this *is* the overload path), so the local
/// buffers here may allocate.
fn overloaded_close(mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let mut body = Vec::new();
    render_error_body(&mut body, "queue_full", &"connection queue is full");
    let mut head = Vec::new();
    render_head(&mut head, 503, "Service Unavailable", body.len(), false);
    let _ = write_response(&mut stream, &head, &body);
}

/// Per-worker reusable buffers — the whole zero-allocation story lives
/// in these vectors (and the reply slot's recycled channel + output
/// buffer) keeping their capacity across requests and connections.
#[derive(Default)]
struct ConnBuffers {
    /// Raw request bytes; `filled` of them are valid.
    buf: Vec<u8>,
    filled: usize,
    /// Feature arena the JSON scanner parses into.
    features: Vec<f32>,
    /// Rendered response head / body.
    head_out: Vec<u8>,
    body_out: Vec<u8>,
    /// Reusable coordinator reply endpoint (channel + recycled
    /// `Response.fixed` buffer); server-agnostic, so one slot serves
    /// every fleet entry this worker ever talks to.
    reply: ReplySlot,
}

fn conn_worker(rx: &Mutex<Receiver<TcpStream>>, target: &Arc<ServeTarget>, cfg: &HttpConfig) {
    let mut conn = ConnBuffers::default();
    conn.buf.resize(4096, 0);
    loop {
        // Only one idle worker blocks in recv() at a time; the handoff
        // itself is brief, so this does not serialize serving.
        let stream = {
            let Ok(guard) = rx.lock() else { break };
            guard.recv()
        };
        match stream {
            Ok(s) => handle_connection(s, &mut conn, target, cfg),
            Err(_) => break, // acceptor gone, queue drained
        }
    }
}

/// What a parsed head routes to, decided before any buffer mutation so
/// the borrowed head can be dropped early. The model-route spec is
/// parsed (and its id copied out) right here for the same reason.
enum Routed {
    Predict,
    PredictModel(Result<RouteSpec, RouteError>),
    Models,
    Reload,
    Metrics,
    Health,
    MethodNotAllowed,
    NotFound,
}

/// Decide where a request goes from its method and path alone.
fn route(method: &str, path: &str) -> Routed {
    match (method, path) {
        ("POST", "/predict") => Routed::Predict,
        ("GET", "/metrics") => Routed::Metrics,
        ("GET", "/healthz") => Routed::Health,
        ("GET", "/models") => Routed::Models,
        ("POST", "/admin/reload") => Routed::Reload,
        (m, p) => {
            if let Some(spec) = p.strip_prefix("/predict/") {
                return if m == "POST" {
                    Routed::PredictModel(RouteSpec::parse(spec))
                } else {
                    Routed::MethodNotAllowed
                };
            }
            match p {
                "/predict" | "/metrics" | "/healthz" | "/models" | "/admin/reload" => {
                    Routed::MethodNotAllowed
                }
                _ => Routed::NotFound,
            }
        }
    }
}

fn handle_connection(
    mut stream: TcpStream,
    conn: &mut ConnBuffers,
    target: &Arc<ServeTarget>,
    cfg: &HttpConfig,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(cfg.keep_alive_timeout));
    let metrics = target.metrics();
    conn.filled = 0;
    let mut t_receipt: Option<Instant> = None;

    loop {
        // Frame one complete request (head + declared body) from the
        // front of the buffer.
        let (routed, keep_alive, body_start, total) =
            match parser::parse_head(&conn.buf[..conn.filled]) {
                Ok(Some(head)) if conn.filled >= head.total_len() => {
                    let routed = route(head.method, head.path);
                    (routed, head.keep_alive, head.head_len, head.total_len())
                }
                Ok(_) => {
                    // Incomplete: read more. Grow (geometrically, capped
                    // by the framing limits) only when full — steady
                    // state never reallocates.
                    if conn.filled == conn.buf.len() {
                        let cap = parser::MAX_HEAD_BYTES + parser::MAX_BODY_BYTES;
                        let new_len = (conn.buf.len() * 2).clamp(4096, cap);
                        conn.buf.resize(new_len, 0);
                    }
                    match stream.read(&mut conn.buf[conn.filled..]) {
                        Ok(0) => return, // peer closed (possibly mid-request)
                        Ok(n) => {
                            if t_receipt.is_none() {
                                t_receipt = Some(Instant::now());
                            }
                            conn.filled += n;
                            continue;
                        }
                        // Idle keep-alive timeout or interrupt: close.
                        Err(_) => return,
                    }
                }
                Err(e) => {
                    // Framing is unknown from here on: answer and close.
                    metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                    let (code, reason) = e.status();
                    respond_error(&mut stream, conn, &metrics, code, reason, &e, t_receipt);
                    return;
                }
            };

        metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        conn.body_out.clear();
        let (code, reason) = match routed {
            Routed::Predict => match &**target {
                ServeTarget::Single(server) => predict_on(server, conn, body_start, total),
                ServeTarget::Fleet { .. } => {
                    render_error_body(
                        &mut conn.body_out,
                        "not_found",
                        &"this server hosts a model fleet; use POST /predict/{model}",
                    );
                    (404, "Not Found")
                }
            },
            Routed::PredictModel(spec) => match &**target {
                ServeTarget::Single(_) => {
                    render_error_body(
                        &mut conn.body_out,
                        "not_found",
                        &"this server pins one model; use POST /predict",
                    );
                    (404, "Not Found")
                }
                ServeTarget::Fleet { registry, .. } => match spec {
                    Err(e) => {
                        render_error_body(&mut conn.body_out, "bad_route_spec", &e);
                        (400, "Bad Request")
                    }
                    Ok(spec) => match registry.resolve(&spec.id, spec.version) {
                        Ok(entry) => predict_on(entry.server(), conn, body_start, total),
                        Err(e) => {
                            render_error_body(&mut conn.body_out, e.kind(), &e);
                            status_for_registry(&e)
                        }
                    },
                },
            },
            Routed::Models => match &**target {
                ServeTarget::Fleet { registry, .. } => {
                    render_models_body(
                        &mut conn.body_out,
                        &registry.models(),
                        registry.tracked_bytes(),
                    );
                    (200, "OK")
                }
                ServeTarget::Single(_) => {
                    render_error_body(
                        &mut conn.body_out,
                        "not_found",
                        &"this server pins one model; no fleet listing",
                    );
                    (404, "Not Found")
                }
            },
            Routed::Reload => match &**target {
                ServeTarget::Fleet { loader: Some(loader), .. } => match loader.reload() {
                    Ok(report) => {
                        render_reload_body(&mut conn.body_out, &report);
                        (200, "OK")
                    }
                    Err(e) => {
                        render_error_body(&mut conn.body_out, "reload_failed", &e);
                        (500, "Internal Server Error")
                    }
                },
                ServeTarget::Fleet { loader: None, .. } => {
                    render_error_body(
                        &mut conn.body_out,
                        "not_implemented",
                        &"no artifact directory attached; the fleet is managed programmatically",
                    );
                    (501, "Not Implemented")
                }
                ServeTarget::Single(_) => {
                    render_error_body(
                        &mut conn.body_out,
                        "not_found",
                        &"this server pins one model; nothing to reload",
                    );
                    (404, "Not Found")
                }
            },
            Routed::Metrics => {
                render_metrics_body(&mut conn.body_out, &metrics.snapshot());
                (200, "OK")
            }
            Routed::Health => {
                conn.body_out.extend_from_slice(b"{\"status\":\"ok\"}");
                (200, "OK")
            }
            Routed::MethodNotAllowed => {
                render_error_body(&mut conn.body_out, "method_not_allowed", &"use the documented method for this path");
                (405, "Method Not Allowed")
            }
            Routed::NotFound => {
                render_error_body(&mut conn.body_out, "not_found", &"unknown path");
                (404, "Not Found")
            }
        };

        render_head(&mut conn.head_out, code, reason, conn.body_out.len(), keep_alive);
        if write_response(&mut stream, &conn.head_out, &conn.body_out).is_err() {
            return;
        }
        metrics.http_responses.fetch_add(1, Ordering::Relaxed);
        if let Some(t0) = t_receipt {
            metrics.record_e2e_us(t0.elapsed().as_secs_f64() * 1e6);
        }

        // Shift the consumed request out; anything left is the next
        // pipelined request, already received — its clock starts now.
        conn.buf.copy_within(total..conn.filled, 0);
        conn.filled -= total;
        t_receipt = (conn.filled > 0).then(Instant::now);
        if !keep_alive {
            return;
        }
    }
}

/// One framed predict body against one server: scan the features out
/// of the request buffer, submit, render the success or typed-error
/// body, and return the HTTP status. Shared by the single-model and
/// fleet routes so both take the identical hot path.
fn predict_on(
    server: &InferenceServer,
    conn: &mut ConnBuffers,
    body_start: usize,
    total: usize,
) -> (u16, &'static str) {
    match scan::extract_features(&conn.buf[body_start..total], &mut conn.features) {
        Err(e) => {
            render_error_body(&mut conn.body_out, e.kind(), &e);
            (400, "Bad Request")
        }
        Ok(()) => {
            // Arity gate *before* slab checkout: rows in the arena are
            // fixed-width, so a wrong-arity body is refused here with
            // the same typed error the coordinator would raise.
            if conn.features.len() != server.n_features() {
                let e = ServeError::WrongFeatureCount {
                    expected: server.n_features(),
                    got: conn.features.len(),
                };
                server.metrics_handle().rejected.fetch_add(1, Ordering::Relaxed);
                render_error_body(&mut conn.body_out, e.kind(), &e);
                return status_for(&e);
            }
            // Zero-copy admission: the parsed row moves into a
            // checked-out slab row (no allocation) and is read in
            // place by batch formation. An exhausted slab sheds,
            // exactly like a full admission queue.
            let Some(mut row) = server.checkout_row() else {
                let e = ServeError::QueueFull;
                render_error_body(&mut conn.body_out, e.kind(), &e);
                return status_for(&e);
            };
            row.copy_from(&conn.features);
            match server.submit_pooled(row, &mut conn.reply) {
                Ok(()) => match conn.reply.recv() {
                    Ok(resp) => {
                        render_predict_body(&mut conn.body_out, &resp);
                        let (code, reason) = (200, "OK");
                        // Recycle the rendered output buffer into the
                        // slot for the next request on this worker.
                        conn.reply.recycle(resp.fixed);
                        (code, reason)
                    }
                    Err(e) => {
                        render_error_body(&mut conn.body_out, e.kind(), &e);
                        status_for(&e)
                    }
                },
                Err(e) => {
                    render_error_body(&mut conn.body_out, e.kind(), &e);
                    status_for(&e)
                }
            }
        }
    }
}

/// Render + send a connection-fatal parse error.
fn respond_error(
    stream: &mut TcpStream,
    conn: &mut ConnBuffers,
    metrics: &crate::coordinator::Metrics,
    code: u16,
    reason: &str,
    err: &HttpError,
    t_receipt: Option<Instant>,
) {
    conn.body_out.clear();
    render_error_body(&mut conn.body_out, error_kind(err), &err.detail());
    render_head(&mut conn.head_out, code, reason, conn.body_out.len(), false);
    if write_response(stream, &conn.head_out, &conn.body_out).is_ok() {
        metrics.http_responses.fetch_add(1, Ordering::Relaxed);
        if let Some(t0) = t_receipt {
            metrics.record_e2e_us(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
}

/// Machine-readable kind for an [`HttpError`] body.
fn error_kind(e: &HttpError) -> &'static str {
    match e {
        HttpError::BadRequest(_) => "bad_request",
        HttpError::HeadersTooLarge => "headers_too_large",
        HttpError::BodyTooLarge => "body_too_large",
        HttpError::Unsupported(_) => "not_implemented",
    }
}

/// HTTP status answering a coordinator [`ServeError`].
pub fn status_for(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::WrongFeatureCount { .. } | ServeError::NonFiniteFeature { .. } => {
            (400, "Bad Request")
        }
        ServeError::QueueFull | ServeError::ShuttingDown => (503, "Service Unavailable"),
        ServeError::DeadlineExceeded => (504, "Gateway Timeout"),
        ServeError::WorkerLost => (500, "Internal Server Error"),
    }
}

/// HTTP status answering a fleet [`RegistryError`].
pub fn status_for_registry(e: &RegistryError) -> (u16, &'static str) {
    match e {
        RegistryError::UnknownModel(_) | RegistryError::UnknownVersion { .. } => {
            (404, "Not Found")
        }
        RegistryError::StaleVersion { .. }
        | RegistryError::RetireCurrent { .. }
        | RegistryError::BadSplit { .. } => (409, "Conflict"),
        RegistryError::Serve(e) => status_for(e),
    }
}

/// Render the `GET /models` body into `out` (appended): the fleet
/// listing plus the total tracked bytes.
pub fn render_models_body(out: &mut Vec<u8>, models: &[ModelInfo], tracked_bytes: u64) {
    let _ = write!(out, "{{\"models\":[");
    for (i, m) in models.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        let _ = write!(
            out,
            "{sep}{{\"id\":\"{}\",\"version\":{},\"n_features\":{},\"resident_bytes\":{},\"retained\":[",
            m.id, m.version, m.n_features, m.resident_bytes
        );
        for (j, v) in m.retained.iter().enumerate() {
            let sep = if j == 0 { "" } else { "," };
            let _ = write!(out, "{sep}{v}");
        }
        match m.split {
            Some((version, percent)) => {
                let _ = write!(out, "],\"split\":{{\"version\":{version},\"percent\":{percent}}}}}");
            }
            None => {
                let _ = write!(out, "],\"split\":null}}");
            }
        }
    }
    let _ = write!(out, "],\"tracked_bytes\":{tracked_bytes}}}");
}

/// Render the `POST /admin/reload` body into `out` (appended).
pub fn render_reload_body(out: &mut Vec<u8>, report: &ReloadReport) {
    let _ = write!(out, "{{\"loaded\":[");
    for (i, (id, version)) in report.loaded.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        let _ = write!(out, "{sep}{{\"id\":\"{id}\",\"version\":{version}}}");
    }
    let _ = write!(out, "],\"unchanged\":{},\"failed\":[", report.unchanged);
    for (i, (file, err)) in report.failed.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        // Error strings may carry quotes; escape the two JSON-breaking
        // characters rather than pulling in a full escaper.
        let err = err.replace('\\', "\\\\").replace('"', "\\\"");
        let _ = write!(out, "{sep}{{\"file\":\"{file}\",\"error\":\"{err}\"}}");
    }
    let _ = write!(out, "]}}");
}

/// Render a response head into `out` (cleared first). Public so the
/// allocation-counting test can drive the exact production path.
pub fn render_head(out: &mut Vec<u8>, code: u16, reason: &str, content_len: usize, keep_alive: bool) {
    out.clear();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let _ = write!(
        out,
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\nContent-Length: {content_len}\r\nConnection: {conn}\r\n\r\n"
    );
}

/// Render the `POST /predict` success body into `out` (appended):
/// `{"class":c,"route":"scalar","fixed":[..],"proba":[..]}` — the
/// probabilities are streamed through [`fixed_to_prob`] without
/// allocating a probability vector.
pub fn render_predict_body(out: &mut Vec<u8>, resp: &Response) {
    let route = match resp.route {
        Route::Scalar => "scalar",
        Route::Xla => "xla",
    };
    let _ = write!(out, "{{\"class\":{},\"route\":\"{}\",\"fixed\":[", resp.class, route);
    for (i, &q) in resp.fixed.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        let _ = write!(out, "{sep}{q}");
    }
    let _ = write!(out, "],\"proba\":[");
    for (i, &q) in resp.fixed.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        let _ = write!(out, "{sep}{}", fixed_to_prob(q));
    }
    let _ = write!(out, "]}}");
}

/// Render a typed error body into `out` (appended):
/// `{"error":"<kind>","detail":"<display>"}`.
pub fn render_error_body(out: &mut Vec<u8>, kind: &str, detail: &dyn std::fmt::Display) {
    let _ = write!(out, "{{\"error\":\"{kind}\",\"detail\":\"{detail}\"}}");
}

/// Render the metrics snapshot as JSON into `out` (appended). Numbers
/// that can be non-finite (percentiles over empty histograms) are
/// clamped to 0 so the document is always valid JSON.
pub fn render_metrics_body(out: &mut Vec<u8>, m: &MetricsSnapshot) {
    fn fin(x: f64) -> f64 {
        if x.is_finite() {
            x
        } else {
            0.0
        }
    }
    let _ = write!(
        out,
        "{{\"requests\":{},\"responses\":{},\"http_requests\":{},\"http_responses\":{}",
        m.requests, m.responses, m.http_requests, m.http_responses
    );
    let _ = write!(
        out,
        ",\"shed\":{},\"expired\":{},\"rejected\":{},\"lost\":{},\"worker_panics\":{},\"worker_restarts\":{},\"degraded\":{}",
        m.shed, m.expired, m.rejected, m.lost, m.worker_panics, m.worker_restarts, m.degraded
    );
    let _ = write!(
        out,
        ",\"model_bytes\":{},\"model_count\":{}",
        m.model_bytes, m.model_count
    );
    let _ = write!(
        out,
        ",\"batches_scalar\":{},\"batches_xla\":{},\"rows_scalar\":{},\"rows_xla\":{}",
        m.batches_scalar, m.batches_xla, m.rows_scalar, m.rows_xla
    );
    let _ = write!(
        out,
        ",\"flush_full\":{},\"flush_deadline\":{},\"flush_ttl\":{},\"flush_drain\":{}",
        m.flush_full, m.flush_deadline, m.flush_ttl, m.flush_drain
    );
    let _ = write!(
        out,
        ",\"latency_mean_us\":{},\"latency_p50_us\":{},\"latency_p99_us\":{}",
        fin(m.latency_mean_us),
        fin(m.latency_p50_us),
        fin(m.latency_p99_us)
    );
    let _ = write!(
        out,
        ",\"e2e_mean_us\":{},\"e2e_p50_us\":{},\"e2e_p99_us\":{}",
        fin(m.e2e_mean_us),
        fin(m.e2e_p50_us),
        fin(m.e2e_p99_us)
    );
    let _ = write!(
        out,
        ",\"mean_batch\":{},\"batch_p50\":{},\"batch_p99\":{}",
        fin(m.mean_batch),
        fin(m.batch_p50),
        fin(m.batch_p99)
    );
    let _ = write!(
        out,
        ",\"batch_latency_mean_us\":{},\"batch_latency_p50_us\":{},\"batch_latency_p99_us\":{}",
        fin(m.batch_latency_mean_us),
        fin(m.batch_latency_p50_us),
        fin(m.batch_latency_p99_us)
    );
    match m.max_batch {
        Some(b) => {
            let _ = write!(out, ",\"max_batch\":{b}");
        }
        None => {
            let _ = write!(out, ",\"max_batch\":null");
        }
    }
    match m.max_batch_delay_us {
        Some(d) => {
            let _ = write!(out, ",\"max_batch_delay_us\":{d}");
        }
        None => {
            let _ = write!(out, ",\"max_batch_delay_us\":null");
        }
    }
    for (name, v) in [("kernel", &m.kernel), ("backend", &m.backend)] {
        match v {
            Some(s) => {
                let _ = write!(out, ",\"{name}\":\"{s}\"");
            }
            None => {
                let _ = write!(out, ",\"{name}\":null");
            }
        }
    }
    match m.threads {
        Some(t) => {
            let _ = write!(out, ",\"threads\":{t}");
        }
        None => {
            let _ = write!(out, ",\"threads\":null");
        }
    }
    let _ = write!(out, ",\"detected_features\":[");
    for (i, f) in m.detected_features.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        let _ = write!(out, "{sep}\"{f}\"");
    }
    let _ = write!(out, "]}}");
}

/// One vectored write of head + body, completed with a write-all loop
/// when the kernel takes less than everything.
fn write_response(stream: &mut TcpStream, head: &[u8], body: &[u8]) -> io::Result<()> {
    let total = head.len() + body.len();
    let mut n = stream.write_vectored(&[IoSlice::new(head), IoSlice::new(body)])?;
    while n < total {
        let m = if n < head.len() { stream.write(&head[n..])? } else { stream.write(&body[n - head.len()..])? };
        if m == 0 {
            return Err(io::ErrorKind::WriteZero.into());
        }
        n += m;
    }
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_renders_exact_http() {
        let mut out = Vec::new();
        render_head(&mut out, 200, "OK", 17, true);
        assert_eq!(
            std::str::from_utf8(&out).unwrap(),
            "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: 17\r\nConnection: keep-alive\r\n\r\n"
        );
        render_head(&mut out, 503, "Service Unavailable", 0, false);
        assert!(out.starts_with(b"HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(out.ends_with(b"Connection: close\r\n\r\n"));
    }

    #[test]
    fn predict_body_streams_fixed_and_proba() {
        let resp = Response {
            fixed: vec![0, u32::MAX],
            class: 1,
            route: Route::Scalar,
            latency: Duration::from_micros(5),
        };
        let mut out = Vec::new();
        render_predict_body(&mut out, &resp);
        let s = std::str::from_utf8(&out).unwrap();
        assert!(s.starts_with("{\"class\":1,\"route\":\"scalar\",\"fixed\":[0,4294967295]"), "{s}");
        assert!(s.contains("\"proba\":[0,"), "{s}");
        assert!(s.ends_with("]}"), "{s}");
    }

    #[test]
    fn every_serve_error_maps_to_a_status() {
        for e in ServeError::ALL {
            let (code, reason) = status_for(&e);
            assert!((400..=599).contains(&code), "{e}: {code}");
            assert!(!reason.is_empty());
        }
        assert_eq!(status_for(&ServeError::QueueFull).0, 503);
        assert_eq!(status_for(&ServeError::DeadlineExceeded).0, 504);
        assert_eq!(status_for(&ServeError::NonFiniteFeature { index: 0 }).0, 400);
    }

    #[test]
    fn metrics_body_is_json_with_the_slo_fields() {
        let m = crate::coordinator::Metrics::new().snapshot();
        let mut out = Vec::new();
        render_metrics_body(&mut out, &m);
        let s = std::str::from_utf8(&out).unwrap();
        assert!(s.starts_with('{') && s.ends_with('}'), "{s}");
        for field in [
            "e2e_p50_us",
            "e2e_p99_us",
            "max_batch_delay_us",
            "flush_ttl",
            "http_requests",
            "model_bytes",
            "model_count",
        ] {
            assert!(s.contains(&format!("\"{field}\"")), "missing {field} in {s}");
        }
    }

    #[test]
    fn fleet_routes_decided_from_method_and_path() {
        assert!(matches!(route("POST", "/predict"), Routed::Predict));
        assert!(matches!(route("GET", "/models"), Routed::Models));
        assert!(matches!(route("POST", "/admin/reload"), Routed::Reload));
        assert!(matches!(route("GET", "/metrics"), Routed::Metrics));
        assert!(matches!(route("POST", "/predict/shuttle"), Routed::PredictModel(Ok(_))));
        match route("POST", "/predict/shuttle@3") {
            Routed::PredictModel(Ok(spec)) => {
                assert_eq!(spec.id, "shuttle");
                assert_eq!(spec.version, Some(3));
            }
            _ => panic!("expected a parsed model route"),
        }
        assert!(matches!(route("POST", "/predict/bad@spec"), Routed::PredictModel(Err(_))));
        assert!(matches!(route("GET", "/predict/shuttle"), Routed::MethodNotAllowed));
        assert!(matches!(route("DELETE", "/models"), Routed::MethodNotAllowed));
        assert!(matches!(route("GET", "/nope"), Routed::NotFound));
    }

    #[test]
    fn models_body_renders_fleet_listing() {
        let models = vec![
            ModelInfo {
                id: "alpha".into(),
                version: 3,
                n_features: 9,
                resident_bytes: 4096,
                retained: vec![1, 2],
                split: Some((2, 30)),
            },
            ModelInfo {
                id: "beta".into(),
                version: 1,
                n_features: 4,
                resident_bytes: 512,
                retained: vec![],
                split: None,
            },
        ];
        let mut out = Vec::new();
        render_models_body(&mut out, &models, 4608);
        let s = std::str::from_utf8(&out).unwrap();
        assert_eq!(
            s,
            "{\"models\":[\
             {\"id\":\"alpha\",\"version\":3,\"n_features\":9,\"resident_bytes\":4096,\
             \"retained\":[1,2],\"split\":{\"version\":2,\"percent\":30}},\
             {\"id\":\"beta\",\"version\":1,\"n_features\":4,\"resident_bytes\":512,\
             \"retained\":[],\"split\":null}],\"tracked_bytes\":4608}"
        );
    }

    #[test]
    fn reload_body_renders_report_and_escapes_errors() {
        let report = ReloadReport {
            loaded: vec![("alpha".into(), 2)],
            unchanged: 3,
            failed: vec![("bad.bin".into(), "said \"no\"".into())],
        };
        let mut out = Vec::new();
        render_reload_body(&mut out, &report);
        let s = std::str::from_utf8(&out).unwrap();
        assert_eq!(
            s,
            "{\"loaded\":[{\"id\":\"alpha\",\"version\":2}],\"unchanged\":3,\
             \"failed\":[{\"file\":\"bad.bin\",\"error\":\"said \\\"no\\\"\"}]}"
        );
    }

    #[test]
    fn registry_errors_map_to_statuses() {
        assert_eq!(status_for_registry(&RegistryError::UnknownModel("x".into())).0, 404);
        assert_eq!(
            status_for_registry(&RegistryError::UnknownVersion { id: "x".into(), version: 2 }).0,
            404
        );
        assert_eq!(
            status_for_registry(&RegistryError::StaleVersion {
                id: "x".into(),
                current: 2,
                offered: 2
            })
            .0,
            409
        );
        assert_eq!(status_for_registry(&RegistryError::Serve(ServeError::QueueFull)).0, 503);
    }
}
