//! InTreeger CLI — the end-to-end framework entrypoint (paper Fig 1):
//! dataset in → trained model → integer-only C out, plus serving,
//! simulation and evaluation utilities.
//!
//! The headline command is `pipeline`: dataset → trained forest →
//! quantized IR → **verified** integer-only C + report, in one
//! invocation. The remaining subcommands expose the individual stages.
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — the offline
//! build has no clap; see `Args`. The usage text is *generated* from the
//! same `COMMANDS` table the dispatcher consults, so help and reality
//! cannot drift (a `tests/cli.rs` test walks the table through
//! `--help`).

use intreeger::codegen::{self, Layout};
use intreeger::coordinator::{self, InferenceServer, ServerConfig};
use intreeger::data::{self, Dataset};
use intreeger::inference::{self, SimdBackend, Variant, BACKEND_ENV, THREADS_ENV};
use intreeger::ir::Model;
use intreeger::net::{HttpConfig, HttpServer};
use intreeger::pipeline::{self, PipelineConfig};
use intreeger::simarch::{self, Core};
use intreeger::trees::{self, ForestParams, GbtParams, RandomForest};
use intreeger::util::Rng;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Minimal `--key value` argument map with typed accessors.
struct Args {
    values: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut values = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{}'", argv[i]))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                values.insert(k.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                values.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { values })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("bad integer flag")).unwrap_or(default)
    }

    fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("bad integer flag")).unwrap_or(default)
    }

    fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("bad float flag")).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

// ---------------------------------------------------------------------------
// Command table: the single source of truth for dispatch AND usage text.
// ---------------------------------------------------------------------------

/// One CLI subcommand: name, flag synopsis (generated at runtime so
/// enumerations like the layout list stay in sync with the code), a
/// one-line description, and the handler.
struct CommandSpec {
    name: &'static str,
    synopsis: fn() -> String,
    about: &'static str,
    run: fn(&Args),
}

fn layout_names() -> String {
    Layout::all().iter().map(|l| l.name()).collect::<Vec<_>>().join("|")
}

fn variant_names() -> String {
    Variant::all().iter().map(|v| v.name()).collect::<Vec<_>>().join("|")
}

fn backend_names() -> String {
    SimdBackend::all().iter().map(|b| b.name()).collect::<Vec<_>>().join("|")
}

/// `--backend NAME` pins the SIMD execution backend for everything this
/// process compiles, by setting [`BACKEND_ENV`] (the same override
/// operators use in deployment). Validated here so a typo fails fast
/// instead of silently falling back.
fn apply_backend_flag(args: &Args) {
    if let Some(name) = args.get("backend") {
        let b = SimdBackend::from_name(name)
            .unwrap_or_else(|| panic!("unknown backend '{name}' (use {})", backend_names()));
        std::env::set_var(BACKEND_ENV, b.name());
    }
}

/// `--threads N` pins the intra-batch thread count for everything this
/// process compiles, by setting [`THREADS_ENV`] (the same override
/// operators use in deployment). Must be a positive integer; counts
/// above the detected cores are clamped loudly by the engines rather
/// than rejected here, matching the env-var behavior.
fn apply_threads_flag(args: &Args) {
    if let Some(raw) = args.get("threads") {
        let n: usize = raw
            .parse()
            .ok()
            .filter(|&n| n > 0)
            .unwrap_or_else(|| panic!("bad --threads '{raw}' (use a positive integer)"));
        std::env::set_var(THREADS_ENV, n.to_string());
    }
}

static COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "pipeline",
        synopsis: || {
            format!(
                "--csv data.csv --out DIR [--target COL] [--header] [--holdout F] [--trees N] \
                 [--depth D] [--gbt] [--no-rf] [--layout {}] [--bench] [--simulate] [--seed S] \
                 [--dataset shuttle|esa --rows N]",
                layout_names()
            )
        },
        about: "dataset -> trained forest -> quantized IR -> verified integer-only C + report",
        run: cmd_pipeline,
    },
    CommandSpec {
        name: "train",
        synopsis: || {
            "--dataset shuttle|esa|csv:PATH [--header] [--rows N] [--trees N] [--depth D] \
             [--gbt] [--seed S] [--out model.json]"
                .to_string()
        },
        about: "train an RF/GBT on a dataset -> model.json",
        run: cmd_train,
    },
    CommandSpec {
        name: "import",
        synopsis: || {
            "--file dump.txt [--format lightgbm|xgboost] [--features N --classes N] \
             [--base-score B] [--out model.json]"
                .to_string()
        },
        about: "import an XGBoost/LightGBM text dump into the IR",
        run: cmd_import,
    },
    CommandSpec {
        name: "codegen",
        synopsis: || {
            format!(
                "--model model.json [--variant {}] [--layout {}] [--out model.c] \
                 [--emit-bin model.bin]",
                variant_names(),
                layout_names()
            )
        },
        about: "generate C from a model (stdout without --out); --emit-bin also writes the INTB binary artifact",
        run: cmd_codegen,
    },
    CommandSpec {
        name: "predict",
        synopsis: || "--model model.json --csv data.csv [--header] [--engine float|flint|int]".to_string(),
        about: "run a model over a CSV and print predictions",
        run: cmd_predict,
    },
    CommandSpec {
        name: "inspect",
        synopsis: || {
            format!("--model model.json [--trees] [--backend {}] [--threads N]", backend_names())
        },
        about: "model stats, QuickScorer eligibility + SIMD/threads calibration preview",
        run: cmd_inspect,
    },
    CommandSpec {
        name: "simulate",
        synopsis: || "--model model.json [--dataset shuttle|esa|csv:PATH] [--rows N]".to_string(),
        about: "per-core cycle estimates for all three variants (Fig 3)",
        run: cmd_simulate,
    },
    CommandSpec {
        name: "serve",
        synopsis: || {
            format!(
                "--model model.json | --pipeline DIR | --bin model.bin [--artifacts DIR] \
                 [--requests N] [--workers W] [--calibrate] [--backend {}] [--threads N] \
                 [--dataset ...]",
                backend_names()
            )
        },
        about: "start the batching server (model file, pipeline bundle, or INTB binary) and run a demo workload",
        run: cmd_serve,
    },
    CommandSpec {
        name: "serve-http",
        synopsis: || {
            format!(
                "--model model.json | --pipeline DIR | --models DIR [--addr HOST:PORT] \
                 [--max-batch N] [--max-batch-delay USEC] [--workers W] [--conn-workers C] \
                 [--queue-depth Q] [--ttl-ms T] [--duration SECS] [--calibrate] \
                 [--backend {}] [--threads N]",
                backend_names()
            )
        },
        about: "serve over HTTP/1.1: one model, or (--models DIR) a hot-swappable versioned fleet",
        run: cmd_serve_http,
    },
    CommandSpec {
        name: "tablei",
        synopsis: String::new,
        about: "print the evaluation-core table (Table I)",
        run: |_| cmd_tablei(),
    },
];

/// Usage text generated from [`COMMANDS`] — the same table `main`
/// dispatches on, so a new subcommand (or flag synopsis change) shows up
/// here by construction.
fn usage() -> String {
    let names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
    let mut s = format!("usage: intreeger <{}> [--flags]\n\n", names.join("|"));
    for c in COMMANDS {
        s.push_str(&format!("  {:<9} {}\n", c.name, c.about));
        let syn = (c.synopsis)();
        if !syn.is_empty() {
            s.push_str(&format!("            intreeger {} {}\n", c.name, syn));
        }
    }
    s.push_str("\n  help      print this text (also --help / -h)\n");
    s
}

// ---------------------------------------------------------------------------
// Handlers
// ---------------------------------------------------------------------------

/// Print a CLI-facing error and exit(1). The library layers return
/// typed errors (`IrError`, `ServeError`, …); the CLI's job is to render
/// them once, at top level, instead of unwinding with a panic backtrace.
fn die(msg: impl std::fmt::Display) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(1);
}

fn load_dataset(args: &Args) -> Dataset {
    let rows = args.usize_or("rows", 8000);
    let seed = args.u64_or("seed", 42);
    match args.get("dataset").unwrap_or("shuttle") {
        "shuttle" => data::shuttle_like(rows, seed),
        "esa" => data::esa_like(rows, seed),
        spec if spec.starts_with("csv:") => {
            data::csv::read_file(Path::new(&spec[4..]), args.flag("header"))
                .expect("failed to read csv dataset")
        }
        other => panic!("unknown dataset '{other}' (use shuttle | esa | csv:PATH)"),
    }
}

fn load_model(args: &Args) -> Model {
    let path = args.get("model").unwrap_or_else(|| die("--model PATH required"));
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| die(format!("cannot read model file '{path}': {e}")));
    Model::from_json(&text)
        .unwrap_or_else(|e| die(format!("invalid model file '{path}': {e}")))
}

fn parse_variant(s: &str) -> Variant {
    if s == "int" {
        return Variant::IntTreeger; // shorthand kept for muscle memory
    }
    Variant::from_name(s)
        .unwrap_or_else(|| panic!("unknown variant '{s}' (use {} | int)", variant_names()))
}

fn parse_layout(s: &str) -> Layout {
    Layout::from_name(s)
        .unwrap_or_else(|| panic!("unknown layout '{s}' (use {})", layout_names()))
}

fn cmd_pipeline(args: &Args) {
    let out = PathBuf::from(args.get("out").expect("--out DIR required"));
    let cfg = PipelineConfig {
        holdout_frac: args.f64_or("holdout", 0.25),
        seed: args.u64_or("seed", 42),
        train_rf: !args.flag("no-rf"),
        train_gbt: args.flag("gbt"),
        n_trees: args.usize_or("trees", 10),
        max_depth: args.usize_or("depth", 6),
        layout: parse_layout(args.get("layout").unwrap_or("ifelse")),
        bench: args.flag("bench"),
        simulate: args.flag("simulate"),
        source: String::new(), // filled below
    };
    let result = match args.get("csv") {
        Some(path) => {
            pipeline::run_csv(Path::new(path), args.flag("header"), args.get("target"), &out, &cfg)
        }
        None => {
            // Synthetic fallback so the quickstart works with zero files.
            let mut cfg = cfg;
            cfg.source = format!("synthetic:{}", args.get("dataset").unwrap_or("shuttle"));
            let ds = load_dataset(args);
            pipeline::run(&ds, &out, &cfg)
        }
    };
    match result {
        Ok(outcome) => {
            let r = &outcome.report;
            eprintln!(
                "pipeline PASS: {} model(s) verified on {} holdout rows; artifacts in {}",
                r.models.len(),
                r.dataset.holdout_rows,
                outcome.out_dir.display()
            );
            for m in &r.models {
                eprintln!(
                    "  {}: accuracy float {:.4} / int {:.4}, max fixed-point error {:.3e} \
                     (bound {:.3e}){}",
                    m.kind,
                    m.parity.accuracy_float,
                    m.parity.accuracy_int,
                    m.parity.max_abs_error,
                    m.parity.error_bound,
                    match &m.codegen {
                        Some(c) => format!(", C: {} ({} bytes)", c.file, c.bytes),
                        None => String::new(),
                    }
                );
            }
            eprintln!("  report: {} / REPORT.md", outcome.out_dir.join("report.json").display());
        }
        Err(e) => {
            eprintln!("pipeline FAILED: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_train(args: &Args) {
    let ds = load_dataset(args);
    let seed = args.u64_or("seed", 42);
    let mut rng = Rng::new(seed ^ 0x5117);
    let (train, test) = ds.train_test_split(0.25, &mut rng);
    let model = if args.flag("gbt") {
        trees::train_gbt(
            &train,
            &GbtParams {
                n_rounds: args.usize_or("trees", 10),
                max_depth: args.usize_or("depth", 4),
                ..Default::default()
            },
            seed,
        )
    } else {
        RandomForest::train(
            &train,
            &ForestParams {
                n_trees: args.usize_or("trees", 10),
                max_depth: args.usize_or("depth", 8),
                ..Default::default()
            },
            seed,
        )
    };
    let acc = trees::accuracy(&model, &test);
    let stats = intreeger::ir::stats::stats(&model);
    eprintln!(
        "trained {} trees, {} nodes, depth {}; holdout accuracy {:.4}",
        stats.n_trees, stats.n_nodes, stats.max_depth, acc
    );
    let out = args.get("out").unwrap_or("model.json");
    std::fs::write(out, model.to_json()).expect("write model");
    eprintln!("wrote {out}");
}

fn cmd_import(args: &Args) {
    let path = args.get("file").unwrap_or_else(|| die("--file PATH required"));
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| die(format!("cannot read dump file '{path}': {e}")));
    let model = match args.get("format").unwrap_or("lightgbm") {
        "lightgbm" => intreeger::ir::import::lightgbm::import(&text)
            .unwrap_or_else(|e| die(format!("lightgbm import of '{path}' failed: {e}"))),
        "xgboost" => {
            let nf = args.usize_or("features", 0);
            let nc = args.usize_or("classes", 2);
            if nf == 0 {
                die("--features N required for xgboost dumps");
            }
            let base = args
                .get("base-score")
                .map(|v| v.parse::<f32>().unwrap_or_else(|_| die("bad --base-score")))
                .unwrap_or(0.0);
            intreeger::ir::import::xgboost::import(&text, nf, nc, base)
                .unwrap_or_else(|e| die(format!("xgboost import of '{path}' failed: {e}")))
        }
        other => die(format!("unknown format '{other}' (use lightgbm | xgboost)")),
    };
    let stats = intreeger::ir::stats::stats(&model);
    eprintln!(
        "imported {} trees, {} nodes, {} classes, {} features",
        stats.n_trees, stats.n_nodes, model.n_classes, model.n_features
    );
    let out = args.get("out").unwrap_or("model.json");
    std::fs::write(out, model.to_json()).expect("write model");
    eprintln!("wrote {out}");
}

fn cmd_codegen(args: &Args) {
    let model = load_model(args);
    let variant = parse_variant(args.get("variant").unwrap_or("intreeger"));
    let layout = parse_layout(args.get("layout").unwrap_or("ifelse"));
    let src = codegen::generate(&model, layout, variant);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &src).expect("write C file");
            eprintln!(
                "wrote {path} ({} bytes, variant {}, layout {})",
                src.len(),
                variant.name(),
                layout.name()
            );
        }
        None if args.get("emit-bin").is_none() => print!("{src}"),
        None => {} // binary-only emission: keep stdout clean
    }
    if let Some(path) = args.get("emit-bin") {
        let bytes = intreeger::runtime::binfmt::write_model(&model);
        std::fs::write(path, &bytes).expect("write binary artifact");
        eprintln!(
            "wrote {path} ({} bytes, INTB v{}; zero-copy loadable via serve --bin / serve-http --models)",
            bytes.len(),
            intreeger::runtime::binfmt::VERSION
        );
    }
}

/// Load an INTB binary artifact into a ready integer engine plus its
/// resident-bytes figure and load-path tag (`"mmap"` on unix,
/// `"owned-copy"` otherwise or on a refused mapping). All binary-format
/// failures are typed [`BinError`](intreeger::runtime::BinError)s
/// rendered once, here.
fn load_bin_engine(path: &str) -> (intreeger::inference::IntEngine, u64, &'static str) {
    let file = intreeger::runtime::FileBin::open(Path::new(path))
        .unwrap_or_else(|e| die(format!("cannot read binary model '{path}': {e}")));
    let view = file
        .view()
        .unwrap_or_else(|e| die(format!("invalid binary model '{path}': {e}")));
    let forest = view.to_forest().unwrap_or_else(|e| {
        die(format!("'{path}': {e} (serving needs an RF artifact: probability leaves feed the u32 engine)"))
    });
    let resident = view.resident_bytes() as u64;
    (intreeger::inference::IntEngine::from_forest(forest), resident, file.source())
}

fn cmd_predict(args: &Args) {
    let model = load_model(args);
    let csv_path = args.get("csv").expect("--csv PATH required");
    let ds = data::csv::read_file(Path::new(csv_path), args.flag("header")).expect("read csv");
    let engine = inference::engines::compile_variant(
        &model,
        parse_variant(args.get("engine").unwrap_or("intreeger")),
    );
    let mut correct = 0usize;
    for i in 0..ds.n_rows() {
        let pred = engine.predict(ds.row(i));
        println!("{pred}");
        if pred == ds.labels[i] {
            correct += 1;
        }
    }
    eprintln!(
        "accuracy vs labels in file: {:.4}",
        correct as f64 / ds.n_rows().max(1) as f64
    );
}

fn cmd_simulate(args: &Args) {
    let model = load_model(args);
    let ds = load_dataset(args);
    println!("core,variant,instructions,cycles,ipc,us_per_inference");
    for core in Core::all() {
        for v in Variant::all() {
            let r = simarch::simulate(&model, &ds, v, core, 300);
            println!(
                "{},{},{:.1},{:.1},{:.3},{:.3}",
                core.name(),
                v.name(),
                r.instructions,
                r.cycles,
                r.ipc(),
                r.seconds() * 1e6
            );
        }
    }
}

fn cmd_serve(args: &Args) {
    apply_backend_flag(args);
    apply_threads_flag(args);
    let config = ServerConfig {
        n_workers: args.usize_or("workers", 1),
        auto_calibrate: args.flag("calibrate"),
        ..ServerConfig::default()
    };
    // Boot from an INTB binary artifact, a pipeline bundle (model +
    // holdout in one dir), or an explicit model file.
    if let Some(bin) = args.get("bin") {
        let (engine, resident, source) = load_bin_engine(bin);
        let server = InferenceServer::start_with_engine(engine, config);
        let demo = load_dataset(args);
        if demo.n_features != server.n_features() {
            die(format!(
                "demo rows have {} features but the binary model expects {}",
                demo.n_features,
                server.n_features()
            ));
        }
        eprintln!(
            "(binary artifact: {resident} resident bytes, zero-copy sections via {source}; scalar route)"
        );
        run_serve_demo(args, server, demo);
        return;
    }
    let (server, demo): (InferenceServer, Dataset) = match args.get("pipeline") {
        Some(dir) => {
            let dir = PathBuf::from(dir);
            let (server, model) = coordinator::server_from_pipeline(&dir, config)
                .unwrap_or_else(|e| {
                    die(format!("cannot boot from pipeline bundle '{}': {e}", dir.display()))
                });
            // Demo traffic: the bundle's own holdout, falling back to a
            // synthetic set with the model's arity.
            let demo = data::csv::read_file(&dir.join("holdout.csv"), false)
                .unwrap_or_else(|_| load_dataset(args));
            if demo.n_features != model.n_features {
                die(format!(
                    "demo rows have {} features but the model expects {}",
                    demo.n_features, model.n_features
                ));
            }
            (server, demo)
        }
        None => {
            let model = load_model(args);
            let ds = load_dataset(args);
            let artifacts = args
                .get("artifacts")
                .map(PathBuf::from)
                .or_else(|| Some(PathBuf::from("artifacts")))
                .filter(|p| intreeger::runtime::artifacts_available(p));
            if artifacts.is_none() {
                eprintln!("(artifacts not found — scalar route only)");
            }
            (InferenceServer::start(&model, artifacts, config), ds)
        }
    };
    run_serve_demo(args, server, demo);
}

/// The `serve` demo workload + outcome report, shared by every boot
/// path (model file, pipeline bundle, INTB binary).
fn run_serve_demo(args: &Args, server: InferenceServer, demo: Dataset) {
    let n = args.usize_or("requests", 1000);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| demo.row(i % demo.n_rows()).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = server.infer_many(rows);
    let wall = t0.elapsed();
    let snap = server.metrics();
    // Every submitted request resolves — as a Response or a typed
    // ServeError (shed/expired/lost) — so ok + failed always equals n.
    let ok = responses.iter().filter(|r| r.is_ok()).count();
    println!(
        "served {n} requests in {:.1} ms ({:.0} req/s)",
        wall.as_secs_f64() * 1e3,
        n as f64 / wall.as_secs_f64()
    );
    println!(
        "outcomes: {ok} ok / {} failed; shed {} expired {} rejected {} lost {}; \
         worker panics {} restarts {}{}",
        n - ok,
        snap.shed,
        snap.expired,
        snap.rejected,
        snap.lost,
        snap.worker_panics,
        snap.worker_restarts,
        if snap.degraded { " (DEGRADED: serving on the fallback scalar engine)" } else { "" }
    );
    println!(
        "routes: scalar {} rows / xla {} rows; mean batch {:.1}; latency p50 {:.0} us p99 {:.0} us",
        snap.rows_scalar, snap.rows_xla, snap.mean_batch, snap.latency_p50_us, snap.latency_p99_us
    );
    println!(
        "execution: kernel {} on the {} backend with {} intra-batch thread(s) (host SIMD: {})",
        snap.kernel.as_deref().unwrap_or("?"),
        snap.backend.as_deref().unwrap_or("?"),
        snap.threads.map(|t| t.to_string()).unwrap_or_else(|| "?".to_string()),
        if snap.detected_features.is_empty() {
            "none".to_string()
        } else {
            snap.detected_features.join(", ")
        }
    );
}

/// `serve-http`: boot the coordinator (model file or pipeline bundle,
/// same resolution as `serve`) and put the zero-copy HTTP/1.1 front end
/// in front of it. `--duration SECS` serves for a bounded window and
/// prints an outcome summary on exit (CI smoke and benchmarks);
/// without it the server runs until killed.
fn cmd_serve_http(args: &Args) {
    use std::io::Write as _;
    apply_backend_flag(args);
    apply_threads_flag(args);
    let defaults = coordinator::BatchPolicy::default();
    let policy = coordinator::BatchPolicy {
        max_batch: args.usize_or("max-batch", defaults.max_batch),
        max_wait: Duration::from_micros(
            args.u64_or("max-batch-delay", defaults.max_wait.as_micros() as u64),
        ),
    };
    let config = ServerConfig {
        policy,
        n_workers: args.usize_or("workers", 1),
        queue_depth: args.usize_or("queue-depth", ServerConfig::default().queue_depth),
        auto_calibrate: args.flag("calibrate"),
        default_ttl: args
            .get("ttl-ms")
            .map(|v| Duration::from_millis(v.parse().expect("bad --ttl-ms (use milliseconds)"))),
        ..ServerConfig::default()
    };
    let http_config = HttpConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:8080").to_string(),
        conn_workers: args.usize_or("conn-workers", 4),
        ..HttpConfig::default()
    };
    if let Some(models_dir) = args.get("models") {
        serve_http_fleet(args, models_dir, config, http_config);
        return;
    }
    let server = match args.get("pipeline") {
        Some(dir) => {
            let dir = PathBuf::from(dir);
            let (server, _model) =
                coordinator::server_from_pipeline(&dir, config).unwrap_or_else(|e| {
                    die(format!("cannot boot from pipeline bundle '{}': {e}", dir.display()))
                });
            server
        }
        None => {
            let model = load_model(args);
            let artifacts = args
                .get("artifacts")
                .map(PathBuf::from)
                .or_else(|| Some(PathBuf::from("artifacts")))
                .filter(|p| intreeger::runtime::artifacts_available(p));
            InferenceServer::start(&model, artifacts, config)
        }
    };
    let server = Arc::new(server);
    let http = HttpServer::start(Arc::clone(&server), http_config)
        .unwrap_or_else(|e| die(format!("cannot bind HTTP listener: {e}")));
    println!(
        "intreeger serve-http: listening on http://{} (POST /predict, GET /metrics, GET /healthz)",
        http.local_addr()
    );
    println!(
        "policy: max_batch {}, max_batch_delay {} us; {} coordinator worker(s), {} connection worker(s)",
        server.metrics().max_batch.unwrap_or(0),
        server.metrics().max_batch_delay_us.unwrap_or(0),
        args.usize_or("workers", 1),
        args.usize_or("conn-workers", 4),
    );
    // Make the listening lines visible to pipes immediately (stdout is
    // block-buffered when not a tty; CI tails the log while curling).
    let _ = std::io::stdout().flush();
    let duration = args.u64_or("duration", 0);
    if duration == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration));
    drop(http); // join acceptor + connection workers before summarizing
    let snap = server.metrics();
    println!(
        "outcomes: http {} requests / {} responses; coordinator {} ok; shed {} expired {} rejected {} lost {}",
        snap.http_requests,
        snap.http_responses,
        snap.responses,
        snap.shed,
        snap.expired,
        snap.rejected,
        snap.lost
    );
    println!(
        "e2e latency: mean {:.0} us, p50 {:.0} us, p99 {:.0} us; flushes full {} deadline {} ttl {} drain {}",
        snap.e2e_mean_us,
        snap.e2e_p50_us,
        snap.e2e_p99_us,
        snap.flush_full,
        snap.flush_deadline,
        snap.flush_ttl,
        snap.flush_drain
    );
}

/// `serve-http --models DIR`: boot the versioned fleet. Every `*.bin` /
/// `*.json` artifact in DIR is published under its file stem at version
/// 1; `POST /admin/reload` rescans the directory and hot-swaps changed
/// files with a bumped version while in-flight requests drain on the
/// version that admitted them.
fn serve_http_fleet(args: &Args, models_dir: &str, config: ServerConfig, http_config: HttpConfig) {
    use std::io::Write as _;
    let metrics = Arc::new(coordinator::Metrics::new());
    let registry = Arc::new(coordinator::ModelRegistry::new(metrics));
    let loader =
        Arc::new(coordinator::FleetLoader::new(models_dir, Arc::clone(&registry), config));
    let report = loader
        .reload()
        .unwrap_or_else(|e| die(format!("cannot scan models dir '{models_dir}': {e}")));
    for (id, v) in &report.loaded {
        eprintln!("published {id}@{v}");
    }
    for (file, err) in &report.failed {
        eprintln!("skipped {file}: {err}");
    }
    if registry.ids().is_empty() {
        die(format!("no servable models in '{models_dir}' (need RF *.bin or *.json artifacts)"));
    }
    let http = HttpServer::start_fleet(Arc::clone(&registry), Some(loader), http_config)
        .unwrap_or_else(|e| die(format!("cannot bind HTTP listener: {e}")));
    println!(
        "intreeger serve-http: fleet of {} model(s) on http://{} \
         (POST /predict/{{model}}, GET /models, POST /admin/reload, GET /metrics)",
        registry.ids().len(),
        http.local_addr()
    );
    // Make the listening line visible to pipes immediately (CI tails
    // the log while curling).
    let _ = std::io::stdout().flush();
    let duration = args.u64_or("duration", 0);
    if duration == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration));
    drop(http); // join acceptor + connection workers before summarizing
    let snap = registry.metrics().snapshot();
    println!(
        "outcomes: http {} requests / {} responses; coordinator {} ok; \
         fleet {} resident model version(s), {} resident bytes",
        snap.http_requests, snap.http_responses, snap.responses, snap.model_count, snap.model_bytes
    );
}

fn cmd_tablei() {
    print!("{}", simarch::cores::table_i());
}

/// Model statistics with QuickScorer eligibility (shows *why* a model
/// did or did not take the bitvector fast path) plus the host's SIMD
/// features and the execution strategy calibration would pick for this
/// model here — the per-machine half of a perf delta.
fn cmd_inspect(args: &Args) {
    use intreeger::inference::QS_MAX_LEAVES;
    apply_backend_flag(args);
    apply_threads_flag(args);
    let model = load_model(args);
    let s = intreeger::ir::stats::stats(&model);
    println!("kind:            {:?}", model.kind);
    println!("features:        {}", model.n_features);
    println!("classes:         {}", model.n_classes);
    println!(
        "trees:           {} ({} nodes: {} branches + {} leaves)",
        s.n_trees, s.n_nodes, s.n_branches, s.n_leaves
    );
    println!(
        "depth:           max {}, mean leaf depth {:.2}",
        s.max_depth, s.mean_leaf_depth
    );
    println!("min leaf prob:   {:e} (nonzero)", s.min_nonzero_leaf_prob);
    println!(
        "quickscorer:     {}/{} trees eligible (<= {QS_MAX_LEAVES} leaves per u64 mask)",
        s.qs_eligible_trees, s.n_trees
    );
    if s.qs_ineligible.is_empty() {
        println!("                 whole forest takes the bitvector fast path");
    } else {
        println!(
            "                 fallback to the branchless walker: trees {:?}",
            s.qs_ineligible
        );
    }
    let feats = SimdBackend::detected_features();
    println!(
        "simd:            host features [{}]; backends available [{}]; default {}",
        feats.join(", "),
        SimdBackend::available().iter().map(|b| b.name()).collect::<Vec<_>>().join(", "),
        SimdBackend::resolve().name()
    );
    let (pref, basis) = inference::parallel::preferred();
    println!(
        "cores:           {} logical{}; default intra-batch threads {}; \
         calibration sweeps to {pref} {basis} cores",
        inference::parallel::detected(),
        match inference::parallel::physical_cores() {
            Some(p) => format!(" / {p} physical"),
            None => String::new(),
        },
        inference::parallel::resolve()
    );
    // Cache topology and the placement serving would apply under
    // INTREEGER_PIN=1 — printed unconditionally so "no topology" hosts
    // are visible too.
    match inference::parallel::llc_groups() {
        Some(groups) => {
            let rendered: Vec<String> = groups
                .iter()
                .map(|g| {
                    let ids: Vec<String> = g.iter().map(|c| c.to_string()).collect();
                    format!("[{}]", ids.join(","))
                })
                .collect();
            println!("topology:        {} LLC group(s): {}", groups.len(), rendered.join(" "));
        }
        None => println!("topology:        LLC groups unavailable (no sysfs cache index)"),
    }
    match inference::parallel::pin_plan(inference::parallel::preferred().0) {
        Some(plan) => println!(
            "                 pin plan ({} basis, {}=1 to apply): cpus {:?}",
            plan.basis,
            inference::parallel::PIN_ENV,
            plan.cpus
        ),
        None => println!(
            "                 pin plan unavailable ({}=1 would be a loud no-op)",
            inference::parallel::PIN_ENV
        ),
    }
    if model.kind == intreeger::ir::ModelKind::RandomForest {
        // Run the serving coordinator's actual startup calibration on a
        // representative probe batch: the same timing that decides the
        // execution strategy at `serve --calibrate` time.
        let mut engine = inference::IntEngine::compile(&model);
        let choice = coordinator::calibrate_execution(&mut engine, model.n_features, 256);
        println!(
            "calibration:     would pick {} @ {} @ {}t for this model on this host (256-row probe)",
            choice.kernel.name(),
            choice.backend.name(),
            choice.threads
        );
    } else {
        println!("calibration:     (serving calibration targets RF models; GBT uses the defaults)");
    }
    if args.flag("trees") {
        println!("per-tree:");
        for (i, (tree, &leaves)) in model.trees.iter().zip(&s.leaf_counts).enumerate() {
            println!(
                "  tree {i:>3}: {:>5} nodes, {:>4} leaves, depth {:>2}  {}",
                tree.nodes.len(),
                leaves,
                tree.depth(),
                if leaves <= QS_MAX_LEAVES {
                    "qs-eligible".to_string()
                } else {
                    format!("walker fallback (> {QS_MAX_LEAVES} leaves)")
                }
            );
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{}", usage());
        std::process::exit(2);
    };
    // `--help`/`-h` win anywhere on the line: `intreeger pipeline
    // --help` must print usage, not dispatch cmd_pipeline (which would
    // panic on the missing --out) or, worse, silently run a training
    // job. Bare `help` only counts in command position — it could
    // legitimately appear as a flag *value*.
    if cmd == "help" || argv.iter().any(|a| matches!(a.as_str(), "--help" | "-h")) {
        print!("{}", usage());
        return;
    }
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", usage());
            std::process::exit(2);
        }
    };
    match COMMANDS.iter().find(|c| c.name == cmd.as_str()) {
        Some(c) => (c.run)(&args),
        None => {
            eprintln!("unknown command '{cmd}'\n{}", usage());
            std::process::exit(2);
        }
    }
}
