//! InTreeger CLI — the end-to-end framework entrypoint (paper Fig 1):
//! dataset in → trained model → integer-only C out, plus serving,
//! simulation and evaluation utilities.
//!
//! Subcommands:
//!   train     train an RF/GBT on a dataset (synthetic or CSV) → model.json
//!   codegen   generate integer-only (or float/flint) C from a model
//!   predict   run a model over a CSV and print predictions
//!   simulate  per-core cycle estimates for all three variants (Fig 3)
//!   serve     start the batching server and run a demo workload
//!   tablei    print the evaluation-core table (Table I)
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — the offline
//! build has no clap; see `Args`.

use intreeger::codegen::{self, Layout};
use intreeger::coordinator::{InferenceServer, ServerConfig};
use intreeger::data::{self, Dataset};
use intreeger::inference::{self, Variant};
use intreeger::ir::Model;
use intreeger::simarch::{self, Core};
use intreeger::trees::{self, ForestParams, GbtParams, RandomForest};
use intreeger::util::Rng;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Minimal `--key value` argument map with typed accessors.
struct Args {
    values: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut values = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{}'", argv[i]))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                values.insert(k.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                values.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { values })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("bad integer flag")).unwrap_or(default)
    }

    fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect("bad integer flag")).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

fn load_dataset(args: &Args) -> Dataset {
    let rows = args.usize_or("rows", 8000);
    let seed = args.u64_or("seed", 42);
    match args.get("dataset").unwrap_or("shuttle") {
        "shuttle" => data::shuttle_like(rows, seed),
        "esa" => data::esa_like(rows, seed),
        spec if spec.starts_with("csv:") => {
            data::csv::read_file(Path::new(&spec[4..]), args.flag("header"))
                .expect("failed to read csv dataset")
        }
        other => panic!("unknown dataset '{other}' (use shuttle | esa | csv:PATH)"),
    }
}

fn load_model(args: &Args) -> Model {
    let path = args.get("model").expect("--model PATH required");
    let text = std::fs::read_to_string(path).expect("cannot read model file");
    Model::from_json(&text).expect("invalid model file")
}

fn cmd_train(args: &Args) {
    let ds = load_dataset(args);
    let seed = args.u64_or("seed", 42);
    let mut rng = Rng::new(seed ^ 0x5117);
    let (train, test) = ds.train_test_split(0.25, &mut rng);
    let model = if args.flag("gbt") {
        trees::train_gbt(
            &train,
            &GbtParams {
                n_rounds: args.usize_or("trees", 10),
                max_depth: args.usize_or("depth", 4),
                ..Default::default()
            },
            seed,
        )
    } else {
        RandomForest::train(
            &train,
            &ForestParams {
                n_trees: args.usize_or("trees", 10),
                max_depth: args.usize_or("depth", 8),
                ..Default::default()
            },
            seed,
        )
    };
    let acc = trees::accuracy(&model, &test);
    let stats = intreeger::ir::stats::stats(&model);
    eprintln!(
        "trained {} trees, {} nodes, depth {}; holdout accuracy {:.4}",
        stats.n_trees, stats.n_nodes, stats.max_depth, acc
    );
    let out = args.get("out").unwrap_or("model.json");
    std::fs::write(out, model.to_json()).expect("write model");
    eprintln!("wrote {out}");
}

fn parse_variant(s: &str) -> Variant {
    match s {
        "float" => Variant::Float,
        "flint" => Variant::FlInt,
        "intreeger" | "int" => Variant::IntTreeger,
        other => panic!("unknown variant '{other}'"),
    }
}

fn cmd_import(args: &Args) {
    let path = args.get("file").expect("--file PATH required");
    let text = std::fs::read_to_string(path).expect("cannot read dump file");
    let model = match args.get("format").unwrap_or("lightgbm") {
        "lightgbm" => intreeger::ir::import::lightgbm::import(&text).expect("lightgbm import"),
        "xgboost" => {
            let nf = args.usize_or("features", 0);
            let nc = args.usize_or("classes", 2);
            assert!(nf > 0, "--features N required for xgboost dumps");
            let base = args
                .get("base-score")
                .map(|v| v.parse::<f32>().expect("bad base-score"))
                .unwrap_or(0.0);
            intreeger::ir::import::xgboost::import(&text, nf, nc, base).expect("xgboost import")
        }
        other => panic!("unknown format '{other}' (use lightgbm | xgboost)"),
    };
    let stats = intreeger::ir::stats::stats(&model);
    eprintln!(
        "imported {} trees, {} nodes, {} classes, {} features",
        stats.n_trees, stats.n_nodes, model.n_classes, model.n_features
    );
    let out = args.get("out").unwrap_or("model.json");
    std::fs::write(out, model.to_json()).expect("write model");
    eprintln!("wrote {out}");
}

fn cmd_codegen(args: &Args) {
    let model = load_model(args);
    let variant = parse_variant(args.get("variant").unwrap_or("intreeger"));
    let layout = match args.get("layout").unwrap_or("ifelse") {
        "ifelse" => Layout::IfElse,
        "native" => Layout::Native,
        "native-predicated" => Layout::NativePredicated,
        "quickscorer" => Layout::QuickScorer,
        other => panic!("unknown layout '{other}'"),
    };
    let src = codegen::generate(&model, layout, variant);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &src).expect("write C file");
            eprintln!(
                "wrote {path} ({} bytes, variant {}, layout {})",
                src.len(),
                variant.name(),
                layout.name()
            );
        }
        None => print!("{src}"),
    }
}

fn cmd_predict(args: &Args) {
    let model = load_model(args);
    let csv_path = args.get("csv").expect("--csv PATH required");
    let ds = data::csv::read_file(Path::new(csv_path), args.flag("header")).expect("read csv");
    let engine = inference::engines::compile_variant(
        &model,
        parse_variant(args.get("engine").unwrap_or("intreeger")),
    );
    let mut correct = 0usize;
    for i in 0..ds.n_rows() {
        let pred = engine.predict(ds.row(i));
        println!("{pred}");
        if pred == ds.labels[i] {
            correct += 1;
        }
    }
    eprintln!(
        "accuracy vs labels in file: {:.4}",
        correct as f64 / ds.n_rows().max(1) as f64
    );
}

fn cmd_simulate(args: &Args) {
    let model = load_model(args);
    let ds = load_dataset(args);
    println!("core,variant,instructions,cycles,ipc,us_per_inference");
    for core in Core::all() {
        for v in Variant::all() {
            let r = simarch::simulate(&model, &ds, v, core, 300);
            println!(
                "{},{},{:.1},{:.1},{:.3},{:.3}",
                core.name(),
                v.name(),
                r.instructions,
                r.cycles,
                r.ipc(),
                r.seconds() * 1e6
            );
        }
    }
}

fn cmd_serve(args: &Args) {
    let model = load_model(args);
    let ds = load_dataset(args);
    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .or_else(|| Some(PathBuf::from("artifacts")))
        .filter(|p| intreeger::runtime::artifacts_available(p));
    if artifacts.is_none() {
        eprintln!("(artifacts not found — scalar route only)");
    }
    let config = ServerConfig {
        n_workers: args.usize_or("workers", 1),
        auto_calibrate: args.flag("calibrate"),
        ..ServerConfig::default()
    };
    let server = InferenceServer::start(&model, artifacts, config);
    let n = args.usize_or("requests", 1000);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = server.infer_many(rows);
    let wall = t0.elapsed();
    let snap = server.metrics();
    println!(
        "served {n} requests in {:.1} ms ({:.0} req/s)",
        wall.as_secs_f64() * 1e3,
        n as f64 / wall.as_secs_f64()
    );
    println!(
        "routes: scalar {} rows / xla {} rows; mean batch {:.1}; latency p50 {:.0} us p99 {:.0} us",
        snap.rows_scalar, snap.rows_xla, snap.mean_batch, snap.latency_p50_us, snap.latency_p99_us
    );
    let _ = responses;
}

fn cmd_tablei() {
    print!("{}", simarch::cores::table_i());
}

/// Model statistics with QuickScorer eligibility: shows *why* a model
/// did or did not take the bitvector fast path.
fn cmd_inspect(args: &Args) {
    use intreeger::inference::QS_MAX_LEAVES;
    let model = load_model(args);
    let s = intreeger::ir::stats::stats(&model);
    println!("kind:            {:?}", model.kind);
    println!("features:        {}", model.n_features);
    println!("classes:         {}", model.n_classes);
    println!(
        "trees:           {} ({} nodes: {} branches + {} leaves)",
        s.n_trees, s.n_nodes, s.n_branches, s.n_leaves
    );
    println!(
        "depth:           max {}, mean leaf depth {:.2}",
        s.max_depth, s.mean_leaf_depth
    );
    println!("min leaf prob:   {:e} (nonzero)", s.min_nonzero_leaf_prob);
    println!(
        "quickscorer:     {}/{} trees eligible (<= {QS_MAX_LEAVES} leaves per u64 mask)",
        s.qs_eligible_trees, s.n_trees
    );
    if s.qs_ineligible.is_empty() {
        println!("                 whole forest takes the bitvector fast path");
    } else {
        println!(
            "                 fallback to the branchless walker: trees {:?}",
            s.qs_ineligible
        );
    }
    if args.flag("trees") {
        println!("per-tree:");
        for (i, (tree, &leaves)) in model.trees.iter().zip(&s.leaf_counts).enumerate() {
            println!(
                "  tree {i:>3}: {:>5} nodes, {:>4} leaves, depth {:>2}  {}",
                tree.nodes.len(),
                leaves,
                tree.depth(),
                if leaves <= QS_MAX_LEAVES {
                    "qs-eligible".to_string()
                } else {
                    format!("walker fallback (> {QS_MAX_LEAVES} leaves)")
                }
            );
        }
    }
}

const USAGE: &str = "usage: intreeger <train|import|codegen|predict|inspect|simulate|serve|tablei> [--flags]\n\
  train    --dataset shuttle|esa|csv:PATH [--rows N] [--trees N] [--depth D] [--gbt] [--seed S] [--out model.json]\n\
  import   --file dump.txt [--format lightgbm|xgboost] [--features N --classes N] [--out model.json]\n\
  codegen  --model model.json [--variant float|flint|intreeger] [--layout ifelse|native|native-predicated|quickscorer] [--out model.c]\n\
  predict  --model model.json --csv data.csv [--engine float|flint|int]\n\
  inspect  --model model.json [--trees]   (stats + per-tree QuickScorer eligibility)\n\
  simulate --model model.json [--dataset ...]\n\
  serve    --model model.json [--artifacts DIR] [--requests N] [--workers W] [--calibrate]\n\
  tablei\n";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "import" => cmd_import(&args),
        "codegen" => cmd_codegen(&args),
        "predict" => cmd_predict(&args),
        "inspect" => cmd_inspect(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "tablei" => cmd_tablei(),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
