//! Arena-owned feature-row slab for the serving admission path.
//!
//! The HTTP scanner used to clone its per-connection feature arena
//! into a fresh `Vec<f32>` at admission — the one documented heap
//! allocation left on the request hot path. This module removes it:
//! the server owns one fixed `rows × row_len` f32 arena plus a
//! free-list of row indices, admission checks out a row handle and
//! copies the parsed features straight into the slab, and the handle
//! rides inside the queued `Request` instead of an owned vector.
//! Batch formation reads the row in place; dropping the handle (on
//! *any* resolution path — responded, shed, expired, or lost to a
//! worker panic) pushes the index back onto the free-list, so the
//! slab can never leak rows while the chaos accounting identity
//! `requests == responses + expired + lost` holds.
//!
//! Concurrency contract: the free-list is the exclusivity token. A
//! checked-out index is owned by exactly one [`SlabRow`] until its
//! `Drop` returns it, so writes through [`SlabRow::copy_from`] and
//! reads through [`SlabRow::as_slice`] never alias another live
//! handle's row. This is the same disjoint-ownership argument the
//! intra-batch pool's `SharedSlab` makes for tile outputs, expressed
//! here with `UnsafeCell` storage instead of raw pointers. Checkout
//! **never blocks and never allocates**: an exhausted slab returns
//! `None` and the caller sheds the request (typed `QueueFull`), and
//! the free-list vector is pre-sized to hold every index so push/pop
//! never reallocate.

use std::cell::UnsafeCell;
use std::sync::Mutex;

use super::lock_unpoisoned;

/// Fixed arena of feature rows with a free-list of row handles.
///
/// Sized once at server start (`rows` of `row_len` f32 each) and
/// shared behind an `Arc`; see the module docs for the ownership
/// contract that makes the interior mutability sound.
pub struct FeatureSlab {
    /// Row storage; cell interior-mutable because disjoint checked-out
    /// rows are written without a storage-wide lock.
    storage: Box<[UnsafeCell<f32>]>,
    row_len: usize,
    /// Indices currently available for checkout. Pre-sized to `rows`
    /// capacity, so returning a row never allocates.
    free: Mutex<Vec<u32>>,
}

// SAFETY: the free-list is the exclusivity token — a given row index
// is reachable through exactly one live `SlabRow` at a time, so
// cross-thread access to `storage` is always to disjoint rows (see
// module docs).
unsafe impl Send for FeatureSlab {}
unsafe impl Sync for FeatureSlab {}

impl FeatureSlab {
    /// Build a slab of `rows` rows of `row_len` features each.
    pub fn new(rows: usize, row_len: usize) -> FeatureSlab {
        assert!(row_len > 0, "slab rows must be at least one feature wide");
        let storage: Box<[UnsafeCell<f32>]> =
            (0..rows * row_len).map(|_| UnsafeCell::new(0.0)).collect();
        let mut free = Vec::with_capacity(rows);
        // Hand out low indices first: reverse order so pop() starts at 0.
        for i in (0..rows as u32).rev() {
            free.push(i);
        }
        FeatureSlab { storage, row_len, free: Mutex::new(free) }
    }

    /// Features per row (the server's `n_features`).
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Total rows the slab holds.
    pub fn rows(&self) -> usize {
        if self.row_len == 0 { 0 } else { self.storage.len() / self.row_len }
    }

    /// Rows currently available for checkout (diagnostic; racy by
    /// nature, exact only when no checkouts are in flight).
    pub fn available(&self) -> usize {
        lock_unpoisoned(&self.free).len()
    }

    /// Check a row out of the free-list, or `None` when the slab is
    /// exhausted. Never blocks, never allocates — exhaustion is the
    /// caller's shed signal. Takes the `Arc` (an associated function,
    /// since `&Arc<Self>` is not a valid method receiver) so the
    /// returned handle can keep the slab alive independently of the
    /// server that owns it.
    pub fn checkout(slab: &std::sync::Arc<FeatureSlab>) -> Option<SlabRow> {
        let index = lock_unpoisoned(&slab.free).pop()?;
        Some(SlabRow { slab: std::sync::Arc::clone(slab), index })
    }

    /// Return a row index to the free-list (handle `Drop` path).
    fn give_back(&self, index: u32) {
        let mut free = lock_unpoisoned(&self.free);
        debug_assert!(!free.contains(&index), "slab row {index} returned twice");
        debug_assert!(free.len() < free.capacity(), "slab free-list overflow");
        free.push(index);
    }
}

/// Exclusive handle to one checked-out slab row. Dropping the handle
/// returns the row to the free-list, on every resolution path.
pub struct SlabRow {
    slab: std::sync::Arc<FeatureSlab>,
    index: u32,
}

impl SlabRow {
    /// Copy a parsed feature row into the slab. `src.len()` must equal
    /// the slab's `row_len` (the admission arity check runs first).
    pub fn copy_from(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.slab.row_len, "slab row width mismatch");
        let base = self.index as usize * self.slab.row_len;
        for (i, &v) in src.iter().enumerate() {
            // SAFETY: this handle exclusively owns row `index` until
            // Drop (free-list contract), so no other reference to
            // these cells exists.
            unsafe { *self.slab.storage[base + i].get() = v };
        }
    }

    /// The row contents, read in place (batch formation's view).
    pub fn as_slice(&self) -> &[f32] {
        let base = self.index as usize * self.slab.row_len;
        // SAFETY: exclusive ownership of the row (free-list contract)
        // means no concurrent writer; the cast only covers this row's
        // cells, which are plain f32s.
        unsafe {
            std::slice::from_raw_parts(
                self.slab.storage[base].get() as *const f32,
                self.slab.row_len,
            )
        }
    }
}

impl Drop for SlabRow {
    fn drop(&mut self) {
        self.slab.give_back(self.index);
    }
}

impl std::fmt::Debug for SlabRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SlabRow").field("index", &self.index).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn checkout_copy_read_and_return() {
        let slab = Arc::new(FeatureSlab::new(2, 3));
        assert_eq!(slab.rows(), 2);
        assert_eq!(slab.available(), 2);
        let mut row = FeatureSlab::checkout(&slab).expect("fresh slab has rows");
        row.copy_from(&[1.0, 2.0, 3.0]);
        assert_eq!(row.as_slice(), &[1.0, 2.0, 3.0]);
        assert_eq!(slab.available(), 1);
        drop(row);
        assert_eq!(slab.available(), 2);
    }

    #[test]
    fn exhaustion_returns_none_without_blocking() {
        let slab = Arc::new(FeatureSlab::new(1, 2));
        let held = FeatureSlab::checkout(&slab).expect("one row available");
        assert!(FeatureSlab::checkout(&slab).is_none(), "exhausted slab must shed");
        drop(held);
        assert!(FeatureSlab::checkout(&slab).is_some(), "returned row is reusable");
    }

    #[test]
    fn rows_are_disjoint_across_handles() {
        let slab = Arc::new(FeatureSlab::new(2, 2));
        let mut a = FeatureSlab::checkout(&slab).unwrap();
        let mut b = FeatureSlab::checkout(&slab).unwrap();
        a.copy_from(&[1.0, 1.0]);
        b.copy_from(&[2.0, 2.0]);
        assert_eq!(a.as_slice(), &[1.0, 1.0]);
        assert_eq!(b.as_slice(), &[2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "slab row width mismatch")]
    fn width_mismatch_panics() {
        let slab = Arc::new(FeatureSlab::new(1, 3));
        FeatureSlab::checkout(&slab).unwrap().copy_from(&[0.0]);
    }

    #[test]
    fn concurrent_checkout_return_cycles_never_leak() {
        let slab = Arc::new(FeatureSlab::new(8, 4));
        std::thread::scope(|s| {
            for t in 0..4 {
                let slab = Arc::clone(&slab);
                s.spawn(move || {
                    for i in 0..500 {
                        if let Some(mut row) = FeatureSlab::checkout(&slab) {
                            let v = (t * 1000 + i) as f32;
                            row.copy_from(&[v; 4]);
                            assert_eq!(row.as_slice(), &[v; 4]);
                        }
                    }
                });
            }
        });
        assert_eq!(slab.available(), 8, "all rows must return to the free-list");
    }
}
