//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a *script* of failures — worker panics on specific
//! batch sequence numbers, artificial per-batch service latency, forced
//! queue-full rejections on the first N submissions — that the
//! [`super::server`] consults at well-defined points. The plan is plain
//! data (cloneable, comparable); the server materializes it into a
//! [`Faults`] injector holding the monotone sequence counters, shared by
//! every worker shard.
//!
//! Determinism is the whole point: the chaos suite (`tests/chaos.rs`)
//! asserts serving invariants (no lost reply, no hang, surviving results
//! bit-identical to a fault-free run) under *reproducible* failures. A
//! plan has no randomness — injection triggers on exact global sequence
//! numbers, so the same plan against the same request stream (with one
//! worker shard) fails the same batch every run. With several shards the
//! *set* of injected faults is still exact (the counters are global and
//! atomic); only which shard draws a given sequence number varies.
//!
//! Plans come from two places:
//! * programmatically — [`ServerConfig::faults`](super::ServerConfig)
//!   (tests, benches);
//! * the [`FAULTS_ENV`] environment variable (`INTREEGER_FAULTS`) — for
//!   injecting faults into an unmodified binary (the CI chaos leg pins
//!   plans this way). Format: `;`- or `,`-separated directives:
//!   `panic_batch=N` (repeatable; 1-indexed executed-batch sequence
//!   numbers that panic mid-execution), `latency_us=N` (added to every
//!   batch's service time), `queue_full_n=N` (the first N submissions
//!   are refused with `QueueFull`). Malformed directives are reported
//!   loudly on stderr and skipped — an operator typo must not take the
//!   server down (loud-never-panic, the same contract as the backend and
//!   threads overrides).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Environment variable holding a fault plan for the serving stack
/// (see the module docs for the directive syntax).
pub const FAULTS_ENV: &str = "INTREEGER_FAULTS";

/// A deterministic failure script (plain data; see the module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// 1-indexed global batch sequence numbers whose execution panics
    /// (simulating a crash in the kernel / engine path).
    pub panic_batches: Vec<u64>,
    /// Artificial latency added to every batch's execution.
    pub latency: Option<Duration>,
    /// The first N submissions are refused as `QueueFull` (simulating a
    /// saturated admission queue regardless of actual depth).
    pub queue_full_first: u64,
}

impl FaultPlan {
    /// The empty plan: no faults injected.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.panic_batches.is_empty() && self.latency.is_none() && self.queue_full_first == 0
    }

    /// Parse the `INTREEGER_FAULTS` directive syntax. Unknown or
    /// malformed directives are returned as errors; [`Self::from_env`]
    /// downgrades them to loud warnings.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::none();
        for tok in text.split([';', ',']).map(str::trim).filter(|t| !t.is_empty()) {
            let (key, value) = tok
                .split_once('=')
                .ok_or_else(|| format!("fault directive '{tok}' is not key=value"))?;
            let n: u64 = value
                .trim()
                .parse()
                .map_err(|e| format!("fault directive '{tok}': {e}"))?;
            match key.trim() {
                "panic_batch" => plan.panic_batches.push(n),
                "latency_us" => plan.latency = Some(Duration::from_micros(n)),
                "queue_full_n" => plan.queue_full_first = n,
                other => return Err(format!("unknown fault directive '{other}'")),
            }
        }
        plan.panic_batches.sort_unstable();
        plan.panic_batches.dedup();
        Ok(plan)
    }

    /// Read the plan from [`FAULTS_ENV`]; unset means no faults.
    /// Malformed plans are reported on stderr and treated as empty
    /// (loud-never-panic).
    pub fn from_env() -> FaultPlan {
        match std::env::var(FAULTS_ENV) {
            Ok(text) => match Self::parse(&text) {
                Ok(plan) => {
                    if !plan.is_empty() {
                        eprintln!("intreeger-server: fault injection ACTIVE ({FAULTS_ENV}={text})");
                    }
                    plan
                }
                Err(e) => {
                    eprintln!("intreeger-server: ignoring malformed {FAULTS_ENV}: {e}");
                    FaultPlan::none()
                }
            },
            Err(_) => FaultPlan::none(),
        }
    }
}

/// The runtime injector: a [`FaultPlan`] plus the global sequence
/// counters. One per server, shared (behind an `Arc`) by the admission
/// path and every worker shard.
#[derive(Debug, Default)]
pub struct Faults {
    plan: FaultPlan,
    /// Batches that have *started* executing, across all shards.
    batches: AtomicU64,
    /// Submissions admitted or shed so far.
    submits: AtomicU64,
}

impl Faults {
    /// Materialize a plan into an injector with zeroed counters.
    pub fn new(plan: FaultPlan) -> Faults {
        Faults { plan, ..Faults::default() }
    }

    /// The plan this injector runs.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Admission-time hook: returns true when this submission must be
    /// refused as `QueueFull` (counted against `queue_full_first`).
    pub fn inject_queue_full(&self) -> bool {
        if self.plan.queue_full_first == 0 {
            return false; // fast path: skip the counter
        }
        let seq = self.submits.fetch_add(1, Ordering::Relaxed) + 1;
        seq <= self.plan.queue_full_first
    }

    /// Execution-time hook, called *inside* the shard's catch_unwind
    /// region: sleeps the scripted latency, then panics if this batch's
    /// global 1-indexed sequence number is in `panic_batches`.
    pub fn on_batch_execution(&self) {
        if self.plan.latency.is_none() && self.plan.panic_batches.is_empty() {
            return; // fast path: no counter traffic on the hot path
        }
        let seq = self.batches.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(d) = self.plan.latency {
            std::thread::sleep(d);
        }
        if self.plan.panic_batches.binary_search(&seq).is_ok() {
            panic!("injected fault: worker panic on batch #{seq}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_plan() {
        let p = FaultPlan::parse("panic_batch=3;latency_us=250,panic_batch=1;queue_full_n=5")
            .unwrap();
        assert_eq!(p.panic_batches, vec![1, 3]); // sorted + deduped
        assert_eq!(p.latency, Some(Duration::from_micros(250)));
        assert_eq!(p.queue_full_first, 5);
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_empty_and_whitespace() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; , ").unwrap().is_empty());
        assert!(FaultPlan::none().is_empty());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(FaultPlan::parse("panic_batch").is_err());
        assert!(FaultPlan::parse("panic_batch=x").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("latency_us=-5").is_err());
    }

    #[test]
    fn queue_full_injection_counts_down() {
        let f = Faults::new(FaultPlan { queue_full_first: 2, ..FaultPlan::none() });
        assert!(f.inject_queue_full());
        assert!(f.inject_queue_full());
        assert!(!f.inject_queue_full());
        assert!(!f.inject_queue_full());
        // The empty plan never injects and never touches the counter.
        let quiet = Faults::new(FaultPlan::none());
        for _ in 0..10 {
            assert!(!quiet.inject_queue_full());
        }
        assert_eq!(quiet.submits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn batch_panic_fires_on_exact_sequence_numbers() {
        let f = Faults::new(FaultPlan { panic_batches: vec![2], ..FaultPlan::none() });
        f.on_batch_execution(); // batch 1: fine
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.on_batch_execution() // batch 2: scripted panic
        }));
        assert!(r.is_err());
        f.on_batch_execution(); // batch 3: fine again
    }

    #[test]
    fn latency_injection_sleeps() {
        let f = Faults::new(FaultPlan {
            latency: Some(Duration::from_millis(5)),
            ..FaultPlan::none()
        });
        let t0 = std::time::Instant::now();
        f.on_batch_execution();
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn env_roundtrip_formats() {
        // The exact strings the CI chaos leg pins.
        for (text, check) in [
            ("latency_us=500", FaultPlan {
                latency: Some(Duration::from_micros(500)),
                ..FaultPlan::none()
            }),
            ("queue_full_n=3", FaultPlan { queue_full_first: 3, ..FaultPlan::none() }),
            ("panic_batch=1;panic_batch=2", FaultPlan {
                panic_batches: vec![1, 2],
                ..FaultPlan::none()
            }),
        ] {
            assert_eq!(FaultPlan::parse(text).unwrap(), check, "{text}");
        }
    }
}
