//! The inference server: dynamic batching over two execution backends,
//! drained by a sharded pool of *supervised* worker threads.
//!
//! Requests are round-robin sharded across `n_workers` worker threads;
//! each worker owns a [`Batcher`] and drains its own channel, so
//! scalar-route throughput scales with cores. Flushed batches run
//! through the **tiled batch kernel** ([`IntEngine::predict_fixed_batch`])
//! rather than a per-row loop; batches at/above `xla_threshold` go to
//! the AOT-compiled XLA/PJRT Pallas engine instead (shard 0 only — the
//! xla handles are not `Send`, and one compiled executable per process
//! is enough). Both backends emit bit-identical u32 fixed-point
//! accumulators, so the route is an implementation detail (asserted by
//! integration tests).
//!
//! # Failure model
//!
//! Every submitted request **resolves** — with a [`Response`] or a typed
//! [`ServeError`] — and never panics the caller:
//!
//! * **Admission control**: [`InferenceServer::submit`] validates the
//!   row (arity, finiteness), then `try_send`s into the shard channel.
//!   A full channel *sheds* the request ([`ServeError::QueueFull`])
//!   instead of blocking the caller — under overload the server
//!   protects the latency of admitted work and refuses the rest. The
//!   blocking conveniences ([`InferenceServer::infer`] /
//!   [`InferenceServer::infer_many`]) are closed-loop clients: they
//!   absorb transient `QueueFull` with a bounded retry so existing
//!   all-answered semantics hold, and surface every other error.
//! * **Deadlines**: a per-request TTL ([`ServerConfig::default_ttl`] or
//!   [`InferenceServer::submit_with_ttl`]) is checked at batch-formation
//!   time; rows whose deadline passed before execution resolve as
//!   [`ServeError::DeadlineExceeded`] without burning kernel time.
//! * **Shard supervision**: batch execution runs under `catch_unwind`.
//!   A panicking execution path answers every in-flight request of that
//!   batch with [`ServeError::WorkerLost`], then the shard's supervisor
//!   restarts the worker loop with bounded exponential backoff. After
//!   [`DEGRADE_AFTER`] execution failures the shard *degrades*: it swaps
//!   to a pre-compiled scalar-branchless single-thread engine (the most
//!   conservative execution strategy, bit-identical by the parity
//!   invariant) and records the degraded flag in [`Metrics`].
//! * **Fault injection**: a deterministic [`FaultPlan`]
//!   ([`ServerConfig::faults`] or the `INTREEGER_FAULTS` env) scripts
//!   worker panics, added service latency, and forced queue-full, which
//!   is how `tests/chaos.rs` proves the above without flaky sleeps.

use super::batcher::{BatchPolicy, Batcher, FlushReason};
use super::faults::{FaultPlan, Faults};
use super::lock_unpoisoned;
use super::metrics::Metrics;
use super::slab::{FeatureSlab, SlabRow};
use crate::inference::{IntEngine, SimdBackend, TraversalKernel};
use crate::ir::{argmax, Model};
use crate::runtime::PjrtEngine;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Execution failures a shard tolerates before degrading to the
/// conservative fallback engine (scalar-branchless, one thread).
pub const DEGRADE_AFTER: u32 = 2;

/// Why a request could not be served. Every variant is a *resolution*:
/// the caller always gets an answer, never a hang or a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The submitted row's length does not match the model.
    WrongFeatureCount {
        /// The model's feature count.
        expected: usize,
        /// The submitted row's length.
        got: usize,
    },
    /// A feature value is NaN or infinite.
    NonFiniteFeature {
        /// Index of the first offending value.
        index: usize,
    },
    /// The admission queue is full; the request was shed (load
    /// shedding under overload, or a scripted fault).
    QueueFull,
    /// The request's TTL expired before its batch executed.
    DeadlineExceeded,
    /// The worker shard serving the request crashed; the request was
    /// answered by the supervisor, not executed.
    WorkerLost,
    /// The server is shutting down and no longer admits work.
    ShuttingDown,
}

impl ServeError {
    /// One representative instance of every variant (payload-carrying
    /// variants use zeroed payloads) — the exhaustiveness anchor for
    /// round-trip tests and error tables.
    pub const ALL: [ServeError; 6] = [
        ServeError::WrongFeatureCount { expected: 0, got: 0 },
        ServeError::NonFiniteFeature { index: 0 },
        ServeError::QueueFull,
        ServeError::DeadlineExceeded,
        ServeError::WorkerLost,
        ServeError::ShuttingDown,
    ];

    /// Stable machine-readable name of the variant (payloads ignored).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::WrongFeatureCount { .. } => "wrong_feature_count",
            ServeError::NonFiniteFeature { .. } => "non_finite_feature",
            ServeError::QueueFull => "queue_full",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::WorkerLost => "worker_lost",
            ServeError::ShuttingDown => "shutting_down",
        }
    }

    /// Inverse of [`Self::kind`] up to payloads: returns the
    /// representative instance whose kind matches.
    pub fn from_kind(kind: &str) -> Option<ServeError> {
        ServeError::ALL.iter().copied().find(|e| e.kind() == kind)
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::WrongFeatureCount { expected, got } => {
                write!(f, "wrong feature count: expected {expected}, got {got}")
            }
            ServeError::NonFiniteFeature { index } => {
                write!(f, "non-finite feature value at index {index}")
            }
            ServeError::QueueFull => write!(f, "request shed: admission queue full"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::WorkerLost => write!(f, "worker shard lost while serving the request"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request resolves to: a [`Response`] or a typed
/// [`ServeError`]. Never neither — the chaos suite's core invariant.
pub type ServeResult = Result<Response, ServeError>;

/// The feature payload a queued request carries: an owned vector (the
/// legacy `submit` path and the blocking helpers) or a checked-out
/// arena slab row (the zero-copy [`InferenceServer::submit_pooled`]
/// path — batch formation reads the row in place and the handle
/// returns to the slab free-list when the request resolves, on every
/// path: responded, shed, expired, or lost).
enum RowPayload {
    Owned(Vec<f32>),
    Slab(SlabRow),
}

impl RowPayload {
    fn as_slice(&self) -> &[f32] {
        match self {
            RowPayload::Owned(v) => v,
            RowPayload::Slab(r) => r.as_slice(),
        }
    }
}

/// An inference request: one feature row.
pub struct Request {
    /// The feature row to classify (owned or slab-resident).
    row: RowPayload,
    /// Reusable output buffer traveling with the request: the worker
    /// fills it with the row's fixed-point accumulators and sends it
    /// back as `Response.fixed`; pooled callers recycle it through
    /// their [`ReplySlot`], so steady-state pooled requests allocate
    /// nothing on resolution either.
    fixed_buf: Vec<u32>,
    tx: SyncSender<ServeResult>,
    t_arrival: Instant,
    /// Absolute deadline; past it the request resolves as
    /// `DeadlineExceeded` instead of executing.
    deadline: Option<Instant>,
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// The batched scalar (tiled-kernel) route.
    Scalar,
    /// The AOT-compiled XLA/PJRT route.
    Xla,
}

/// An inference response: the integer-only result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Response {
    /// Fixed-point class accumulators (scale 2^32/n_trees).
    pub fixed: Vec<u32>,
    /// argmax class.
    pub class: u32,
    /// Backend that served the request.
    pub route: Route,
    /// End-to-end latency (arrival to response).
    pub latency: Duration,
}

/// A connection-lifetime reply endpoint for the pooled admission path:
/// one reusable rendezvous channel plus a recycled `Response.fixed`
/// buffer. Creating the channel once per connection (instead of once
/// per request) and recycling the output buffer through
/// [`Self::recycle`] is what makes the pooled request loop
/// allocation-free in steady state. The contract is strict
/// alternation: [`InferenceServer::submit_pooled`] then
/// [`Self::recv`], never two outstanding submissions on one slot.
pub struct ReplySlot {
    tx: SyncSender<ServeResult>,
    rx: Receiver<ServeResult>,
    spare: Vec<u32>,
}

impl ReplySlot {
    /// Fresh slot with an empty recycled buffer (the buffer gains its
    /// steady-state capacity on the first response).
    pub fn new() -> ReplySlot {
        let (tx, rx) = sync_channel(1);
        ReplySlot { tx, rx, spare: Vec::new() }
    }

    /// Block until the outstanding pooled request resolves. A dropped
    /// resolution (impossible while the server honors its
    /// every-request-resolves invariant) maps to `WorkerLost`.
    pub fn recv(&self) -> ServeResult {
        self.rx.recv().unwrap_or(Err(ServeError::WorkerLost))
    }

    /// Hand a rendered `Response.fixed` buffer back so the next request
    /// submitted through this slot reuses its capacity.
    pub fn recycle(&mut self, mut fixed: Vec<u32>) {
        fixed.clear();
        self.spare = fixed;
    }

    fn take_fixed(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.spare)
    }

    fn sender(&self) -> SyncSender<ServeResult> {
        self.tx.clone()
    }

    /// Drop any stale resolution left by a caller that broke the
    /// alternation contract, so `recv` can never read an old result.
    fn clear_stale(&self) {
        while self.rx.try_recv().is_ok() {}
    }
}

impl Default for ReplySlot {
    fn default() -> Self {
        ReplySlot::new()
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dynamic-batching policy applied per worker shard.
    pub policy: BatchPolicy,
    /// Batches of at least this many rows go to the XLA engine.
    pub xla_threshold: usize,
    /// Total channel capacity (admission bound), split across workers.
    /// A full shard channel **sheds** (`ServeError::QueueFull`) instead
    /// of blocking the submitter.
    pub queue_depth: usize,
    /// Measure alternative execution strategies at startup and keep the
    /// fastest:
    /// 1. the scalar route's traversal kernel **×** SIMD backend —
    ///    branchy early-exit vs the predicated branchless descent vs the
    ///    QuickScorer bitvector evaluation, each under every detected
    ///    backend (scalar / AVX2 / NEON; `INTREEGER_BACKEND` pins the
    ///    sweep) — is timed on the loaded model (deep, early-exiting
    ///    trees can favor branchy; shallow balanced trees favor
    ///    branchless; wide QS-eligible forests at big batches favor
    ///    quickscorer; gather-friendly hosts favor AVX2), and the winner
    ///    is recorded in the metrics snapshot, and
    /// 2. the XLA route is disabled when the batched scalar kernel beats
    ///    it at the full policy batch size. On a single CPU core the
    ///    padded batched artifact usually loses to the tiled scalar
    ///    kernel (see `cargo bench --bench serve_throughput`); on a real
    ///    accelerator it wins — this flag makes the router honest either
    ///    way.
    ///
    /// Every candidate produces bit-identical results (the batch module's
    /// parity invariant), so calibration is invisible to clients.
    pub auto_calibrate: bool,
    /// Worker threads draining the (sharded) request queue. The scalar
    /// batched route scales near-linearly with workers; the XLA offload
    /// rides shard 0 only. Clamped to at least 1.
    pub n_workers: usize,
    /// TTL applied to requests submitted without an explicit one
    /// ([`InferenceServer::submit_with_ttl`] overrides per request).
    /// `None` means requests never expire.
    pub default_ttl: Option<Duration>,
    /// Deterministic fault script for chaos testing. `None` consults the
    /// `INTREEGER_FAULTS` environment variable; `Some(FaultPlan::none())`
    /// pins faults off regardless of environment.
    pub faults: Option<FaultPlan>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            xla_threshold: 16,
            queue_depth: 1024,
            auto_calibrate: false,
            n_workers: 1,
            default_ttl: None,
            faults: None,
        }
    }
}

enum Msg {
    Infer(Request),
    Shutdown,
}

/// Handle to a running inference server (clone freely behind an `Arc`).
pub struct InferenceServer {
    txs: Vec<SyncSender<Msg>>,
    next_shard: AtomicUsize,
    metrics: Arc<Metrics>,
    n_features: usize,
    workers: Vec<JoinHandle<()>>,
    shutting_down: AtomicBool,
    default_ttl: Option<Duration>,
    faults: Arc<Faults>,
    /// Arena of feature rows backing the pooled admission path; sized
    /// to cover the full queue depth plus in-execution batches, so
    /// exhaustion only happens past the point where admission would
    /// shed anyway.
    slab: Arc<FeatureSlab>,
}

/// A shard's execution state: the shared calibrated engine, the
/// conservative fallback it degrades to, and the failure count driving
/// that decision. Lives in the shard's supervisor so it survives worker
/// restarts — degradation is per shard lifetime, not per incarnation.
struct ShardExec {
    primary: Arc<IntEngine>,
    /// Scalar-branchless @ 1 thread: the execution strategy with the
    /// fewest moving parts (no SIMD dispatch, no thread pool), used
    /// after repeated primary-path failures. Bit-identical to the
    /// primary by the parity invariant.
    fallback: Arc<IntEngine>,
    exec_failures: u32,
    degraded: bool,
}

impl ShardExec {
    fn engine(&self) -> &IntEngine {
        if self.degraded {
            &self.fallback
        } else {
            &self.primary
        }
    }

    fn record_failure(&mut self, metrics: &Metrics) {
        self.exec_failures += 1;
        if !self.degraded && self.exec_failures >= DEGRADE_AFTER {
            self.degraded = true;
            metrics.degraded.store(true, Ordering::Relaxed);
            use crate::inference::Engine as _;
            metrics.record_execution(
                self.fallback.kernel().name(),
                self.fallback.backend().name(),
                self.fallback.threads(),
            );
            eprintln!(
                "intreeger-server: shard DEGRADED to {}@{}@{}t after {} execution failures",
                self.fallback.kernel().name(),
                self.fallback.backend().name(),
                self.fallback.threads(),
                self.exec_failures
            );
        }
    }
}

impl InferenceServer {
    /// Start a server for `model`. `artifacts_dir` is optional: without
    /// it (or when no tier fits) every batch takes the scalar route.
    ///
    /// The PJRT engine is constructed *inside* worker thread 0: the
    /// xla crate's handles are not `Send`, so the whole XLA object graph
    /// must live and die on the thread that uses it.
    pub fn start(
        model: &Model,
        artifacts_dir: Option<std::path::PathBuf>,
        config: ServerConfig,
    ) -> InferenceServer {
        // One compiled forest shared by every worker (read-only walks).
        let scalar_engine = IntEngine::compile(model);
        // The degradation target, pre-compiled while the process is
        // healthy: scalar backend, branchless kernel, one thread.
        let fallback = IntEngine::compile(model);
        let xla_seed = artifacts_dir.map(|dir| (dir, model.clone()));
        Self::start_inner(scalar_engine, fallback, xla_seed, config)
    }

    /// Start a server around an **already-compiled** engine — the
    /// binary-artifact path ([`crate::runtime::binfmt`]): the forest was
    /// materialized by pointer-cast + validation, there is no IR
    /// [`Model`] in hand, and the XLA route (which packs from IR) is
    /// simply absent. Everything else — sharding, supervision,
    /// degradation, calibration — behaves exactly as [`Self::start`].
    pub fn start_with_engine(engine: IntEngine, config: ServerConfig) -> InferenceServer {
        let fallback = IntEngine::from_forest(engine.forest().clone());
        Self::start_inner(engine, fallback, None, config)
    }

    /// Shared tail of [`Self::start`] / [`Self::start_with_engine`]:
    /// calibrate, arm the fallback, spawn the supervised shard pool.
    fn start_inner(
        mut scalar_engine: IntEngine,
        mut fallback: IntEngine,
        xla_seed: Option<(std::path::PathBuf, Model)>,
        config: ServerConfig,
    ) -> InferenceServer {
        use crate::inference::Engine as _;
        let n_workers = config.n_workers.max(1);
        let n_features = scalar_engine.n_features();
        let metrics = Arc::new(Metrics::new());
        metrics.record_policy(config.policy.max_batch, config.policy.max_wait.as_micros() as u64);
        // The execution strategy (tile-walk kernel × SIMD backend) is
        // calibrated once, before sharing: the choice is per *model*
        // (tree shape) and per *host* (CPU features), not per worker.
        if config.auto_calibrate {
            calibrate_execution(&mut scalar_engine, n_features, config.policy.max_batch);
        }
        // Record the execution strategy actually serving (calibrated
        // or compile-time default) so the metrics snapshot — and
        // anything built on it — can explain per-machine deltas.
        metrics.record_execution(
            scalar_engine.kernel().name(),
            scalar_engine.backend().name(),
            scalar_engine.threads(),
        );
        let scalar = Arc::new(scalar_engine);
        // Arm the degradation target: the execution strategy with the
        // fewest moving parts (no SIMD dispatch, no thread pool).
        fallback.set_kernel(TraversalKernel::Branchless);
        fallback.set_backend(SimdBackend::Scalar);
        fallback.set_threads(1);
        let fallback = Arc::new(fallback);
        let faults =
            Arc::new(Faults::new(config.faults.clone().unwrap_or_else(FaultPlan::from_env)));
        let per_worker_depth = (config.queue_depth / n_workers).max(1);
        // Cache-topology-aware placement (opt-in, INTREEGER_PIN=1):
        // each shard thread pins itself to a distinct physical core
        // inside one LLC group, so a shard's engine tables and slab
        // rows stay resident in a single cache domain. `None` (gate
        // off, or no usable topology — complained about loudly once)
        // leaves every shard wherever the scheduler puts it.
        let pin_plan = crate::inference::parallel::active_pin_plan(n_workers).map(Arc::new);

        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Msg>(per_worker_depth);
            txs.push(tx);
            let scalar = Arc::clone(&scalar);
            let fallback = Arc::clone(&fallback);
            let m2 = Arc::clone(&metrics);
            let f2 = Arc::clone(&faults);
            let config = config.clone();
            let pin_plan = pin_plan.clone();
            // Only shard 0 needs the model (to pack the XLA artifact).
            let seed = if w == 0 { xla_seed.clone() } else { None };
            let worker = std::thread::Builder::new()
                .name(format!("intreeger-server-{w}"))
                .spawn(move || {
                    if let Some(plan) = &pin_plan {
                        plan.pin(w);
                    }
                    let xla: Option<PjrtEngine> = seed.and_then(|(dir, model)| {
                        if !crate::runtime::artifacts_available(&dir) {
                            return None;
                        }
                        // Ask for a tier that can hold a full policy batch, so
                        // the XLA route is actually usable at max batch size.
                        match crate::runtime::engine_for_model(&dir, &model, config.policy.max_batch)
                        {
                            Ok(e) => Some(e),
                            Err(err) => {
                                eprintln!(
                                    "intreeger-server: XLA engine unavailable ({err}); scalar only"
                                );
                                None
                            }
                        }
                    });
                    let xla = if config.auto_calibrate {
                        calibrate(xla, &scalar, n_features, config.policy.max_batch)
                    } else {
                        xla
                    };
                    let exec =
                        ShardExec { primary: scalar, fallback, exec_failures: 0, degraded: false };
                    supervise(rx, exec, xla, config, m2, n_features, f2)
                })
                .expect("spawn server worker");
            workers.push(worker);
        }
        // Slab sizing: every queued request may hold a row, every
        // worker may hold a flushed batch plus one being answered, and
        // a margin covers rows checked out by front-end connections
        // between checkout and submit.
        let slab_rows = config.queue_depth + 2 * n_workers * config.policy.max_batch + 64;
        let slab = Arc::new(FeatureSlab::new(slab_rows, n_features.max(1)));
        InferenceServer {
            txs,
            next_shard: AtomicUsize::new(0),
            metrics,
            n_features,
            workers,
            shutting_down: AtomicBool::new(false),
            default_ttl: config.default_ttl,
            faults,
            slab,
        }
    }

    /// Shared admission gate: shutdown, arity, finiteness, scripted
    /// queue-full. Counts the matching rejection/shed metrics.
    fn gate(&self, row: &[f32]) -> Result<(), ServeError> {
        if self.shutting_down.load(Ordering::Relaxed) {
            return Err(ServeError::ShuttingDown);
        }
        if row.len() != self.n_features {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::WrongFeatureCount {
                expected: self.n_features,
                got: row.len(),
            });
        }
        if let Some(index) = row.iter().position(|v| !v.is_finite()) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::NonFiniteFeature { index });
        }
        if self.faults.inject_queue_full() {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::QueueFull);
        }
        Ok(())
    }

    /// Enqueue an already-gated request. On a full shard the whole
    /// request is handed back so the caller can reclaim its payload.
    fn enqueue(&self, req: Request) -> Result<(), (ServeError, Option<Request>)> {
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        match self.txs[shard].try_send(Msg::Infer(req)) {
            Ok(()) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(msg)) => {
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                let req = match msg {
                    Msg::Infer(r) => Some(r),
                    Msg::Shutdown => None,
                };
                Err((ServeError::QueueFull, req))
            }
            Err(TrySendError::Disconnected(_)) => {
                // Workers only exit on shutdown (panics are supervised),
                // so a dead channel outside shutdown is a lost shard.
                let e = if self.shutting_down.load(Ordering::Relaxed) {
                    ServeError::ShuttingDown
                } else {
                    ServeError::WorkerLost
                };
                Err((e, None))
            }
        }
    }

    /// The full owned-row admission path. On `QueueFull` the feature
    /// row is handed back so blocking callers can retry without
    /// cloning.
    fn admit(
        &self,
        features: Vec<f32>,
        ttl: Option<Duration>,
    ) -> Result<Receiver<ServeResult>, (ServeError, Option<Vec<f32>>)> {
        if let Err(e) = self.gate(&features) {
            return Err((e, Some(features)));
        }
        let (tx, rx) = sync_channel(1);
        let t_arrival = Instant::now();
        let deadline = ttl.and_then(|d| t_arrival.checked_add(d));
        let req = Request {
            row: RowPayload::Owned(features),
            fixed_buf: Vec::new(),
            tx,
            t_arrival,
            deadline,
        };
        match self.enqueue(req) {
            Ok(()) => Ok(rx),
            Err((e, req)) => {
                let features = req.and_then(|r| match r.row {
                    RowPayload::Owned(v) => Some(v),
                    RowPayload::Slab(_) => None,
                });
                Err((e, features))
            }
        }
    }

    /// Closed-loop admission for the blocking helpers: absorb transient
    /// `QueueFull` with a bounded retry (the shard drains concurrently),
    /// surface everything else immediately.
    fn admit_blocking(
        &self,
        features: Vec<f32>,
        ttl: Option<Duration>,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        const SPIN: Duration = Duration::from_micros(100);
        const MAX_SPINS: u32 = 100_000; // ~10 s of sustained backpressure
        let mut features = features;
        let mut spins = 0u32;
        loop {
            match self.admit(features, ttl) {
                Ok(rx) => return Ok(rx),
                Err((ServeError::QueueFull, Some(f))) if spins < MAX_SPINS => {
                    features = f;
                    spins += 1;
                    std::thread::sleep(SPIN);
                }
                Err((e, _)) => return Err(e),
            }
        }
    }

    /// Asynchronous submit: validates and *tries* to admit the request,
    /// returning a receiver for its resolution. Requests round-robin
    /// across worker shards; a full shard queue sheds
    /// ([`ServeError::QueueFull`]) instead of blocking. Applies
    /// [`ServerConfig::default_ttl`].
    pub fn submit(&self, features: Vec<f32>) -> Result<Receiver<ServeResult>, ServeError> {
        self.submit_with_ttl(features, self.default_ttl)
    }

    /// [`Self::submit`] with an explicit per-request TTL (`None` never
    /// expires). The deadline is checked when the batch forms: an
    /// admitted request whose TTL lapses while queued resolves as
    /// [`ServeError::DeadlineExceeded`].
    pub fn submit_with_ttl(
        &self,
        features: Vec<f32>,
        ttl: Option<Duration>,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        self.admit(features, ttl).map_err(|(e, _)| e)
    }

    /// Check a feature row out of the server's arena slab for the
    /// pooled admission path ([`Self::submit_pooled`]). `None` means
    /// the slab is exhausted — counted as a shed here, mirroring
    /// queue-full — and the caller must refuse the request; checkout
    /// never blocks and never allocates a fallback row.
    pub fn checkout_row(&self) -> Option<SlabRow> {
        let row = FeatureSlab::checkout(&self.slab);
        if row.is_none() {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
        }
        row
    }

    /// The server's feature-row arena (sizing and free-list
    /// diagnostics; tests assert every resolution path refills it).
    pub fn slab(&self) -> &Arc<FeatureSlab> {
        &self.slab
    }

    /// Zero-copy admission for a slab-resident row
    /// ([`Self::checkout_row`]): the row is validated in place and
    /// enqueued with the slot's reusable reply channel and recycled
    /// output buffer, so a steady-state pooled request performs no
    /// heap allocation from admission through response. Applies
    /// [`ServerConfig::default_ttl`]. The contract is one outstanding
    /// submission per slot — [`ReplySlot::recv`] before submitting
    /// again. On every error path the slab row is released back to
    /// the free-list (dropped here or handed back by the shard),
    /// never leaked.
    pub fn submit_pooled(&self, row: SlabRow, slot: &mut ReplySlot) -> Result<(), ServeError> {
        self.submit_pooled_with_ttl(row, slot, self.default_ttl)
    }

    /// [`Self::submit_pooled`] with an explicit per-request TTL
    /// (`None` never expires).
    pub fn submit_pooled_with_ttl(
        &self,
        row: SlabRow,
        slot: &mut ReplySlot,
        ttl: Option<Duration>,
    ) -> Result<(), ServeError> {
        if let Err(e) = self.gate(row.as_slice()) {
            // Dropping `row` here returns it to the slab free-list.
            return Err(e);
        }
        slot.clear_stale();
        let t_arrival = Instant::now();
        let deadline = ttl.and_then(|d| t_arrival.checked_add(d));
        let req = Request {
            row: RowPayload::Slab(row),
            fixed_buf: slot.take_fixed(),
            tx: slot.sender(),
            t_arrival,
            deadline,
        };
        match self.enqueue(req) {
            Ok(()) => Ok(()),
            Err((e, req)) => {
                if let Some(r) = req {
                    // Reclaim the output buffer; the slab row drops
                    // with the rest of the request.
                    slot.recycle(r.fixed_buf);
                }
                Err(e)
            }
        }
    }

    /// Blocking inference. Waits out transient queue-full (bounded), so
    /// a closed-loop caller sees every request resolve.
    pub fn infer(&self, features: Vec<f32>) -> ServeResult {
        match self.admit_blocking(features, self.default_ttl) {
            Ok(rx) => rx.recv().unwrap_or(Err(ServeError::WorkerLost)),
            Err(e) => Err(e),
        }
    }

    /// Blocking batch inference (submits all, then waits). One
    /// `ServeResult` per input row, in order.
    pub fn infer_many(&self, rows: Vec<Vec<f32>>) -> Vec<ServeResult> {
        let slots: Vec<Result<Receiver<ServeResult>, ServeError>> =
            rows.into_iter().map(|r| self.admit_blocking(r, self.default_ttl)).collect();
        slots
            .into_iter()
            .map(|slot| match slot {
                Ok(rx) => rx.recv().unwrap_or(Err(ServeError::WorkerLost)),
                Err(e) => Err(e),
            })
            .collect()
    }

    /// Number of worker shards actually running.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Point-in-time metrics snapshot.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics sink — the recordable form the
    /// HTTP front end uses for socket-to-socket SLO latency and
    /// request/response counters ([`Metrics::record_e2e_us`] and the
    /// `http_*` counters live outside the coordinator's own paths).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Feature columns a submitted row must have (the model's arity).
    pub fn n_features(&self) -> usize {
        self.n_features
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Refuse new admissions first so queued Shutdown messages are
        // not buried under a flood of racing submits.
        self.shutting_down.store(true, Ordering::SeqCst);
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Probe rows for kernel calibration, sampled around the compiled
/// forest's *own* per-feature thresholds (jittered both below and above)
/// so the timed walks exercise realistic split decisions. A fixed
/// synthetic pattern can fall entirely on one side of every split, and
/// the branchy kernel's cost is data-dependent through its early exit —
/// timing it on a degenerate all-left workload would crown the wrong
/// kernel for production traffic.
fn calibration_rows(engine: &IntEngine, n_features: usize, b: usize) -> Vec<f32> {
    let f = engine.forest();
    let mut pools: Vec<Vec<f32>> = vec![Vec::new(); n_features];
    for i in 0..f.n_nodes() {
        if f.feature[i] != crate::inference::LEAF {
            pools[f.feature[i] as usize].push(f.thresh_f32[i]);
        }
    }
    // Deterministic: same model -> same probe batch -> stable choice.
    let mut rng = crate::util::Rng::new(0xCA11_B8A7);
    let mut rows = Vec::with_capacity(b * n_features);
    for _ in 0..b {
        for pool in pools.iter().take(n_features) {
            let v = if pool.is_empty() {
                rng.uniform_in(-1.0, 1.0)
            } else {
                let t = pool[rng.below(pool.len())];
                // Jitter in ±5% of the threshold's magnitude: both branch
                // outcomes occur across the batch.
                t + rng.uniform_in(-0.5, 0.5) * (t.abs().max(1.0) * 0.1)
            };
            rows.push(v);
        }
    }
    rows
}

/// The execution strategy calibration settled on.
#[derive(Clone, Debug)]
pub struct ExecutionChoice {
    /// Winning traversal kernel.
    pub kernel: TraversalKernel,
    /// Winning SIMD execution backend.
    pub backend: SimdBackend,
    /// Winning intra-batch thread count.
    pub threads: usize,
    /// Min-of-k probe time per `kernel@backend@Nt` candidate, in seconds
    /// (candidate name, time) — the evidence behind the pick.
    pub timings: Vec<(String, f64)>,
}

/// Startup micro-benchmark: pick the fastest execution strategy —
/// traversal kernel (branchy early-exit vs predicated branchless
/// fixed-trip vs QuickScorer bitvector) × SIMD backend
/// ([`SimdBackend::sweep`]: every detected backend, or just the forced
/// one when `INTREEGER_BACKEND` pins it) × intra-batch thread count
/// ([`parallel::sweep`](crate::inference::parallel::sweep): 1, powers of
/// two, and the detected core count, or just the forced one when
/// `INTREEGER_THREADS` pins it) — for this model's tree shapes on this
/// host. Leaves the winner set on `engine` and returns the full choice.
/// Uses min-of-k timing on a full-policy batch of
/// threshold-representative probe rows. Also used by the CLI `inspect`
/// command to explain per-machine performance deltas.
pub fn calibrate_execution(
    engine: &mut IntEngine,
    n_features: usize,
    batch: usize,
) -> ExecutionChoice {
    use crate::inference::Engine as _;
    let b = batch.max(crate::inference::TILE_ROWS);
    let rows = calibration_rows(engine, n_features, b);
    let mut best = (f64::INFINITY, TraversalKernel::default(), SimdBackend::Scalar, 1usize);
    let mut timings: Vec<(String, f64)> = Vec::new();
    for &threads in &crate::inference::parallel::sweep() {
        engine.set_threads(threads);
        for (bi, &backend) in SimdBackend::sweep().iter().enumerate() {
            engine.set_backend(backend);
            for kernel in TraversalKernel::all() {
                // The branchy walk ignores the backend (inherently
                // divergent, always scalar); timing it once per thread
                // count is enough — it still scales across row chunks.
                if kernel == TraversalKernel::Branchy && bi > 0 {
                    continue;
                }
                engine.set_kernel(kernel);
                std::hint::black_box(engine.predict_fixed_batch(&rows)); // warmup
                let mut t_min = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = Instant::now();
                    std::hint::black_box(engine.predict_fixed_batch(&rows));
                    t_min = t_min.min(t0.elapsed().as_secs_f64());
                }
                timings.push((
                    format!("{}@{}@{}t", kernel.name(), backend.name(), threads),
                    t_min,
                ));
                if t_min < best.0 {
                    best = (t_min, kernel, backend, threads);
                }
            }
        }
    }
    engine.set_kernel(best.1);
    engine.set_backend(best.2);
    engine.set_threads(best.3);
    let report: Vec<String> =
        timings.iter().map(|(name, t)| format!("{name} {:.0} us", t * 1e6)).collect();
    let (pref, basis) = crate::inference::parallel::preferred();
    eprintln!(
        "intreeger-server: auto-calibration picked {}@{}@{}t per {b}-batch \
         (threads swept to {pref} {basis} cores; {})",
        best.1.name(),
        best.2.name(),
        best.3,
        report.join(", ")
    );
    ExecutionChoice { kernel: best.1, backend: best.2, threads: best.3, timings }
}

/// Startup micro-benchmark: keep the XLA engine only if it beats the
/// *batched* scalar kernel per row at the policy's full batch size —
/// the honest comparison now that the scalar route is batch-first.
fn calibrate(
    xla: Option<PjrtEngine>,
    scalar: &IntEngine,
    n_features: usize,
    batch: usize,
) -> Option<PjrtEngine> {
    let engine = xla?;
    let b = batch.clamp(1, engine.max_batch());
    // Synthetic probe rows: values spread across the training range are
    // unnecessary — timing is dominated by batch mechanics, not path
    // shape — but vary them a little to avoid one-leaf degenerate walks.
    let rows: Vec<f32> = (0..b * n_features).map(|i| (i % 97) as f32 - 48.0).collect();
    let time_of = |f: &mut dyn FnMut()| {
        f(); // warmup
        let t0 = Instant::now();
        for _ in 0..3 {
            f();
        }
        t0.elapsed().as_secs_f64() / 3.0
    };
    let t_xla = time_of(&mut || {
        let _ = engine.execute(&rows, n_features);
    });
    let t_scalar = time_of(&mut || {
        std::hint::black_box(scalar.predict_fixed_batch(&rows));
    });
    if t_xla <= t_scalar {
        Some(engine)
    } else {
        eprintln!(
            "intreeger-server: auto-calibration disabled the XLA route \
             ({:.0} us vs batched scalar {:.0} us per {b}-batch on this host)",
            t_xla * 1e6,
            t_scalar * 1e6
        );
        None
    }
}

/// Shard supervisor: runs the worker loop under `catch_unwind` and
/// restarts it with bounded exponential backoff after a panic. Requests
/// stranded in the shard's batcher by the crash resolve as
/// [`ServeError::WorkerLost`] before the restart — nothing is lost, the
/// caller just learns the truth.
fn supervise(
    rx: Receiver<Msg>,
    mut exec: ShardExec,
    xla: Option<PjrtEngine>,
    config: ServerConfig,
    metrics: Arc<Metrics>,
    n_features: usize,
    faults: Arc<Faults>,
) {
    // The batcher lives *outside* the unwind region behind a mutex so
    // the supervisor can flush stranded requests after a crash.
    let pending: Mutex<Batcher<Request>> = Mutex::new(Batcher::new(config.policy));
    let mut restarts: u32 = 0;
    loop {
        let finished = catch_unwind(AssertUnwindSafe(|| {
            worker_loop(&rx, &pending, &mut exec, &xla, &config, &metrics, n_features, &faults)
        }));
        match finished {
            Ok(()) => return, // clean shutdown / channel closed
            Err(_) => {
                metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                if let Some((batch, _)) = lock_unpoisoned(&pending).drain() {
                    metrics.lost.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    for req in batch {
                        let _ = req.tx.send(Err(ServeError::WorkerLost));
                    }
                }
                let backoff = Duration::from_millis(1u64 << restarts.min(6));
                restarts += 1;
                eprintln!(
                    "intreeger-server: worker shard panicked; restart #{restarts} in {backoff:?}"
                );
                std::thread::sleep(backoff);
            }
        }
    }
}

/// Per-shard flat buffers reused across batch executions: the row-major
/// input and the fixed-point output of the whole batch. Steady-state
/// batch execution therefore allocates nothing batch-sized, and the
/// per-request output rides each request's traveling `fixed_buf`
/// (recycled by pooled callers) — so a steady-state pooled request
/// allocates nothing at all. Rebuilt (empty) when a supervisor
/// restarts its worker.
#[derive(Default)]
struct BatchScratch {
    rows: Vec<f32>,
    fixed: Vec<u32>,
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: &Receiver<Msg>,
    pending: &Mutex<Batcher<Request>>,
    exec: &mut ShardExec,
    xla: &Option<PjrtEngine>,
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    n_features: usize,
    faults: &Faults,
) {
    let mut scratch = BatchScratch::default();
    loop {
        // Wait bounded by the batch deadline (if any).
        let timeout = lock_unpoisoned(pending)
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Infer(req)) => {
                // The TTL deadline rides into the batcher so the flush
                // deadline adapts to the most urgent pending request.
                let deadline = req.deadline;
                let flushed = lock_unpoisoned(pending).push_deadline(req, deadline);
                if let Some((batch, why)) = flushed {
                    let empty = serve_batch(
                        batch, why, exec, xla, config, metrics, n_features, faults, &mut scratch,
                    );
                    lock_unpoisoned(pending).recycle(empty);
                }
            }
            Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                let flushed = lock_unpoisoned(pending).drain();
                if let Some((batch, why)) = flushed {
                    serve_batch(
                        batch, why, exec, xla, config, metrics, n_features, faults, &mut scratch,
                    );
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                let flushed = lock_unpoisoned(pending).poll();
                if let Some((batch, why)) = flushed {
                    let empty = serve_batch(
                        batch, why, exec, xla, config, metrics, n_features, faults, &mut scratch,
                    );
                    lock_unpoisoned(pending).recycle(empty);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_batch(
    mut batch: Vec<Request>,
    why: FlushReason,
    exec: &mut ShardExec,
    xla: &Option<PjrtEngine>,
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    n_features: usize,
    faults: &Faults,
    scratch: &mut BatchScratch,
) -> Vec<Request> {
    // Deadline check at batch-formation time, in place: expired rows
    // resolve without burning kernel time and without allocating
    // partition vectors (expiry strictness matches
    // `Batcher::partition_expired`: a deadline of exactly `now` still
    // serves). Dropping an expired request releases its slab row.
    let now = Instant::now();
    let mut n_expired = 0u64;
    batch.retain(|req| {
        let live = match req.deadline {
            Some(d) => now <= d,
            None => true,
        };
        if !live {
            n_expired += 1;
            let _ = req.tx.send(Err(ServeError::DeadlineExceeded));
        }
        live
    });
    if n_expired > 0 {
        metrics.expired.fetch_add(n_expired, Ordering::Relaxed);
    }
    if batch.is_empty() {
        return batch;
    }
    let use_xla = !exec.degraded
        && match xla {
            Some(engine) => {
                batch.len() >= config.xla_threshold && batch.len() <= engine.max_batch()
            }
            None => false,
        };
    metrics.record_batch(batch.len(), use_xla, why);
    let t_serve = Instant::now();

    // Flatten once into the reused scratch; both routes consume the
    // row-major buffer. The flat fixed-point output is also reused —
    // batch execution allocates nothing batch-sized in steady state.
    use crate::inference::Engine as _;
    let n_classes = exec.engine().n_classes();
    scratch.rows.clear();
    for r in &batch {
        scratch.rows.extend_from_slice(r.row.as_slice());
    }
    scratch.fixed.clear();
    scratch.fixed.resize(batch.len() * n_classes, 0);
    // Execution is the untrusted region: a panicking kernel (or an
    // injected fault) must not strand the batch's callers.
    let engine = exec.engine();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        faults.on_batch_execution();
        let mut served_by_xla = false;
        if use_xla {
            let x = xla.as_ref().unwrap();
            // On runtime errors fall through to the batched scalar
            // kernel — requests must never be dropped.
            if let Ok(out) = x.execute(&scratch.rows, n_features) {
                for (slot, row) in scratch.fixed.chunks_exact_mut(n_classes).zip(&out) {
                    slot.copy_from_slice(row);
                }
                served_by_xla = true;
            }
        }
        if !served_by_xla {
            engine.predict_fixed_batch_into(&scratch.rows, &mut scratch.fixed);
        }
    }));
    match outcome {
        Ok(()) => {
            metrics.record_batch_latency_us(t_serve.elapsed().as_secs_f64() * 1e6);
            let route = if use_xla { Route::Xla } else { Route::Scalar };
            for (mut req, fixed) in batch.drain(..).zip(scratch.fixed.chunks_exact(n_classes)) {
                let latency = req.t_arrival.elapsed();
                metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                let class = argmax(fixed);
                // Fill the request's traveling output buffer —
                // clear + extend reuses the recycled capacity, so a
                // steady-state pooled response allocates nothing.
                // Receiver may have gone away; that's fine.
                req.fixed_buf.clear();
                req.fixed_buf.extend_from_slice(fixed);
                let fixed_out = std::mem::take(&mut req.fixed_buf);
                let _ = req.tx.send(Ok(Response { fixed: fixed_out, class, route, latency }));
                // `req` drops here: a slab-resident row returns to the
                // free-list only after its response resolved.
            }
        }
        Err(payload) => {
            // The batch's callers learn the truth now; the supervisor
            // learns it next (re-raised) and restarts the worker.
            metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
            metrics.lost.fetch_add(batch.len() as u64, Ordering::Relaxed);
            for req in batch.drain(..) {
                let _ = req.tx.send(Err(ServeError::WorkerLost));
            }
            exec.record_failure(metrics);
            resume_unwind(payload);
        }
    }
    // Hand the (now empty) batch vector back for the batcher to reuse.
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::inference::Engine;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(1200, 100);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            9,
        );
        (ds, m)
    }

    /// Config with faults pinned off: unit tests must not pick up an
    /// `INTREEGER_FAULTS` plan from the environment (the CI chaos leg
    /// sets one process-wide).
    fn quiet() -> ServerConfig {
        ServerConfig { faults: Some(FaultPlan::none()), ..Default::default() }
    }

    #[test]
    fn scalar_only_server_answers_correctly() {
        let (ds, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let oracle = crate::inference::IntEngine::compile(&m);
        for i in 0..50 {
            let r = server.infer(ds.row(i).to_vec()).expect("serve");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)));
            assert_eq!(r.class, oracle.predict(ds.row(i)));
            assert_eq!(r.route, Route::Scalar);
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 50);
        assert_eq!(snap.responses, 50);
        assert_eq!(snap.rows_scalar, 50);
        assert_eq!(snap.rows_xla, 0);
        // A healthy run records no failures.
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.expired, 0);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.lost, 0);
        assert_eq!(snap.worker_panics, 0);
        assert_eq!(snap.worker_restarts, 0);
        assert!(!snap.degraded);
        // Every flush served at least one batch, so batch latency was
        // recorded.
        assert!(snap.batch_latency_mean_us > 0.0);
        // The execution strategy is recorded even without calibration
        // (the engine's compile-time defaults).
        assert_eq!(snap.kernel.as_deref(), Some(TraversalKernel::default().name()));
        let backend = snap.backend.expect("backend recorded at startup");
        assert!(
            SimdBackend::from_name(&backend).unwrap().is_available(),
            "recorded backend {backend} must be executable"
        );
        let threads = snap.threads.expect("thread count recorded at startup");
        assert!((1..=crate::inference::parallel::detected()).contains(&threads));
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let (ds, m) = model();
        let server = std::sync::Arc::new(InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
                ..quiet()
            },
        ));
        let mut rxs = Vec::new();
        for i in 0..200 {
            rxs.push(server.submit(ds.row(i % ds.n_rows()).to_vec()).expect("admitted"));
        }
        for rx in rxs {
            let r = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("resolved")
                .expect("served");
            assert_eq!(r.fixed.len(), ds.n_classes);
        }
        assert_eq!(server.metrics().responses, 200);
    }

    #[test]
    fn worker_pool_shards_and_answers_correctly() {
        let (ds, m) = model();
        let oracle = crate::inference::IntEngine::compile(&m);
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) },
                n_workers: 4,
                ..quiet()
            },
        );
        assert_eq!(server.n_workers(), 4);
        let rows: Vec<Vec<f32>> = (0..400).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let responses = server.infer_many(rows);
        assert_eq!(responses.len(), 400);
        for (i, r) in responses.iter().enumerate() {
            let r = r.as_ref().expect("served");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i % ds.n_rows())), "row {i}");
            assert_eq!(r.route, Route::Scalar);
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 400);
        assert_eq!(snap.responses, 400);
        assert_eq!(snap.rows_scalar, 400);
        // Every flush respects the per-shard policy cap (exact batch-size
        // quantiles make this a real bound, not a bucket estimate). Note
        // this checks policy enforcement, not shard *distribution* — each
        // Batcher caps its own flushes, so a sharding regression would
        // need a per-shard counter to detect.
        assert!(
            snap.batch_p99 as usize <= 16,
            "flush exceeded per-shard max_batch: p99 = {}",
            snap.batch_p99
        );
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let (ds, m) = model();
        let server =
            InferenceServer::start(&m, None, ServerConfig { n_workers: 0, ..quiet() });
        assert_eq!(server.n_workers(), 1);
        let r = server.infer(ds.row(0).to_vec()).expect("serve");
        assert_eq!(r.fixed.len(), ds.n_classes);
    }

    #[test]
    fn xla_route_used_for_large_batches_and_matches_scalar() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let (ds, m) = model();
        let oracle = crate::inference::IntEngine::compile(&m);
        let server = InferenceServer::start(
            &m,
            Some(dir),
            ServerConfig {
                policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) },
                xla_threshold: 8,
                ..quiet()
            },
        );
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        let responses = server.infer_many(rows);
        let mut xla_routed = 0;
        for (i, r) in responses.iter().enumerate() {
            let r = r.as_ref().expect("served");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i} parity");
            if r.route == Route::Xla {
                xla_routed += 1;
            }
        }
        assert!(xla_routed > 0, "no request took the XLA route");
        assert!(server.metrics().rows_xla > 0);
    }

    #[test]
    fn auto_calibrate_prefers_faster_backend() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            Some(dir),
            ServerConfig { auto_calibrate: true, ..quiet() },
        );
        // Whatever the calibration decided, requests must be answered
        // correctly (on this 1-core host the scalar route wins).
        let oracle = crate::inference::IntEngine::compile(&m);
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        for (i, r) in server.infer_many(rows).iter().enumerate() {
            assert_eq!(r.as_ref().expect("served").fixed, oracle.predict_fixed(ds.row(i)));
        }
    }

    #[test]
    fn auto_calibrate_without_artifacts_picks_a_kernel_and_answers() {
        // No artifacts dir: only the tile-kernel calibration runs. The
        // choice must be invisible — every answer still matches the
        // scalar oracle bit-for-bit.
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig { auto_calibrate: true, n_workers: 2, ..quiet() },
        );
        let oracle = crate::inference::IntEngine::compile(&m);
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        for (i, r) in server.infer_many(rows).iter().enumerate() {
            let r = r.as_ref().expect("served");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i}");
            assert_eq!(r.route, Route::Scalar);
        }
        // Whatever won, the calibrated execution strategy is on record
        // and names real, executable candidates.
        let snap = server.metrics();
        let kernel = snap.kernel.expect("calibrated kernel recorded");
        assert!(TraversalKernel::all().iter().any(|k| k.name() == kernel), "{kernel}");
        let backend = snap.backend.expect("calibrated backend recorded");
        assert!(SimdBackend::from_name(&backend).unwrap().is_available(), "{backend}");
        let threads = snap.threads.expect("calibrated thread count recorded");
        assert!(
            (1..=crate::inference::parallel::detected()).contains(&threads),
            "{threads} threads"
        );
    }

    /// The calibration helper itself: sweeps kernel × available backend
    /// × thread count, returns timings for every candidate, and leaves
    /// the winner set on the engine.
    #[test]
    fn calibrate_execution_sets_winner_and_reports_timings() {
        use crate::inference::Engine as _;
        let (_, m) = model();
        let mut engine = IntEngine::compile(&m);
        let choice = calibrate_execution(&mut engine, m.n_features, 64);
        assert_eq!(engine.kernel(), choice.kernel);
        assert_eq!(engine.backend(), choice.backend);
        assert_eq!(engine.threads(), choice.threads);
        assert!(choice.backend.is_available());
        assert!((1..=crate::inference::parallel::detected()).contains(&choice.threads));
        // Per thread count: branchy once + (branchless + quickscorer)
        // per backend.
        let n_backends = SimdBackend::sweep().len();
        let n_threads = crate::inference::parallel::sweep().len();
        assert_eq!(choice.timings.len(), n_threads * (1 + 2 * n_backends));
        assert!(choice.timings.iter().all(|(_, t)| *t > 0.0));
        // The winner was one of the timed candidates.
        let winner = format!(
            "{}@{}@{}t",
            choice.kernel.name(),
            choice.backend.name(),
            choice.threads
        );
        assert!(
            choice.timings.iter().any(|(n, _)| *n == winner),
            "winner {winner} missing from timings {:?}",
            choice.timings
        );
    }

    #[test]
    fn rejects_wrong_arity_with_typed_error() {
        let (_, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let err = server.infer(vec![1.0, 2.0]).unwrap_err();
        assert_eq!(err, ServeError::WrongFeatureCount { expected: m.n_features, got: 2 });
        // The legacy panic message survives as the Display text so old
        // operator runbooks keep grepping.
        assert!(err.to_string().contains("wrong feature count"), "{err}");
        let snap = server.metrics();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.requests, 0, "rejected rows are not admitted");
    }

    #[test]
    fn rejects_non_finite_features_with_typed_error() {
        let (ds, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let mut row = ds.row(0).to_vec();
        row[3] = f32::NAN;
        assert_eq!(
            server.infer(row.clone()).unwrap_err(),
            ServeError::NonFiniteFeature { index: 3 }
        );
        row[3] = f32::INFINITY;
        assert_eq!(
            server.infer(row).unwrap_err(),
            ServeError::NonFiniteFeature { index: 3 }
        );
        assert_eq!(server.metrics().rejected, 2);
    }

    #[test]
    fn forced_queue_full_sheds_with_typed_error() {
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                faults: Some(FaultPlan { queue_full_first: 3, ..FaultPlan::none() }),
                ..Default::default()
            },
        );
        let mut shed = 0;
        let mut rxs = Vec::new();
        for i in 0..5 {
            match server.submit(ds.row(i).to_vec()) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    assert_eq!(e, ServeError::QueueFull);
                    shed += 1;
                }
            }
        }
        assert_eq!(shed, 3, "exactly the scripted number of sheds");
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).expect("resolved").expect("served");
        }
        let snap = server.metrics();
        assert_eq!(snap.shed, 3);
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.responses, 2);
    }

    #[test]
    fn serve_error_display_kind_roundtrip_exhaustive() {
        // Six variants, all distinct in kind and Display, all
        // round-trippable through from_kind.
        assert_eq!(ServeError::ALL.len(), 6);
        let mut kinds: Vec<&str> = ServeError::ALL.iter().map(|e| e.kind()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), 6, "kinds must be unique");
        for e in ServeError::ALL {
            let text = e.to_string();
            assert!(!text.is_empty());
            let back = ServeError::from_kind(e.kind()).expect("kind round-trips");
            assert_eq!(back.kind(), e.kind());
            // std::error::Error is implemented (boxable).
            let boxed: Box<dyn std::error::Error> = Box::new(e);
            assert_eq!(boxed.to_string(), text);
        }
        assert_eq!(ServeError::from_kind("no_such_kind"), None);
        // Payloads show up in the human text.
        let e = ServeError::WrongFeatureCount { expected: 9, got: 2 };
        assert_eq!(e.to_string(), "wrong feature count: expected 9, got 2");
        assert_eq!(
            ServeError::NonFiniteFeature { index: 4 }.to_string(),
            "non-finite feature value at index 4"
        );
    }

    #[test]
    fn submit_with_ttl_expires_queued_requests() {
        let (ds, m) = model();
        // Slow batch formation (long max_wait, huge max_batch) so a
        // zero TTL is guaranteed to lapse before the flush.
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 512, max_wait: Duration::from_millis(20) },
                ..quiet()
            },
        );
        let rx = server
            .submit_with_ttl(ds.row(0).to_vec(), Some(Duration::ZERO))
            .expect("admitted");
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(5)).expect("resolved"),
            Err(ServeError::DeadlineExceeded)
        );
        // A TTL-free request on the same server still serves.
        server.infer(ds.row(1).to_vec()).expect("served");
        let snap = server.metrics();
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.responses, 1);
    }

    /// Wait (bounded) for every slab row to return to the free-list:
    /// the worker drops a request just *after* sending its response,
    /// so the caller can observe the resolution before the row lands.
    fn wait_slab_full(server: &InferenceServer) {
        let total = server.slab().rows();
        for _ in 0..500 {
            if server.slab().available() == total {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(server.slab().available(), total, "slab rows leaked");
    }

    #[test]
    fn pooled_submission_answers_correctly_and_returns_rows() {
        let (ds, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let oracle = crate::inference::IntEngine::compile(&m);
        let mut slot = ReplySlot::new();
        for i in 0..50 {
            let mut row = server.checkout_row().expect("slab row");
            row.copy_from(ds.row(i));
            server.submit_pooled(row, &mut slot).expect("admitted");
            let r = slot.recv().expect("served");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)));
            assert_eq!(r.class, oracle.predict(ds.row(i)));
            slot.recycle(r.fixed);
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 50);
        assert_eq!(snap.responses, 50);
        wait_slab_full(&server);
    }

    #[test]
    fn slab_exhaustion_sheds_and_recovers() {
        let (_, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let total = server.slab().rows();
        let held: Vec<_> =
            (0..total).map(|_| server.checkout_row().expect("row available")).collect();
        // Exhausted: checkout sheds (typed as queue-full by callers),
        // never blocks.
        assert!(server.checkout_row().is_none());
        assert_eq!(server.metrics().shed, 1);
        drop(held);
        assert!(server.checkout_row().is_some(), "returned rows are reusable");
        wait_slab_full(&server);
    }

    #[test]
    fn pooled_ttl_expiry_returns_slab_row() {
        let (ds, m) = model();
        // Slow batch formation so a zero TTL lapses before the flush.
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 512, max_wait: Duration::from_millis(20) },
                ..quiet()
            },
        );
        let mut slot = ReplySlot::new();
        let mut row = server.checkout_row().expect("slab row");
        row.copy_from(ds.row(0));
        server
            .submit_pooled_with_ttl(row, &mut slot, Some(Duration::ZERO))
            .expect("admitted");
        assert_eq!(slot.recv().unwrap_err(), ServeError::DeadlineExceeded);
        wait_slab_full(&server);
        assert_eq!(server.metrics().expired, 1);
    }

    #[test]
    fn pooled_shed_returns_row_synchronously() {
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                faults: Some(FaultPlan { queue_full_first: 1, ..FaultPlan::none() }),
                ..Default::default()
            },
        );
        let total = server.slab().rows();
        let mut slot = ReplySlot::new();
        let mut row = server.checkout_row().expect("slab row");
        row.copy_from(ds.row(0));
        assert_eq!(server.submit_pooled(row, &mut slot).unwrap_err(), ServeError::QueueFull);
        // The gate shed the request before enqueue, so the row is back
        // already — no waiting on a worker.
        assert_eq!(server.slab().available(), total);
        // The slot survives a shed: the next submission serves.
        let mut row = server.checkout_row().expect("slab row");
        row.copy_from(ds.row(0));
        server.submit_pooled(row, &mut slot).expect("admitted");
        slot.recv().expect("served");
        wait_slab_full(&server);
    }

    #[test]
    fn pooled_rejections_release_the_row() {
        let (ds, m) = model();
        let server = InferenceServer::start(&m, None, quiet());
        let total = server.slab().rows();
        let mut slot = ReplySlot::new();
        // Non-finite feature: typed rejection, row released in place.
        let mut row = server.checkout_row().expect("slab row");
        let mut bad = ds.row(0).to_vec();
        bad[2] = f32::NAN;
        row.copy_from(&bad);
        assert_eq!(
            server.submit_pooled(row, &mut slot).unwrap_err(),
            ServeError::NonFiniteFeature { index: 2 }
        );
        assert_eq!(server.slab().available(), total);
        assert_eq!(server.metrics().rejected, 1);
    }
}
