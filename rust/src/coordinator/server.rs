//! The inference server: dynamic batching over two execution backends,
//! drained by a sharded pool of worker threads.
//!
//! Requests are round-robin sharded across `n_workers` worker threads;
//! each worker owns a [`Batcher`] and drains its own channel, so
//! scalar-route throughput scales with cores. Flushed batches run
//! through the **tiled batch kernel** ([`IntEngine::predict_fixed_batch`])
//! rather than a per-row loop; batches at/above `xla_threshold` go to
//! the AOT-compiled XLA/PJRT Pallas engine instead (shard 0 only — the
//! xla handles are not `Send`, and one compiled executable per process
//! is enough). Both backends emit bit-identical u32 fixed-point
//! accumulators, so the route is an implementation detail (asserted by
//! integration tests).

use super::batcher::{BatchPolicy, Batcher, FlushReason};
use super::metrics::Metrics;
use crate::inference::{IntEngine, SimdBackend, TraversalKernel};
use crate::ir::{argmax, Model};
use crate::runtime::PjrtEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// An inference request: one feature row.
pub struct Request {
    /// The feature row to classify.
    pub features: Vec<f32>,
    tx: SyncSender<Response>,
    t_arrival: Instant,
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// The batched scalar (tiled-kernel) route.
    Scalar,
    /// The AOT-compiled XLA/PJRT route.
    Xla,
}

/// An inference response: the integer-only result.
#[derive(Clone, Debug)]
pub struct Response {
    /// Fixed-point class accumulators (scale 2^32/n_trees).
    pub fixed: Vec<u32>,
    /// argmax class.
    pub class: u32,
    /// Backend that served the request.
    pub route: Route,
    /// End-to-end latency (arrival to response).
    pub latency: Duration,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dynamic-batching policy applied per worker shard.
    pub policy: BatchPolicy,
    /// Batches of at least this many rows go to the XLA engine.
    pub xla_threshold: usize,
    /// Total channel capacity (backpressure bound), split across workers.
    pub queue_depth: usize,
    /// Measure alternative execution strategies at startup and keep the
    /// fastest:
    /// 1. the scalar route's traversal kernel **×** SIMD backend —
    ///    branchy early-exit vs the predicated branchless descent vs the
    ///    QuickScorer bitvector evaluation, each under every detected
    ///    backend (scalar / AVX2 / NEON; `INTREEGER_BACKEND` pins the
    ///    sweep) — is timed on the loaded model (deep, early-exiting
    ///    trees can favor branchy; shallow balanced trees favor
    ///    branchless; wide QS-eligible forests at big batches favor
    ///    quickscorer; gather-friendly hosts favor AVX2), and the winner
    ///    is recorded in the metrics snapshot, and
    /// 2. the XLA route is disabled when the batched scalar kernel beats
    ///    it at the full policy batch size. On a single CPU core the
    ///    padded batched artifact usually loses to the tiled scalar
    ///    kernel (see `cargo bench --bench serve_throughput`); on a real
    ///    accelerator it wins — this flag makes the router honest either
    ///    way.
    ///
    /// Every candidate produces bit-identical results (the batch module's
    /// parity invariant), so calibration is invisible to clients.
    pub auto_calibrate: bool,
    /// Worker threads draining the (sharded) request queue. The scalar
    /// batched route scales near-linearly with workers; the XLA offload
    /// rides shard 0 only. Clamped to at least 1.
    pub n_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            xla_threshold: 16,
            queue_depth: 1024,
            auto_calibrate: false,
            n_workers: 1,
        }
    }
}

enum Msg {
    Infer(Request),
    Shutdown,
}

/// Handle to a running inference server (clone freely behind an `Arc`).
pub struct InferenceServer {
    txs: Vec<SyncSender<Msg>>,
    next_shard: AtomicUsize,
    metrics: Arc<Metrics>,
    n_features: usize,
    workers: Vec<JoinHandle<()>>,
}

impl InferenceServer {
    /// Start a server for `model`. `artifacts_dir` is optional: without
    /// it (or when no tier fits) every batch takes the scalar route.
    ///
    /// The PJRT engine is constructed *inside* worker thread 0: the
    /// xla crate's handles are not `Send`, so the whole XLA object graph
    /// must live and die on the thread that uses it.
    pub fn start(
        model: &Model,
        artifacts_dir: Option<std::path::PathBuf>,
        config: ServerConfig,
    ) -> InferenceServer {
        let n_workers = config.n_workers.max(1);
        // One compiled forest shared by every worker (read-only walks).
        // The execution strategy (tile-walk kernel × SIMD backend) is
        // calibrated once, before sharing: the choice is per *model*
        // (tree shape) and per *host* (CPU features), not per worker.
        let mut scalar_engine = IntEngine::compile(model);
        let metrics = Arc::new(Metrics::new());
        if config.auto_calibrate {
            calibrate_execution(&mut scalar_engine, model.n_features, config.policy.max_batch);
        }
        {
            // Record the execution strategy actually serving (calibrated
            // or compile-time default) so the metrics snapshot — and
            // anything built on it — can explain per-machine deltas.
            use crate::inference::Engine as _;
            metrics.record_execution(
                scalar_engine.kernel().name(),
                scalar_engine.backend().name(),
                scalar_engine.threads(),
            );
        }
        let scalar = Arc::new(scalar_engine);
        let n_features = model.n_features;
        let per_worker_depth = (config.queue_depth / n_workers).max(1);

        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Msg>(per_worker_depth);
            txs.push(tx);
            let scalar = Arc::clone(&scalar);
            let m2 = Arc::clone(&metrics);
            let config = config.clone();
            // Only shard 0 needs the model (to pack the XLA artifact).
            let xla_seed = (w == 0).then(|| (artifacts_dir.clone(), model.clone()));
            let worker = std::thread::Builder::new()
                .name(format!("intreeger-server-{w}"))
                .spawn(move || {
                    let xla: Option<PjrtEngine> = xla_seed.and_then(|(dir, model)| {
                        let dir = dir?;
                        if !crate::runtime::artifacts_available(&dir) {
                            return None;
                        }
                        // Ask for a tier that can hold a full policy batch, so
                        // the XLA route is actually usable at max batch size.
                        match crate::runtime::engine_for_model(&dir, &model, config.policy.max_batch)
                        {
                            Ok(e) => Some(e),
                            Err(err) => {
                                eprintln!(
                                    "intreeger-server: XLA engine unavailable ({err}); scalar only"
                                );
                                None
                            }
                        }
                    });
                    let xla = if config.auto_calibrate {
                        calibrate(xla, &scalar, n_features, config.policy.max_batch)
                    } else {
                        xla
                    };
                    worker_loop(rx, scalar, xla, config, m2, n_features)
                })
                .expect("spawn server worker");
            workers.push(worker);
        }
        InferenceServer { txs, next_shard: AtomicUsize::new(0), metrics, n_features, workers }
    }

    /// Asynchronous submit: returns a receiver for the response.
    /// Requests round-robin across worker shards.
    pub fn submit(&self, features: Vec<f32>) -> Receiver<Response> {
        assert_eq!(features.len(), self.n_features, "wrong feature count");
        let (tx, rx) = sync_channel(1);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = Request { features, tx, t_arrival: Instant::now() };
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.txs[shard].send(Msg::Infer(req)).expect("server thread gone");
        rx
    }

    /// Blocking inference.
    pub fn infer(&self, features: Vec<f32>) -> Response {
        self.submit(features).recv().expect("server dropped response")
    }

    /// Blocking batch inference (submits all, then waits).
    pub fn infer_many(&self, rows: Vec<Vec<f32>>) -> Vec<Response> {
        let rxs: Vec<_> = rows.into_iter().map(|r| self.submit(r)).collect();
        rxs.into_iter().map(|rx| rx.recv().expect("response")).collect()
    }

    /// Number of worker shards actually running.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Point-in-time metrics snapshot.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.metrics.snapshot()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Probe rows for kernel calibration, sampled around the compiled
/// forest's *own* per-feature thresholds (jittered both below and above)
/// so the timed walks exercise realistic split decisions. A fixed
/// synthetic pattern can fall entirely on one side of every split, and
/// the branchy kernel's cost is data-dependent through its early exit —
/// timing it on a degenerate all-left workload would crown the wrong
/// kernel for production traffic.
fn calibration_rows(engine: &IntEngine, n_features: usize, b: usize) -> Vec<f32> {
    let f = engine.forest();
    let mut pools: Vec<Vec<f32>> = vec![Vec::new(); n_features];
    for i in 0..f.n_nodes() {
        if f.feature[i] != crate::inference::LEAF {
            pools[f.feature[i] as usize].push(f.thresh_f32[i]);
        }
    }
    // Deterministic: same model -> same probe batch -> stable choice.
    let mut rng = crate::util::Rng::new(0xCA11_B8A7);
    let mut rows = Vec::with_capacity(b * n_features);
    for _ in 0..b {
        for pool in pools.iter().take(n_features) {
            let v = if pool.is_empty() {
                rng.uniform_in(-1.0, 1.0)
            } else {
                let t = pool[rng.below(pool.len())];
                // Jitter in ±5% of the threshold's magnitude: both branch
                // outcomes occur across the batch.
                t + rng.uniform_in(-0.5, 0.5) * (t.abs().max(1.0) * 0.1)
            };
            rows.push(v);
        }
    }
    rows
}

/// The execution strategy calibration settled on.
#[derive(Clone, Debug)]
pub struct ExecutionChoice {
    /// Winning traversal kernel.
    pub kernel: TraversalKernel,
    /// Winning SIMD execution backend.
    pub backend: SimdBackend,
    /// Winning intra-batch thread count.
    pub threads: usize,
    /// Min-of-k probe time per `kernel@backend@Nt` candidate, in seconds
    /// (candidate name, time) — the evidence behind the pick.
    pub timings: Vec<(String, f64)>,
}

/// Startup micro-benchmark: pick the fastest execution strategy —
/// traversal kernel (branchy early-exit vs predicated branchless
/// fixed-trip vs QuickScorer bitvector) × SIMD backend
/// ([`SimdBackend::sweep`]: every detected backend, or just the forced
/// one when `INTREEGER_BACKEND` pins it) × intra-batch thread count
/// ([`parallel::sweep`](crate::inference::parallel::sweep): 1, powers of
/// two, and the detected core count, or just the forced one when
/// `INTREEGER_THREADS` pins it) — for this model's tree shapes on this
/// host. Leaves the winner set on `engine` and returns the full choice.
/// Uses min-of-k timing on a full-policy batch of
/// threshold-representative probe rows. Also used by the CLI `inspect`
/// command to explain per-machine performance deltas.
pub fn calibrate_execution(
    engine: &mut IntEngine,
    n_features: usize,
    batch: usize,
) -> ExecutionChoice {
    use crate::inference::Engine as _;
    let b = batch.max(crate::inference::TILE_ROWS);
    let rows = calibration_rows(engine, n_features, b);
    let mut best = (f64::INFINITY, TraversalKernel::default(), SimdBackend::Scalar, 1usize);
    let mut timings: Vec<(String, f64)> = Vec::new();
    for &threads in &crate::inference::parallel::sweep() {
        engine.set_threads(threads);
        for (bi, &backend) in SimdBackend::sweep().iter().enumerate() {
            engine.set_backend(backend);
            for kernel in TraversalKernel::all() {
                // The branchy walk ignores the backend (inherently
                // divergent, always scalar); timing it once per thread
                // count is enough — it still scales across row chunks.
                if kernel == TraversalKernel::Branchy && bi > 0 {
                    continue;
                }
                engine.set_kernel(kernel);
                std::hint::black_box(engine.predict_fixed_batch(&rows)); // warmup
                let mut t_min = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = Instant::now();
                    std::hint::black_box(engine.predict_fixed_batch(&rows));
                    t_min = t_min.min(t0.elapsed().as_secs_f64());
                }
                timings.push((
                    format!("{}@{}@{}t", kernel.name(), backend.name(), threads),
                    t_min,
                ));
                if t_min < best.0 {
                    best = (t_min, kernel, backend, threads);
                }
            }
        }
    }
    engine.set_kernel(best.1);
    engine.set_backend(best.2);
    engine.set_threads(best.3);
    let report: Vec<String> =
        timings.iter().map(|(name, t)| format!("{name} {:.0} us", t * 1e6)).collect();
    eprintln!(
        "intreeger-server: auto-calibration picked {}@{}@{}t per {b}-batch ({})",
        best.1.name(),
        best.2.name(),
        best.3,
        report.join(", ")
    );
    ExecutionChoice { kernel: best.1, backend: best.2, threads: best.3, timings }
}

/// Startup micro-benchmark: keep the XLA engine only if it beats the
/// *batched* scalar kernel per row at the policy's full batch size —
/// the honest comparison now that the scalar route is batch-first.
fn calibrate(
    xla: Option<PjrtEngine>,
    scalar: &IntEngine,
    n_features: usize,
    batch: usize,
) -> Option<PjrtEngine> {
    let engine = xla?;
    let b = batch.clamp(1, engine.max_batch());
    // Synthetic probe rows: values spread across the training range are
    // unnecessary — timing is dominated by batch mechanics, not path
    // shape — but vary them a little to avoid one-leaf degenerate walks.
    let rows: Vec<f32> = (0..b * n_features).map(|i| (i % 97) as f32 - 48.0).collect();
    let time_of = |f: &mut dyn FnMut()| {
        f(); // warmup
        let t0 = Instant::now();
        for _ in 0..3 {
            f();
        }
        t0.elapsed().as_secs_f64() / 3.0
    };
    let t_xla = time_of(&mut || {
        let _ = engine.execute(&rows, n_features);
    });
    let t_scalar = time_of(&mut || {
        std::hint::black_box(scalar.predict_fixed_batch(&rows));
    });
    if t_xla <= t_scalar {
        Some(engine)
    } else {
        eprintln!(
            "intreeger-server: auto-calibration disabled the XLA route \
             ({:.0} us vs batched scalar {:.0} us per {b}-batch on this host)",
            t_xla * 1e6,
            t_scalar * 1e6
        );
        None
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    scalar: Arc<IntEngine>,
    xla: Option<PjrtEngine>,
    config: ServerConfig,
    metrics: Arc<Metrics>,
    n_features: usize,
) {
    let mut batcher: Batcher<Request> = Batcher::new(config.policy);
    loop {
        // Wait bounded by the batch deadline (if any).
        let timeout = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Infer(req)) => {
                if let Some((batch, why)) = batcher.push(req) {
                    serve_batch(batch, why, &scalar, &xla, &config, &metrics, n_features);
                }
            }
            Ok(Msg::Shutdown) => {
                if let Some((batch, why)) = batcher.drain() {
                    serve_batch(batch, why, &scalar, &xla, &config, &metrics, n_features);
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some((batch, why)) = batcher.poll() {
                    serve_batch(batch, why, &scalar, &xla, &config, &metrics, n_features);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if let Some((batch, why)) = batcher.drain() {
                    serve_batch(batch, why, &scalar, &xla, &config, &metrics, n_features);
                }
                return;
            }
        }
    }
}

fn serve_batch(
    batch: Vec<Request>,
    why: FlushReason,
    scalar: &IntEngine,
    xla: &Option<PjrtEngine>,
    config: &ServerConfig,
    metrics: &Arc<Metrics>,
    n_features: usize,
) {
    let use_xla = match xla {
        Some(engine) => batch.len() >= config.xla_threshold && batch.len() <= engine.max_batch(),
        None => false,
    };
    metrics.record_batch(batch.len(), use_xla, why);
    let t_serve = Instant::now();

    // Flatten once; both routes consume the row-major buffer.
    let mut rows = Vec::with_capacity(batch.len() * n_features);
    for r in &batch {
        rows.extend_from_slice(&r.features);
    }
    let results: Vec<Vec<u32>> = if use_xla {
        let engine = xla.as_ref().unwrap();
        match engine.execute(&rows, n_features) {
            Ok(out) => out,
            // Fall back to the batched scalar kernel on runtime errors —
            // requests must never be dropped.
            Err(_) => scalar.predict_fixed_batch(&rows),
        }
    } else {
        scalar.predict_fixed_batch(&rows)
    };
    metrics.record_batch_latency_us(t_serve.elapsed().as_secs_f64() * 1e6);

    let route = if use_xla { Route::Xla } else { Route::Scalar };
    for (req, fixed) in batch.into_iter().zip(results) {
        let latency = req.t_arrival.elapsed();
        metrics.record_latency_us(latency.as_secs_f64() * 1e6);
        metrics.responses.fetch_add(1, Ordering::Relaxed);
        let class = argmax(&fixed);
        // Receiver may have gone away; that's fine.
        let _ = req.tx.send(Response { fixed, class, route, latency });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::inference::Engine;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(1200, 100);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            9,
        );
        (ds, m)
    }

    #[test]
    fn scalar_only_server_answers_correctly() {
        let (ds, m) = model();
        let server = InferenceServer::start(&m, None, ServerConfig::default());
        let oracle = crate::inference::IntEngine::compile(&m);
        for i in 0..50 {
            let r = server.infer(ds.row(i).to_vec());
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)));
            assert_eq!(r.class, oracle.predict(ds.row(i)));
            assert_eq!(r.route, Route::Scalar);
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 50);
        assert_eq!(snap.responses, 50);
        assert_eq!(snap.rows_scalar, 50);
        assert_eq!(snap.rows_xla, 0);
        // Every flush served at least one batch, so batch latency was
        // recorded.
        assert!(snap.batch_latency_mean_us > 0.0);
        // The execution strategy is recorded even without calibration
        // (the engine's compile-time defaults).
        assert_eq!(snap.kernel.as_deref(), Some(TraversalKernel::default().name()));
        let backend = snap.backend.expect("backend recorded at startup");
        assert!(
            SimdBackend::from_name(&backend).unwrap().is_available(),
            "recorded backend {backend} must be executable"
        );
        let threads = snap.threads.expect("thread count recorded at startup");
        assert!((1..=crate::inference::parallel::detected()).contains(&threads));
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let (ds, m) = model();
        let server = std::sync::Arc::new(InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) },
                ..Default::default()
            },
        ));
        let mut rxs = Vec::new();
        for i in 0..200 {
            rxs.push(server.submit(ds.row(i % ds.n_rows()).to_vec()));
        }
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("response");
            assert_eq!(r.fixed.len(), ds.n_classes);
        }
        assert_eq!(server.metrics().responses, 200);
    }

    #[test]
    fn worker_pool_shards_and_answers_correctly() {
        let (ds, m) = model();
        let oracle = crate::inference::IntEngine::compile(&m);
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) },
                n_workers: 4,
                ..Default::default()
            },
        );
        assert_eq!(server.n_workers(), 4);
        let rows: Vec<Vec<f32>> = (0..400).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let responses = server.infer_many(rows);
        assert_eq!(responses.len(), 400);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i % ds.n_rows())), "row {i}");
            assert_eq!(r.route, Route::Scalar);
        }
        let snap = server.metrics();
        assert_eq!(snap.requests, 400);
        assert_eq!(snap.responses, 400);
        assert_eq!(snap.rows_scalar, 400);
        // Every flush respects the per-shard policy cap (exact batch-size
        // quantiles make this a real bound, not a bucket estimate). Note
        // this checks policy enforcement, not shard *distribution* — each
        // Batcher caps its own flushes, so a sharding regression would
        // need a per-shard counter to detect.
        assert!(
            snap.batch_p99 as usize <= 16,
            "flush exceeded per-shard max_batch: p99 = {}",
            snap.batch_p99
        );
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let (ds, m) = model();
        let server =
            InferenceServer::start(&m, None, ServerConfig { n_workers: 0, ..Default::default() });
        assert_eq!(server.n_workers(), 1);
        let r = server.infer(ds.row(0).to_vec());
        assert_eq!(r.fixed.len(), ds.n_classes);
    }

    #[test]
    fn xla_route_used_for_large_batches_and_matches_scalar() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let (ds, m) = model();
        let oracle = crate::inference::IntEngine::compile(&m);
        let server = InferenceServer::start(
            &m,
            Some(dir),
            ServerConfig {
                policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) },
                xla_threshold: 8,
                ..Default::default()
            },
        );
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        let responses = server.infer_many(rows);
        let mut xla_routed = 0;
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i} parity");
            if r.route == Route::Xla {
                xla_routed += 1;
            }
        }
        assert!(xla_routed > 0, "no request took the XLA route");
        assert!(server.metrics().rows_xla > 0);
    }

    #[test]
    fn auto_calibrate_prefers_faster_backend() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            Some(dir),
            ServerConfig { auto_calibrate: true, ..Default::default() },
        );
        // Whatever the calibration decided, requests must be answered
        // correctly (on this 1-core host the scalar route wins).
        let oracle = crate::inference::IntEngine::compile(&m);
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        for (i, r) in server.infer_many(rows).iter().enumerate() {
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)));
        }
    }

    #[test]
    fn auto_calibrate_without_artifacts_picks_a_kernel_and_answers() {
        // No artifacts dir: only the tile-kernel calibration runs. The
        // choice must be invisible — every answer still matches the
        // scalar oracle bit-for-bit.
        let (ds, m) = model();
        let server = InferenceServer::start(
            &m,
            None,
            ServerConfig { auto_calibrate: true, n_workers: 2, ..Default::default() },
        );
        let oracle = crate::inference::IntEngine::compile(&m);
        let rows: Vec<Vec<f32>> = (0..64).map(|i| ds.row(i).to_vec()).collect();
        for (i, r) in server.infer_many(rows).iter().enumerate() {
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i}");
            assert_eq!(r.route, Route::Scalar);
        }
        // Whatever won, the calibrated execution strategy is on record
        // and names real, executable candidates.
        let snap = server.metrics();
        let kernel = snap.kernel.expect("calibrated kernel recorded");
        assert!(TraversalKernel::all().iter().any(|k| k.name() == kernel), "{kernel}");
        let backend = snap.backend.expect("calibrated backend recorded");
        assert!(SimdBackend::from_name(&backend).unwrap().is_available(), "{backend}");
        let threads = snap.threads.expect("calibrated thread count recorded");
        assert!(
            (1..=crate::inference::parallel::detected()).contains(&threads),
            "{threads} threads"
        );
    }

    /// The calibration helper itself: sweeps kernel × available backend
    /// × thread count, returns timings for every candidate, and leaves
    /// the winner set on the engine.
    #[test]
    fn calibrate_execution_sets_winner_and_reports_timings() {
        use crate::inference::Engine as _;
        let (_, m) = model();
        let mut engine = IntEngine::compile(&m);
        let choice = calibrate_execution(&mut engine, m.n_features, 64);
        assert_eq!(engine.kernel(), choice.kernel);
        assert_eq!(engine.backend(), choice.backend);
        assert_eq!(engine.threads(), choice.threads);
        assert!(choice.backend.is_available());
        assert!((1..=crate::inference::parallel::detected()).contains(&choice.threads));
        // Per thread count: branchy once + (branchless + quickscorer)
        // per backend.
        let n_backends = SimdBackend::sweep().len();
        let n_threads = crate::inference::parallel::sweep().len();
        assert_eq!(choice.timings.len(), n_threads * (1 + 2 * n_backends));
        assert!(choice.timings.iter().all(|(_, t)| *t > 0.0));
        // The winner was one of the timed candidates.
        let winner = format!(
            "{}@{}@{}t",
            choice.kernel.name(),
            choice.backend.name(),
            choice.threads
        );
        assert!(
            choice.timings.iter().any(|(n, _)| *n == winner),
            "winner {winner} missing from timings {:?}",
            choice.timings
        );
    }

    #[test]
    #[should_panic(expected = "wrong feature count")]
    fn rejects_wrong_arity() {
        let (_, m) = model();
        let server = InferenceServer::start(&m, None, ServerConfig::default());
        server.infer(vec![1.0, 2.0]);
    }
}
