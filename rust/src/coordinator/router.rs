//! Model registry / request router: multiple named models served side by
//! side, hot-swappable (the "end-to-end framework" face of the system —
//! retrain on new data, re-register, clients never stop).

use super::server::{InferenceServer, Response, ServeError, ServerConfig};
use crate::ir::Model;
use std::collections::HashMap;
use std::sync::{Arc, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Thread-safe name → server mapping.
///
/// Registry locks recover from poisoning: a thread that panicked while
/// holding the lock leaves a perfectly usable `HashMap` behind (every
/// mutation is a single insert/remove), so later routing calls proceed
/// instead of cascading the panic.
#[derive(Default)]
pub struct Router {
    servers: RwLock<HashMap<String, Arc<InferenceServer>>>,
}

/// Routing error.
#[derive(Debug, PartialEq)]
pub enum RouteError {
    /// No model is registered under the given name.
    UnknownModel(String),
    /// A route spec that does not parse (`"id"` or `"id@version"`).
    BadSpec {
        /// The spec as received.
        spec: String,
        /// What was wrong with it.
        why: String,
    },
    /// The model exists but serving it failed (typed serving error).
    Serve(ServeError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            RouteError::BadSpec { spec, why } => write!(f, "bad route spec '{spec}': {why}"),
            RouteError::Serve(e) => write!(f, "serving failed: {e}"),
        }
    }
}
impl std::error::Error for RouteError {}

/// A parsed routing rule: `"id"` follows the fleet's routing policy for
/// that model (A/B split if one is set, else the current version);
/// `"id@version"` pins the request to one resident version. This is the
/// grammar the HTTP front end accepts in `POST /predict/{spec}` and the
/// CLI accepts in `--model`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteSpec {
    /// Model id.
    pub id: String,
    /// Pinned version, if the spec carried one.
    pub version: Option<u64>,
}

impl RouteSpec {
    /// Parse `"id"` / `"id@version"`. The id may not be empty or
    /// contain `@`; the version must be a decimal `u64`.
    pub fn parse(spec: &str) -> Result<RouteSpec, RouteError> {
        let bad = |why: &str| RouteError::BadSpec { spec: spec.to_string(), why: why.to_string() };
        match spec.split_once('@') {
            None => {
                if spec.is_empty() {
                    return Err(bad("empty model id"));
                }
                Ok(RouteSpec { id: spec.to_string(), version: None })
            }
            Some((id, ver)) => {
                if id.is_empty() {
                    return Err(bad("empty model id"));
                }
                if ver.contains('@') {
                    return Err(bad("more than one '@'"));
                }
                let version = ver
                    .parse::<u64>()
                    .map_err(|_| bad("version is not a decimal integer"))?;
                Ok(RouteSpec { id: id.to_string(), version: Some(version) })
            }
        }
    }
}

impl std::str::FromStr for RouteSpec {
    type Err = RouteError;
    fn from_str(s: &str) -> Result<RouteSpec, RouteError> {
        RouteSpec::parse(s)
    }
}

impl std::fmt::Display for RouteSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.version {
            Some(v) => write!(f, "{}@{}", self.id, v),
            None => write!(f, "{}", self.id),
        }
    }
}

impl From<ServeError> for RouteError {
    fn from(e: ServeError) -> RouteError {
        RouteError::Serve(e)
    }
}

impl Router {
    /// Empty registry.
    pub fn new() -> Router {
        Router::default()
    }

    /// Read lock on the registry, recovering from poisoning.
    fn read(&self) -> RwLockReadGuard<'_, HashMap<String, Arc<InferenceServer>>> {
        self.servers.read().unwrap_or_else(PoisonError::into_inner)
    }

    /// Write lock on the registry, recovering from poisoning.
    fn write(&self) -> RwLockWriteGuard<'_, HashMap<String, Arc<InferenceServer>>> {
        self.servers.write().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register (or replace) a model under a name. Replacement is atomic:
    /// in-flight requests finish on the old server (it drains on drop of
    /// the last Arc), new requests see the new one.
    pub fn register(
        &self,
        name: &str,
        model: &Model,
        artifacts_dir: Option<std::path::PathBuf>,
        config: ServerConfig,
    ) {
        let server = Arc::new(InferenceServer::start(model, artifacts_dir, config));
        self.write().insert(name.to_string(), server);
    }

    /// Remove a model. Returns true if it existed.
    pub fn unregister(&self, name: &str) -> bool {
        self.write().remove(name).is_some()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.read().keys().cloned().collect();
        v.sort();
        v
    }

    /// Get a handle to a model's server.
    pub fn server(&self, name: &str) -> Result<Arc<InferenceServer>, RouteError> {
        self.read()
            .get(name)
            .cloned()
            .ok_or_else(|| RouteError::UnknownModel(name.to_string()))
    }

    /// Blocking inference against a named model. Serving failures
    /// surface as [`RouteError::Serve`] — one typed error space for the
    /// whole lookup-then-serve path.
    pub fn infer(&self, name: &str, features: Vec<f32>) -> Result<Response, RouteError> {
        Ok(self.server(name)?.infer(features)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model(seed: u64) -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(600, seed);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
            seed,
        );
        (ds, m)
    }

    #[test]
    fn register_route_unregister() {
        let router = Router::new();
        let (ds, m) = model(110);
        router.register("shuttle", &m, None, ServerConfig::default());
        assert_eq!(router.names(), vec!["shuttle".to_string()]);

        let r = router.infer("shuttle", ds.row(0).to_vec()).unwrap();
        assert_eq!(r.fixed.len(), ds.n_classes);

        assert_eq!(
            router.infer("nope", ds.row(0).to_vec()).unwrap_err(),
            RouteError::UnknownModel("nope".into())
        );

        assert!(router.unregister("shuttle"));
        assert!(!router.unregister("shuttle"));
        assert!(router.infer("shuttle", ds.row(0).to_vec()).is_err());
    }

    #[test]
    fn hot_swap_changes_serving_model() {
        let router = Router::new();
        let (ds, m1) = model(111);
        let (_, m2) = model(112);
        router.register("m", &m1, None, ServerConfig::default());
        let before = router.infer("m", ds.row(0).to_vec()).unwrap();
        router.register("m", &m2, None, ServerConfig::default());
        let after = router.infer("m", ds.row(0).to_vec()).unwrap();
        // Different forests: fixed-point vectors will differ for at least
        // some rows; check over a few to avoid a coincidental collision.
        let mut differs = before.fixed != after.fixed;
        for i in 1..20 {
            let a = router.infer("m", ds.row(i).to_vec()).unwrap();
            let b = crate::inference::IntEngine::compile(&m2).predict_fixed(ds.row(i));
            assert_eq!(a.fixed, b);
            if !differs {
                let old = crate::inference::IntEngine::compile(&m1).predict_fixed(ds.row(i));
                differs = old != b;
            }
        }
        assert!(differs, "models m1/m2 unexpectedly identical");
    }

    #[test]
    fn serving_failures_surface_as_typed_route_errors() {
        let router = Router::new();
        let (_, m) = model(115);
        router.register("m", &m, None, ServerConfig::default());
        let err = router.infer("m", vec![1.0]).unwrap_err();
        assert_eq!(
            err,
            RouteError::Serve(ServeError::WrongFeatureCount { expected: m.n_features, got: 1 })
        );
        // Both error spaces render through one Display.
        assert!(err.to_string().contains("wrong feature count"), "{err}");
        assert!(RouteError::UnknownModel("x".into()).to_string().contains("unknown model"));
    }

    /// A thread panicking while holding the registry lock must not take
    /// routing down: the poison-recovering accessors keep the registry
    /// usable (every mutation is a single insert/remove, so the map is
    /// always valid).
    #[test]
    fn registry_survives_a_poisoned_lock() {
        let router = std::sync::Arc::new(Router::new());
        let (ds, m) = model(116);
        router.register("m", &m, None, ServerConfig::default());
        let r2 = std::sync::Arc::clone(&router);
        let _ = std::thread::spawn(move || {
            let _guard = r2.servers.write().unwrap();
            panic!("poison the registry lock");
        })
        .join();
        assert!(router.servers.read().is_err(), "lock must actually be poisoned");
        // Lookup, serving, registration, and removal all still work.
        assert_eq!(router.names(), vec!["m".to_string()]);
        router.infer("m", ds.row(0).to_vec()).unwrap();
        let (_, m2) = model(117);
        router.register("n", &m2, None, ServerConfig::default());
        assert_eq!(router.names().len(), 2);
        assert!(router.unregister("n"));
    }

    #[test]
    fn route_specs_parse_and_reject() {
        assert_eq!(
            RouteSpec::parse("shuttle").unwrap(),
            RouteSpec { id: "shuttle".into(), version: None }
        );
        assert_eq!(
            RouteSpec::parse("shuttle@3").unwrap(),
            RouteSpec { id: "shuttle".into(), version: Some(3) }
        );
        assert_eq!(RouteSpec::parse("shuttle@3").unwrap().to_string(), "shuttle@3");
        assert_eq!(RouteSpec::parse("shuttle").unwrap().to_string(), "shuttle");
        // FromStr routes through the same parser.
        assert_eq!("m@7".parse::<RouteSpec>().unwrap().version, Some(7));

        for (spec, why_frag) in [
            ("", "empty model id"),
            ("@3", "empty model id"),
            ("m@", "not a decimal"),
            ("m@x", "not a decimal"),
            ("m@1@2", "more than one '@'"),
            ("m@-1", "not a decimal"),
            ("m@18446744073709551616", "not a decimal"), // u64::MAX + 1
        ] {
            let err = RouteSpec::parse(spec).unwrap_err();
            match &err {
                RouteError::BadSpec { spec: s, why } => {
                    assert_eq!(s, spec);
                    assert!(why.contains(why_frag), "{spec}: {why}");
                }
                other => panic!("{spec}: expected BadSpec, got {other:?}"),
            }
            assert!(err.to_string().contains("bad route spec"), "{err}");
        }
    }

    #[test]
    fn multiple_models_servable() {
        let router = Router::new();
        let (ds1, m1) = model(113);
        let esa = crate::data::esa_like(400, 114);
        let m_esa = RandomForest::train(
            &esa,
            &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
            5,
        );
        router.register("shuttle", &m1, None, ServerConfig::default());
        router.register("esa", &m_esa, None, ServerConfig::default());
        assert_eq!(router.names().len(), 2);
        assert_eq!(router.infer("shuttle", ds1.row(0).to_vec()).unwrap().fixed.len(), 7);
        assert_eq!(router.infer("esa", esa.row(0).to_vec()).unwrap().fixed.len(), 2);
    }
}
