//! Model registry / request router: multiple named models served side by
//! side, hot-swappable (the "end-to-end framework" face of the system —
//! retrain on new data, re-register, clients never stop).

use super::server::{InferenceServer, Response, ServerConfig};
use crate::ir::Model;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Thread-safe name → server mapping.
#[derive(Default)]
pub struct Router {
    servers: RwLock<HashMap<String, Arc<InferenceServer>>>,
}

/// Routing error.
#[derive(Debug, PartialEq)]
pub enum RouteError {
    /// No model is registered under the given name.
    UnknownModel(String),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
        }
    }
}
impl std::error::Error for RouteError {}

impl Router {
    /// Empty registry.
    pub fn new() -> Router {
        Router::default()
    }

    /// Register (or replace) a model under a name. Replacement is atomic:
    /// in-flight requests finish on the old server (it drains on drop of
    /// the last Arc), new requests see the new one.
    pub fn register(
        &self,
        name: &str,
        model: &Model,
        artifacts_dir: Option<std::path::PathBuf>,
        config: ServerConfig,
    ) {
        let server = Arc::new(InferenceServer::start(model, artifacts_dir, config));
        self.servers.write().unwrap().insert(name.to_string(), server);
    }

    /// Remove a model. Returns true if it existed.
    pub fn unregister(&self, name: &str) -> bool {
        self.servers.write().unwrap().remove(name).is_some()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.servers.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Get a handle to a model's server.
    pub fn server(&self, name: &str) -> Result<Arc<InferenceServer>, RouteError> {
        self.servers
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| RouteError::UnknownModel(name.to_string()))
    }

    /// Blocking inference against a named model.
    pub fn infer(&self, name: &str, features: Vec<f32>) -> Result<Response, RouteError> {
        Ok(self.server(name)?.infer(features))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model(seed: u64) -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(600, seed);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
            seed,
        );
        (ds, m)
    }

    #[test]
    fn register_route_unregister() {
        let router = Router::new();
        let (ds, m) = model(110);
        router.register("shuttle", &m, None, ServerConfig::default());
        assert_eq!(router.names(), vec!["shuttle".to_string()]);

        let r = router.infer("shuttle", ds.row(0).to_vec()).unwrap();
        assert_eq!(r.fixed.len(), ds.n_classes);

        assert_eq!(
            router.infer("nope", ds.row(0).to_vec()).unwrap_err(),
            RouteError::UnknownModel("nope".into())
        );

        assert!(router.unregister("shuttle"));
        assert!(!router.unregister("shuttle"));
        assert!(router.infer("shuttle", ds.row(0).to_vec()).is_err());
    }

    #[test]
    fn hot_swap_changes_serving_model() {
        let router = Router::new();
        let (ds, m1) = model(111);
        let (_, m2) = model(112);
        router.register("m", &m1, None, ServerConfig::default());
        let before = router.infer("m", ds.row(0).to_vec()).unwrap();
        router.register("m", &m2, None, ServerConfig::default());
        let after = router.infer("m", ds.row(0).to_vec()).unwrap();
        // Different forests: fixed-point vectors will differ for at least
        // some rows; check over a few to avoid a coincidental collision.
        let mut differs = before.fixed != after.fixed;
        for i in 1..20 {
            let a = router.infer("m", ds.row(i).to_vec()).unwrap();
            let b = crate::inference::IntEngine::compile(&m2).predict_fixed(ds.row(i));
            assert_eq!(a.fixed, b);
            if !differs {
                let old = crate::inference::IntEngine::compile(&m1).predict_fixed(ds.row(i));
                differs = old != b;
            }
        }
        assert!(differs, "models m1/m2 unexpectedly identical");
    }

    #[test]
    fn multiple_models_servable() {
        let router = Router::new();
        let (ds1, m1) = model(113);
        let esa = crate::data::esa_like(400, 114);
        let m_esa = RandomForest::train(
            &esa,
            &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
            5,
        );
        router.register("shuttle", &m1, None, ServerConfig::default());
        router.register("esa", &m_esa, None, ServerConfig::default());
        assert_eq!(router.names().len(), 2);
        assert_eq!(router.infer("shuttle", ds1.row(0).to_vec()).unwrap().fixed.len(), 7);
        assert_eq!(router.infer("esa", esa.row(0).to_vec()).unwrap().fixed.len(), 2);
    }
}
