//! Dynamic batching policy — pure logic, unit-testable without threads.
//!
//! Requests arrive at arbitrary times; the batcher accumulates them and
//! decides when to flush: when the batch is full (`max_batch`), when
//! the oldest request has waited `max_wait` (the `--max-batch-delay`
//! knob), when the most urgent pending per-request TTL is about to
//! lapse, or on explicit drain. This is the standard continuous-
//! batching trade-off (throughput vs tail latency) scaled down to
//! tabular inference, made *deadline-aware*: a fixed age deadline alone
//! would let a short-TTL request sit out its whole TTL waiting for
//! batch-mates and then expire at formation, so the effective flush
//! deadline adapts to `min(oldest + max_wait, earliest pending TTL)`.
//!
//! Each worker shard of the [`super::server`] pool owns one `Batcher`;
//! the policy is therefore per shard (a pool of N workers at
//! `max_batch = B` can have up to `N * B` rows in flight).

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long (the
    /// `--max-batch-delay` serving knob; surfaced in metrics as
    /// `max_batch_delay_us`).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) }
    }
}

/// Why a flush happened (exported in metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch reached `max_batch` rows.
    Full,
    /// The oldest request hit the `max_wait` deadline.
    Deadline,
    /// The most urgent pending per-request TTL reached its deadline —
    /// the batch closed early to give that request its last chance to
    /// execute before [`Batcher::partition_expired`] would drop it.
    Ttl,
    /// An explicit drain (shutdown or channel close).
    Drain,
}

/// Accumulates items with arrival timestamps and applies the policy.
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
    /// Earliest TTL deadline among pending items (None when no pending
    /// item carries one). Clamps the age deadline: the effective flush
    /// time is `min(oldest + max_wait, min_deadline)`.
    min_deadline: Option<Instant>,
    /// Recycled backing storage for the next flush ([`Self::recycle`]):
    /// `take()` swaps it in instead of allocating, so a worker that
    /// returns its drained batch after serving keeps flushes
    /// allocation-free in steady state.
    spare: Vec<T>,
}

impl<T> Batcher<T> {
    /// Empty batcher under a policy (`max_batch` must be positive).
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher {
            policy,
            pending: Vec::with_capacity(policy.max_batch),
            oldest: None,
            min_deadline: None,
            spare: Vec::with_capacity(policy.max_batch),
        }
    }

    /// Items currently pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add an item (arrival time injectable for tests). Returns a full
    /// batch if the policy says flush-on-full.
    pub fn push_at(&mut self, item: T, now: Instant) -> Option<(Vec<T>, FlushReason)> {
        self.push_deadline_at(item, None, now)
    }

    /// Add an item carrying an optional TTL deadline (arrival time
    /// injectable for tests). The earliest pending deadline clamps the
    /// batch's age deadline, so a short-TTL request pulls the flush
    /// forward instead of silently expiring at formation. Returns a
    /// full batch if the policy says flush-on-full.
    pub fn push_deadline_at(
        &mut self,
        item: T,
        deadline: Option<Instant>,
        now: Instant,
    ) -> Option<(Vec<T>, FlushReason)> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(item);
        self.min_deadline = match (self.min_deadline, deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        };
        if self.pending.len() >= self.policy.max_batch {
            return Some((self.take(), FlushReason::Full));
        }
        None
    }

    /// Add an item at the current time (see [`Self::push_at`]).
    pub fn push(&mut self, item: T) -> Option<(Vec<T>, FlushReason)> {
        self.push_at(item, Instant::now())
    }

    /// Add an item with a TTL deadline at the current time (see
    /// [`Self::push_deadline_at`]).
    pub fn push_deadline(
        &mut self,
        item: T,
        deadline: Option<Instant>,
    ) -> Option<(Vec<T>, FlushReason)> {
        self.push_deadline_at(item, deadline, Instant::now())
    }

    /// The instant at which the pending batch must flush: the oldest
    /// item's age deadline, clamped by the earliest pending TTL. None
    /// when nothing is pending.
    fn effective_deadline(&self) -> Option<Instant> {
        let t0 = self.oldest.filter(|_| !self.pending.is_empty())?;
        let age = t0 + self.policy.max_wait;
        Some(match self.min_deadline {
            Some(ttl) if ttl < age => ttl,
            _ => age,
        })
    }

    /// Check the deadline; flush if the oldest item has waited too long
    /// or the most urgent pending TTL has come due.
    pub fn poll_at(&mut self, now: Instant) -> Option<(Vec<T>, FlushReason)> {
        let t0 = self.oldest.filter(|_| !self.pending.is_empty())?;
        if now.duration_since(t0) >= self.policy.max_wait {
            return Some((self.take(), FlushReason::Deadline));
        }
        match self.min_deadline {
            Some(ttl) if now >= ttl => Some((self.take(), FlushReason::Ttl)),
            _ => None,
        }
    }

    /// Check the deadline at the current time (see [`Self::poll_at`]).
    pub fn poll(&mut self) -> Option<(Vec<T>, FlushReason)> {
        self.poll_at(Instant::now())
    }

    /// Time until the effective deadline fires (None when empty). The
    /// worker loop bounds its receive timeout with this, so a short-TTL
    /// arrival wakes the shard early enough to serve it.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.effective_deadline().map(|d| d.saturating_duration_since(now))
    }

    /// Unconditionally flush whatever is pending.
    pub fn drain(&mut self) -> Option<(Vec<T>, FlushReason)> {
        if self.pending.is_empty() {
            None
        } else {
            Some((self.take(), FlushReason::Drain))
        }
    }

    fn take(&mut self) -> Vec<T> {
        self.oldest = None;
        self.min_deadline = None;
        // Swap in the recycled spare instead of allocating. Before the
        // first recycle the spare is a fresh `max_batch`-capacity
        // vector; after it, flushes reuse the previous batch's storage.
        std::mem::replace(&mut self.pending, std::mem::take(&mut self.spare))
    }

    /// Hand a served batch's (now fully consumed) backing vector back so
    /// the next flush reuses its capacity instead of allocating. The
    /// vector is cleared here; callers pass the `Vec` they received from
    /// a flush after draining or dropping its items.
    pub fn recycle(&mut self, mut buf: Vec<T>) {
        buf.clear();
        self.spare = buf;
    }

    /// Split a flushed batch into (live, expired) by per-item deadline,
    /// preserving order within each part. An item with deadline `d` is
    /// expired iff `now > d` (a deadline of exactly `now` still serves);
    /// items without a deadline never expire. This is the TTL check the
    /// server applies at batch-formation time — expiry is evaluated when
    /// the batch is about to execute, not at submission, so a request
    /// that waited out its TTL in the queue is answered `DeadlineExceeded`
    /// instead of burning kernel time.
    pub fn partition_expired(
        batch: Vec<T>,
        now: Instant,
        deadline: impl Fn(&T) -> Option<Instant>,
    ) -> (Vec<T>, Vec<T>) {
        let mut live = Vec::with_capacity(batch.len());
        let mut expired = Vec::new();
        for item in batch {
            match deadline(&item) {
                Some(d) if now > d => expired.push(item),
                _ => live.push(item),
            }
        }
        (live, expired)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::check::check;

    fn policy(max_batch: usize, wait_us: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) }
    }

    #[test]
    fn flushes_on_full() {
        let mut b = Batcher::new(policy(3, 1_000_000));
        let t = Instant::now();
        assert!(b.push_at(1, t).is_none());
        assert!(b.push_at(2, t).is_none());
        let (batch, why) = b.push_at(3, t).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert_eq!(why, FlushReason::Full);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(policy(100, 500));
        let t0 = Instant::now();
        b.push_at(1, t0);
        b.push_at(2, t0 + Duration::from_micros(100));
        assert!(b.poll_at(t0 + Duration::from_micros(499)).is_none());
        let (batch, why) = b.poll_at(t0 + Duration::from_micros(500)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(why, FlushReason::Deadline);
    }

    #[test]
    fn deadline_resets_after_flush() {
        let mut b = Batcher::new(policy(10, 500));
        let t0 = Instant::now();
        b.push_at(1, t0);
        b.poll_at(t0 + Duration::from_micros(600)).unwrap();
        // New item: deadline measured from its own arrival.
        b.push_at(2, t0 + Duration::from_micros(700));
        assert!(b.poll_at(t0 + Duration::from_micros(1100)).is_none());
        assert!(b.poll_at(t0 + Duration::from_micros(1200)).is_some());
    }

    #[test]
    fn drain_returns_partial() {
        let mut b = Batcher::new(policy(10, 1_000_000));
        assert!(b.drain().is_none());
        b.push(7);
        let (batch, why) = b.drain().unwrap();
        assert_eq!(batch, vec![7]);
        assert_eq!(why, FlushReason::Drain);
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b = Batcher::new(policy(10, 1000));
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push_at(1, t0);
        let d = b.time_to_deadline(t0 + Duration::from_micros(400)).unwrap();
        assert_eq!(d, Duration::from_micros(600));
        let d2 = b.time_to_deadline(t0 + Duration::from_micros(2000)).unwrap();
        assert_eq!(d2, Duration::ZERO);
    }

    #[test]
    fn ttl_deadline_pulls_flush_forward() {
        // max_wait 1 ms, but a pending request's TTL comes due at 200 us:
        // the batch must close at the TTL, not the age deadline.
        let mut b = Batcher::new(policy(100, 1000));
        let t0 = Instant::now();
        b.push_deadline_at(1, None, t0);
        b.push_deadline_at(2, Some(t0 + Duration::from_micros(200)), t0);
        assert!(b.poll_at(t0 + Duration::from_micros(199)).is_none());
        let (batch, why) = b.poll_at(t0 + Duration::from_micros(200)).unwrap();
        assert_eq!(batch, vec![1, 2]);
        assert_eq!(why, FlushReason::Ttl);
        assert!(b.is_empty());
    }

    #[test]
    fn ttl_tracks_the_minimum_pending_deadline() {
        let mut b = Batcher::new(policy(100, 10_000));
        let t0 = Instant::now();
        b.push_deadline_at(1, Some(t0 + Duration::from_micros(900)), t0);
        b.push_deadline_at(2, Some(t0 + Duration::from_micros(300)), t0);
        b.push_deadline_at(3, Some(t0 + Duration::from_micros(600)), t0);
        // Effective deadline = min TTL = t0+300us.
        let ttd = b.time_to_deadline(t0 + Duration::from_micros(100)).unwrap();
        assert_eq!(ttd, Duration::from_micros(200));
        let (batch, why) = b.poll_at(t0 + Duration::from_micros(300)).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(why, FlushReason::Ttl);
    }

    #[test]
    fn ttl_state_resets_after_flush() {
        let mut b = Batcher::new(policy(100, 1000));
        let t0 = Instant::now();
        b.push_deadline_at(1, Some(t0 + Duration::from_micros(100)), t0);
        assert!(b.poll_at(t0 + Duration::from_micros(100)).is_some());
        // New deadline-free item: back to plain age-based behavior.
        b.push_at(2, t0 + Duration::from_micros(150));
        assert!(b.poll_at(t0 + Duration::from_micros(1100)).is_none());
        let (_, why) = b.poll_at(t0 + Duration::from_micros(1150)).unwrap();
        assert_eq!(why, FlushReason::Deadline);
    }

    #[test]
    fn age_deadline_wins_when_earlier_than_ttl() {
        // TTL far in the future: the age deadline still governs, and the
        // reason stays `Deadline`.
        let mut b = Batcher::new(policy(100, 500));
        let t0 = Instant::now();
        b.push_deadline_at(1, Some(t0 + Duration::from_millis(50)), t0);
        let (_, why) = b.poll_at(t0 + Duration::from_micros(500)).unwrap();
        assert_eq!(why, FlushReason::Deadline);
    }

    #[test]
    fn lapsed_ttl_flushes_immediately_on_next_poll() {
        // A request admitted with an already-lapsed deadline flushes on
        // the very next poll (it will then expire at partition time).
        let mut b = Batcher::new(policy(100, 1_000_000));
        let t0 = Instant::now();
        b.push_deadline_at(1, Some(t0), t0);
        assert_eq!(b.time_to_deadline(t0), Some(Duration::ZERO));
        let (_, why) = b.poll_at(t0).unwrap();
        assert_eq!(why, FlushReason::Ttl);
    }

    #[test]
    fn partition_expired_splits_by_deadline() {
        let t0 = Instant::now();
        // Items carry (id, deadline).
        let batch: Vec<(u64, Option<Instant>)> = vec![
            (0, None),                                    // no TTL: never expires
            (1, Some(t0)),                                // already lapsed
            (2, Some(t0 + Duration::from_micros(500))),   // still live at t0+100us
            (3, Some(t0 + Duration::from_micros(50))),    // lapsed at t0+100us
        ];
        let now = t0 + Duration::from_micros(100);
        let (live, expired) = Batcher::partition_expired(batch, now, |it| it.1);
        assert_eq!(live.iter().map(|it| it.0).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(expired.iter().map(|it| it.0).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn partition_expired_boundary_is_inclusive_for_serving() {
        // A deadline of exactly `now` still serves: expiry is strict
        // (`now > d`), matching "TTL of the remaining wait".
        let t0 = Instant::now();
        let (live, expired) =
            Batcher::partition_expired(vec![(1u8, Some(t0))], t0, |it| it.1);
        assert_eq!(live.len(), 1);
        assert!(expired.is_empty());
    }

    #[test]
    fn partition_expired_all_live_and_all_expired() {
        let t0 = Instant::now();
        let now = t0 + Duration::from_millis(10);
        let all_live: Vec<(u8, Option<Instant>)> = (0..5).map(|i| (i, None)).collect();
        let (live, expired) = Batcher::partition_expired(all_live, now, |it| it.1);
        assert_eq!((live.len(), expired.len()), (5, 0));
        let all_dead: Vec<(u8, Option<Instant>)> = (0..5).map(|i| (i, Some(t0))).collect();
        let (live, expired) = Batcher::partition_expired(all_dead, now, |it| it.1);
        assert_eq!((live.len(), expired.len()), (0, 5));
        // Order preserved inside the expired part too.
        assert_eq!(expired.iter().map(|it| it.0).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    /// Property: partition_expired never loses or duplicates an item,
    /// whatever the deadline pattern (the TTL sibling of the batcher's
    /// no-loss invariant).
    #[test]
    fn prop_partition_expired_no_loss() {
        check(
            "partition_expired_no_loss",
            |r| {
                let n = r.below(40);
                // Per item: 0 = no TTL, 1 = lapsed, 2 = live.
                (0..n).map(|_| r.below(3) as u8).collect::<Vec<_>>()
            },
            |pattern| {
                let t0 = Instant::now();
                let now = t0 + Duration::from_micros(100);
                let batch: Vec<(usize, Option<Instant>)> = pattern
                    .iter()
                    .enumerate()
                    .map(|(i, &k)| {
                        let d = match k {
                            0 => None,
                            1 => Some(t0),
                            _ => Some(now + Duration::from_micros(50)),
                        };
                        (i, d)
                    })
                    .collect();
                let n_lapsed = pattern.iter().filter(|&&k| k == 1).count();
                let (live, expired) = Batcher::partition_expired(batch, now, |it| it.1);
                prop_ensure!(
                    expired.len() == n_lapsed,
                    "expired {} != lapsed {n_lapsed}",
                    expired.len()
                );
                let mut ids: Vec<usize> =
                    live.iter().chain(expired.iter()).map(|it| it.0).collect();
                ids.sort_unstable();
                prop_ensure!(
                    ids == (0..pattern.len()).collect::<Vec<_>>(),
                    "items lost or duplicated: {ids:?}"
                );
                Ok(())
            },
        );
    }

    /// Property: no item is ever lost or duplicated across an arbitrary
    /// push/poll/drain sequence (the coordinator-invariant check).
    #[test]
    fn prop_no_loss_no_duplication() {
        check(
            "batcher_no_loss",
            |r| {
                let n_ops = 1 + r.below(60);
                (0..n_ops)
                    .map(|_| (r.below(3) as u8, r.below(1000) as u64))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut b = Batcher::new(policy(4, 100));
                let t0 = Instant::now();
                let mut pushed: Vec<u64> = Vec::new();
                let mut flushed: Vec<u64> = Vec::new();
                let mut next_id = 0u64;
                let mut now = t0;
                for &(op, dt) in ops {
                    now += Duration::from_micros(dt);
                    match op {
                        0 => {
                            pushed.push(next_id);
                            if let Some((batch, _)) = b.push_at(next_id, now) {
                                flushed.extend(batch);
                            }
                            next_id += 1;
                        }
                        1 => {
                            if let Some((batch, _)) = b.poll_at(now) {
                                flushed.extend(batch);
                            }
                        }
                        _ => {
                            if let Some((batch, _)) = b.drain() {
                                flushed.extend(batch);
                            }
                        }
                    }
                }
                if let Some((batch, _)) = b.drain() {
                    flushed.extend(batch);
                }
                prop_ensure!(flushed == pushed, "items lost/reordered: {flushed:?} vs {pushed:?}");
                Ok(())
            },
        );
    }
}
