//! Serving metrics: request counters and latency histograms per route.

use crate::util::stats::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink (cheap atomic counters; histograms behind a
/// mutex touched once per request completion).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches_scalar: AtomicU64,
    pub batches_xla: AtomicU64,
    pub rows_scalar: AtomicU64,
    pub rows_xla: AtomicU64,
    pub flush_full: AtomicU64,
    pub flush_deadline: AtomicU64,
    pub flush_drain: AtomicU64,
    latency_us: Mutex<Histogram>,
    batch_sizes: Mutex<Histogram>,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches_scalar: u64,
    pub batches_xla: u64,
    pub rows_scalar: u64,
    pub rows_xla: u64,
    pub flush_full: u64,
    pub flush_deadline: u64,
    pub flush_drain: u64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latency_us.lock().unwrap().record(us);
    }

    pub fn record_batch(&self, size: usize, xla: bool, reason: super::FlushReason) {
        if xla {
            self.batches_xla.fetch_add(1, Ordering::Relaxed);
            self.rows_xla.fetch_add(size as u64, Ordering::Relaxed);
        } else {
            self.batches_scalar.fetch_add(1, Ordering::Relaxed);
            self.rows_scalar.fetch_add(size as u64, Ordering::Relaxed);
        }
        match reason {
            super::FlushReason::Full => self.flush_full.fetch_add(1, Ordering::Relaxed),
            super::FlushReason::Deadline => self.flush_deadline.fetch_add(1, Ordering::Relaxed),
            super::FlushReason::Drain => self.flush_drain.fetch_add(1, Ordering::Relaxed),
        };
        self.batch_sizes.lock().unwrap().record(size as f64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency_us.lock().unwrap();
        let sizes = self.batch_sizes.lock().unwrap();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            batches_scalar: self.batches_scalar.load(Ordering::Relaxed),
            batches_xla: self.batches_xla.load(Ordering::Relaxed),
            rows_scalar: self.rows_scalar.load(Ordering::Relaxed),
            rows_xla: self.rows_xla.load(Ordering::Relaxed),
            flush_full: self.flush_full.load(Ordering::Relaxed),
            flush_deadline: self.flush_deadline.load(Ordering::Relaxed),
            flush_drain: self.flush_drain.load(Ordering::Relaxed),
            latency_mean_us: lat.mean(),
            latency_p50_us: lat.quantile(0.5),
            latency_p99_us: lat.quantile(0.99),
            mean_batch: sizes.mean(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FlushReason;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.record_batch(3, false, FlushReason::Full);
        m.record_batch(64, true, FlushReason::Deadline);
        m.record_latency_us(100.0);
        m.record_latency_us(300.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.batches_scalar, 1);
        assert_eq!(s.batches_xla, 1);
        assert_eq!(s.rows_scalar, 3);
        assert_eq!(s.rows_xla, 64);
        assert_eq!(s.flush_full, 1);
        assert_eq!(s.flush_deadline, 1);
        assert!((s.latency_mean_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 33.5).abs() < 1e-9);
    }
}
