//! Serving metrics: request counters and latency histograms per route,
//! plus the failure-model counters (shed / expired / rejected / lost,
//! worker panics and restarts, and the degraded-state flag).
//!
//! All mutex-guarded state is accessed through poison-recovering locks
//! ([`super::lock_unpoisoned`]): one panicked thread must not cascade
//! into a poisoned-lock panic in every later metrics call — the data is
//! plain counters and histograms, always valid whatever thread died
//! mid-update.

use super::lock_unpoisoned;
use crate::util::stats::Histogram;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink (cheap atomic counters; histograms behind a
/// mutex touched once per request completion).
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted.
    pub requests: AtomicU64,
    /// Responses delivered.
    pub responses: AtomicU64,
    /// Batches served on the scalar route.
    pub batches_scalar: AtomicU64,
    /// Batches served on the XLA route.
    pub batches_xla: AtomicU64,
    /// Rows served on the scalar route.
    pub rows_scalar: AtomicU64,
    /// Rows served on the XLA route.
    pub rows_xla: AtomicU64,
    /// Flushes triggered by a full batch.
    pub flush_full: AtomicU64,
    /// Flushes triggered by the wait deadline.
    pub flush_deadline: AtomicU64,
    /// Flushes triggered by drain/shutdown.
    pub flush_drain: AtomicU64,
    /// Flushes pulled forward by a pending per-request TTL (the
    /// deadline-aware close: the batch executed early so the most
    /// urgent request got its last chance instead of expiring).
    pub flush_ttl: AtomicU64,
    /// HTTP requests parsed off a socket by the `net` front end.
    pub http_requests: AtomicU64,
    /// HTTP responses flushed back to sockets by the `net` front end.
    pub http_responses: AtomicU64,
    /// Requests shed at admission (queue full, or a scripted fault).
    pub shed: AtomicU64,
    /// Admitted requests whose TTL lapsed before execution.
    pub expired: AtomicU64,
    /// Requests refused at validation (wrong arity, non-finite values).
    pub rejected: AtomicU64,
    /// Admitted requests answered `WorkerLost` (their shard crashed).
    pub lost: AtomicU64,
    /// Batch executions that panicked (caught by the shard supervisor).
    pub worker_panics: AtomicU64,
    /// Worker-loop restarts performed by shard supervisors.
    pub worker_restarts: AtomicU64,
    /// True once any shard degraded to the fallback execution strategy.
    pub degraded: AtomicBool,
    /// Resident bytes across every model currently published in the
    /// fleet registry (node arrays + SoA planes + QuickScorer tables).
    /// Maintained by [`super::ModelRegistry`]: incremented on publish,
    /// decremented when a retired version is dropped.
    pub model_bytes: AtomicU64,
    /// Number of model versions currently resident (published or still
    /// draining after a hot swap).
    pub model_count: AtomicU64,
    latency_us: Mutex<Histogram>,
    batch_sizes: Mutex<SizeHistogram>,
    /// Time to *execute* one flushed batch (flatten + forest walks; the
    /// per-request response fan-out is excluded) regardless of route —
    /// the quantity the batch-first refactor optimizes, reported per
    /// batch rather than per request.
    batch_latency_us: Mutex<Histogram>,
    /// The execution strategy serving the scalar route — (traversal
    /// kernel, SIMD backend, intra-batch thread count), recorded once at
    /// server startup (the calibrated winner, or the compile-time
    /// defaults). `None` until a server records it.
    execution: Mutex<Option<(String, String, usize)>>,
    /// End-to-end SLO latency: first request byte read off the socket to
    /// response bytes flushed back. Recorded by the HTTP front end, so
    /// it covers parse + admission + queueing + batch execution + write
    /// — the quantity a client-facing p99 SLO is stated against.
    e2e_us: Mutex<Histogram>,
    /// Batching policy the server was started with: (`max_batch`,
    /// `max_batch_delay` in microseconds). `None` until a server
    /// records it.
    policy: Mutex<Option<(usize, u64)>>,
}

/// Exact histogram for small integer values (batch sizes). Unlike the
/// power-of-two latency [`Histogram`], quantiles here must be *exact* —
/// batch sizes are bounded by the policy's `max_batch`, and reporting a
/// bucket upper bound (e.g. p50 = 128 for a server capped at 64) would
/// be nonsense.
#[derive(Clone, Debug, Default)]
struct SizeHistogram {
    /// counts[v] = occurrences of value v (grown on demand).
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl SizeHistogram {
    fn record(&mut self, value: usize) {
        if value >= self.counts.len() {
            self.counts.resize(value + 1, 0);
        }
        self.counts[value] += 1;
        self.count += 1;
        self.sum += value as f64;
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact nearest-rank quantile.
    fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64) * p.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (v, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return v as f64;
            }
        }
        (self.counts.len().saturating_sub(1)) as f64
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests submitted.
    pub requests: u64,
    /// Responses delivered.
    pub responses: u64,
    /// Batches served on the scalar route.
    pub batches_scalar: u64,
    /// Batches served on the XLA route.
    pub batches_xla: u64,
    /// Rows served on the scalar route.
    pub rows_scalar: u64,
    /// Rows served on the XLA route.
    pub rows_xla: u64,
    /// Flushes triggered by a full batch.
    pub flush_full: u64,
    /// Flushes triggered by the wait deadline.
    pub flush_deadline: u64,
    /// Flushes triggered by drain/shutdown.
    pub flush_drain: u64,
    /// Flushes pulled forward by a pending per-request TTL.
    pub flush_ttl: u64,
    /// HTTP requests parsed off a socket by the `net` front end.
    pub http_requests: u64,
    /// HTTP responses flushed back to sockets by the `net` front end.
    pub http_responses: u64,
    /// Requests shed at admission (queue full, or a scripted fault).
    pub shed: u64,
    /// Admitted requests whose TTL lapsed before execution.
    pub expired: u64,
    /// Requests refused at validation (wrong arity, non-finite values).
    pub rejected: u64,
    /// Admitted requests answered `WorkerLost` (their shard crashed).
    pub lost: u64,
    /// Batch executions that panicked (caught by the shard supervisor).
    pub worker_panics: u64,
    /// Worker-loop restarts performed by shard supervisors.
    pub worker_restarts: u64,
    /// True once any shard degraded to the fallback execution strategy.
    pub degraded: bool,
    /// Resident bytes across every model version in the fleet registry.
    pub model_bytes: u64,
    /// Number of model versions currently resident in the registry.
    pub model_count: u64,
    /// Mean per-request latency (us).
    pub latency_mean_us: f64,
    /// Median per-request latency (us, bucket upper bound).
    pub latency_p50_us: f64,
    /// p99 per-request latency (us, bucket upper bound).
    pub latency_p99_us: f64,
    /// Mean rows per flushed batch.
    pub mean_batch: f64,
    /// Batch-size distribution (exact p50 of rows per flushed batch).
    pub batch_p50: f64,
    /// Exact p99 of rows per flushed batch.
    pub batch_p99: f64,
    /// Mean per-batch service time (us).
    pub batch_latency_mean_us: f64,
    /// Median per-batch service time (us, bucket upper bound).
    pub batch_latency_p50_us: f64,
    /// p99 per-batch service time (us, bucket upper bound).
    pub batch_latency_p99_us: f64,
    /// Traversal kernel serving the scalar route (recorded at server
    /// startup; `None` when no server recorded one yet).
    pub kernel: Option<String>,
    /// SIMD execution backend serving the scalar route.
    pub backend: Option<String>,
    /// Intra-batch thread count serving the scalar route.
    pub threads: Option<usize>,
    /// Mean end-to-end (socket-to-socket) latency (us).
    pub e2e_mean_us: f64,
    /// Median end-to-end latency (us, bucket upper bound).
    pub e2e_p50_us: f64,
    /// p99 end-to-end latency (us, bucket upper bound) — the SLO number.
    pub e2e_p99_us: f64,
    /// `max_batch` the serving policy was started with (`None` until a
    /// server records its policy).
    pub max_batch: Option<usize>,
    /// `max_batch_delay` in microseconds the serving policy was started
    /// with.
    pub max_batch_delay_us: Option<u64>,
    /// CPU SIMD features detected on this host (computed at snapshot
    /// time; explains *why* the backend was picked).
    pub detected_features: Vec<&'static str>,
}

impl Metrics {
    /// Fresh zeroed metrics sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency_us(&self, us: f64) {
        lock_unpoisoned(&self.latency_us).record(us);
    }

    /// Record how long serving one flushed batch took.
    pub fn record_batch_latency_us(&self, us: f64) {
        lock_unpoisoned(&self.batch_latency_us).record(us);
    }

    /// Record one request's end-to-end (socket-to-socket) latency —
    /// first request byte read to response bytes flushed.
    pub fn record_e2e_us(&self, us: f64) {
        lock_unpoisoned(&self.e2e_us).record(us);
    }

    /// Record the batching policy the server was started with
    /// (`max_batch` rows, `max_batch_delay` in microseconds).
    pub fn record_policy(&self, max_batch: usize, max_batch_delay_us: u64) {
        *lock_unpoisoned(&self.policy) = Some((max_batch, max_batch_delay_us));
    }

    /// Record the execution strategy serving the scalar route (called
    /// once at server startup with the calibrated — or default —
    /// traversal kernel, SIMD backend, and intra-batch thread count).
    pub fn record_execution(&self, kernel: &str, backend: &str, threads: usize) {
        *lock_unpoisoned(&self.execution) =
            Some((kernel.to_string(), backend.to_string(), threads));
    }

    /// Record one flushed batch (size, route, and why it flushed).
    pub fn record_batch(&self, size: usize, xla: bool, reason: super::FlushReason) {
        if xla {
            self.batches_xla.fetch_add(1, Ordering::Relaxed);
            self.rows_xla.fetch_add(size as u64, Ordering::Relaxed);
        } else {
            self.batches_scalar.fetch_add(1, Ordering::Relaxed);
            self.rows_scalar.fetch_add(size as u64, Ordering::Relaxed);
        }
        match reason {
            super::FlushReason::Full => self.flush_full.fetch_add(1, Ordering::Relaxed),
            super::FlushReason::Deadline => self.flush_deadline.fetch_add(1, Ordering::Relaxed),
            super::FlushReason::Ttl => self.flush_ttl.fetch_add(1, Ordering::Relaxed),
            super::FlushReason::Drain => self.flush_drain.fetch_add(1, Ordering::Relaxed),
        };
        lock_unpoisoned(&self.batch_sizes).record(size);
    }

    /// Point-in-time copy of every counter and histogram summary.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = lock_unpoisoned(&self.latency_us);
        let sizes = lock_unpoisoned(&self.batch_sizes);
        let blat = lock_unpoisoned(&self.batch_latency_us);
        let e2e = lock_unpoisoned(&self.e2e_us);
        let execution = lock_unpoisoned(&self.execution).clone();
        let policy = *lock_unpoisoned(&self.policy);
        let (kernel, backend, threads) = match execution {
            Some((k, b, t)) => (Some(k), Some(b), Some(t)),
            None => (None, None, None),
        };
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            batches_scalar: self.batches_scalar.load(Ordering::Relaxed),
            batches_xla: self.batches_xla.load(Ordering::Relaxed),
            rows_scalar: self.rows_scalar.load(Ordering::Relaxed),
            rows_xla: self.rows_xla.load(Ordering::Relaxed),
            flush_full: self.flush_full.load(Ordering::Relaxed),
            flush_deadline: self.flush_deadline.load(Ordering::Relaxed),
            flush_drain: self.flush_drain.load(Ordering::Relaxed),
            flush_ttl: self.flush_ttl.load(Ordering::Relaxed),
            http_requests: self.http_requests.load(Ordering::Relaxed),
            http_responses: self.http_responses.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            lost: self.lost.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            model_bytes: self.model_bytes.load(Ordering::Relaxed),
            model_count: self.model_count.load(Ordering::Relaxed),
            latency_mean_us: lat.mean(),
            latency_p50_us: lat.quantile(0.5),
            latency_p99_us: lat.quantile(0.99),
            mean_batch: sizes.mean(),
            batch_p50: sizes.quantile(0.5),
            batch_p99: sizes.quantile(0.99),
            batch_latency_mean_us: blat.mean(),
            batch_latency_p50_us: blat.quantile(0.5),
            batch_latency_p99_us: blat.quantile(0.99),
            kernel,
            backend,
            threads,
            e2e_mean_us: e2e.mean(),
            e2e_p50_us: e2e.quantile(0.5),
            e2e_p99_us: e2e.quantile(0.99),
            max_batch: policy.map(|(b, _)| b),
            max_batch_delay_us: policy.map(|(_, d)| d),
            detected_features: crate::inference::SimdBackend::detected_features(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FlushReason;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.record_batch(3, false, FlushReason::Full);
        m.record_batch(64, true, FlushReason::Deadline);
        m.record_latency_us(100.0);
        m.record_latency_us(300.0);
        m.record_batch_latency_us(50.0);
        m.record_batch_latency_us(150.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.batches_scalar, 1);
        assert_eq!(s.batches_xla, 1);
        assert_eq!(s.rows_scalar, 3);
        assert_eq!(s.rows_xla, 64);
        assert_eq!(s.flush_full, 1);
        assert_eq!(s.flush_deadline, 1);
        assert!((s.latency_mean_us - 200.0).abs() < 1e-9);
        assert!((s.mean_batch - 33.5).abs() < 1e-9);
        // Batch-size quantiles are exact (SizeHistogram, not the
        // power-of-two latency buckets).
        assert_eq!(s.batch_p50, 3.0);
        assert_eq!(s.batch_p99, 64.0);
        assert!((s.batch_latency_mean_us - 100.0).abs() < 1e-9);
        // Latency quantiles remain bucket upper bounds.
        assert!(s.batch_latency_p50_us >= 50.0);
        assert!(s.batch_latency_p99_us >= s.batch_latency_p50_us);
    }

    #[test]
    fn execution_recorded_and_snapshotted() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.kernel, None);
        assert_eq!(s.backend, None);
        assert_eq!(s.threads, None);
        m.record_execution("branchless", "avx2", 4);
        let s = m.snapshot();
        assert_eq!(s.kernel.as_deref(), Some("branchless"));
        assert_eq!(s.backend.as_deref(), Some("avx2"));
        assert_eq!(s.threads, Some(4));
        // detected_features reflects this host's CPU, matching the simd
        // module's availability report.
        assert_eq!(
            s.detected_features,
            crate::inference::SimdBackend::detected_features()
        );
    }

    #[test]
    fn failure_counters_accumulate_and_snapshot() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(
            (s.shed, s.expired, s.rejected, s.lost, s.worker_panics, s.worker_restarts),
            (0, 0, 0, 0, 0, 0)
        );
        assert!(!s.degraded);
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.expired.fetch_add(2, Ordering::Relaxed);
        m.rejected.fetch_add(1, Ordering::Relaxed);
        m.lost.fetch_add(4, Ordering::Relaxed);
        m.worker_panics.fetch_add(1, Ordering::Relaxed);
        m.worker_restarts.fetch_add(1, Ordering::Relaxed);
        m.degraded.store(true, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 3);
        assert_eq!(s.expired, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.lost, 4);
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.worker_restarts, 1);
        assert!(s.degraded);
    }

    /// A thread panicking while holding a metrics lock must not break
    /// every later metrics call: the poison-recovering accessor keeps
    /// recording and snapshotting (the data is always-valid counters).
    #[test]
    fn metrics_survive_a_poisoned_lock() {
        let m = std::sync::Arc::new(Metrics::new());
        m.record_latency_us(100.0);
        let m2 = std::sync::Arc::clone(&m);
        // Poison latency_us by panicking while the guard is held.
        let _ = std::thread::spawn(move || {
            let _guard = m2.latency_us.lock().unwrap();
            panic!("poison the metrics lock");
        })
        .join();
        assert!(m.latency_us.lock().is_err(), "lock must actually be poisoned");
        // Recording and snapshotting still work.
        m.record_latency_us(300.0);
        m.record_batch(8, false, FlushReason::Full);
        m.record_execution("branchless", "scalar", 1);
        let s = m.snapshot();
        assert!((s.latency_mean_us - 200.0).abs() < 1e-9);
        assert_eq!(s.batches_scalar, 1);
        assert_eq!(s.kernel.as_deref(), Some("branchless"));
    }

    #[test]
    fn e2e_slo_and_policy_snapshot() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.e2e_p99_us, 0.0);
        assert_eq!(s.max_batch, None);
        assert_eq!(s.max_batch_delay_us, None);
        m.record_e2e_us(100.0);
        m.record_e2e_us(300.0);
        m.record_policy(64, 250);
        m.record_batch(5, false, FlushReason::Ttl);
        m.http_requests.fetch_add(2, Ordering::Relaxed);
        m.http_responses.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.e2e_mean_us - 200.0).abs() < 1e-9);
        assert!(s.e2e_p50_us >= 100.0);
        assert!(s.e2e_p99_us >= s.e2e_p50_us);
        assert_eq!(s.max_batch, Some(64));
        assert_eq!(s.max_batch_delay_us, Some(250));
        assert_eq!(s.flush_ttl, 1);
        assert_eq!(s.http_requests, 2);
        assert_eq!(s.http_responses, 2);
    }

    #[test]
    fn fleet_gauges_accumulate_and_release() {
        // The registry publishes two versions, then drops one: the
        // gauges must track resident bytes and version count exactly.
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (0, 0));
        m.model_bytes.fetch_add(4096, Ordering::Relaxed);
        m.model_count.fetch_add(1, Ordering::Relaxed);
        m.model_bytes.fetch_add(8192, Ordering::Relaxed);
        m.model_count.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (12288, 2));
        m.model_bytes.fetch_sub(4096, Ordering::Relaxed);
        m.model_count.fetch_sub(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (8192, 1));
    }

    #[test]
    fn batch_size_quantiles_exact_at_cap() {
        // A server that always flushes full 64-row batches must report
        // p50 = p99 = 64, not a bucket bound like 128.
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_batch(64, false, FlushReason::Full);
        }
        let s = m.snapshot();
        assert_eq!(s.batch_p50, 64.0);
        assert_eq!(s.batch_p99, 64.0);
        assert_eq!(s.mean_batch, 64.0);
    }
}
