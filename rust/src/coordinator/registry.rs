//! Versioned model fleet registry: `(model_id, version)`-keyed serving
//! with atomic hot-swap.
//!
//! The [`Router`](super::Router) maps a *name* to one server; the fleet
//! registry adds the second axis production needs — **versions**. Each
//! model id owns a *slot*: the currently-published version plus any
//! older versions explicitly retained for pinned lookups or A/B splits.
//!
//! ## Swap-drain protocol
//!
//! Publishing version *v+1* swaps the slot's `Arc<ModelEntry>` under a
//! short write lock, then drops the previous entry **after** the lock
//! is released, on the *calling* thread. Dropping the last `Arc`
//! reference runs [`InferenceServer`]'s `Drop`: shutdown messages go to
//! every shard, workers drain their pending batches, and the publisher
//! joins them. In-flight requests therefore finish on the version that
//! admitted them; requests arriving after the swap resolve to the new
//! version; nothing is lost, and routing is never blocked on the drain
//! (readers only contend on the brief pointer swap).
//!
//! ## Memory accounting
//!
//! Every [`ModelEntry`] increments the fleet gauges
//! ([`Metrics::model_bytes`] / [`Metrics::model_count`]) at
//! construction and decrements them in `Drop` — the gauges track true
//! residency, *including* versions still draining after retirement.

use super::server::{InferenceServer, Response, ServeError};
use super::Metrics;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// One resident model version: identity, memory footprint, and the
/// server answering for it. Constructing an entry charges the fleet
/// gauges; dropping it (after the last `Arc` ref goes away, i.e. once
/// the drain finished) releases them.
pub struct ModelEntry {
    id: String,
    version: u64,
    resident_bytes: u64,
    server: InferenceServer,
    metrics: Arc<Metrics>,
}

impl ModelEntry {
    fn new(
        id: String,
        version: u64,
        resident_bytes: u64,
        server: InferenceServer,
        metrics: Arc<Metrics>,
    ) -> ModelEntry {
        metrics.model_bytes.fetch_add(resident_bytes, Ordering::Relaxed);
        metrics.model_count.fetch_add(1, Ordering::Relaxed);
        ModelEntry { id, version, resident_bytes, server, metrics }
    }

    /// Model id this entry serves under.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Version of this entry.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Resident bytes charged to the fleet gauges for this version.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// The server answering for this version.
    pub fn server(&self) -> &InferenceServer {
        &self.server
    }
}

impl Drop for ModelEntry {
    fn drop(&mut self) {
        self.metrics.model_bytes.fetch_sub(self.resident_bytes, Ordering::Relaxed);
        self.metrics.model_count.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Typed fleet-registry error.
#[derive(Debug, PartialEq)]
pub enum RegistryError {
    /// No model is published under the given id.
    UnknownModel(String),
    /// The model exists but the requested version is not resident.
    UnknownVersion {
        /// Model id looked up.
        id: String,
        /// Version requested.
        version: u64,
    },
    /// Publishing a version not newer than the one already serving.
    StaleVersion {
        /// Model id published to.
        id: String,
        /// Version currently serving.
        current: u64,
        /// Version offered.
        offered: u64,
    },
    /// Retiring the currently-serving version (publish a successor, or
    /// remove the model outright).
    RetireCurrent {
        /// Model id.
        id: String,
        /// The current version that was asked to retire.
        version: u64,
    },
    /// A/B split percentage outside `0..=100`.
    BadSplit {
        /// Offending percentage.
        percent: u32,
    },
    /// The model resolved but serving it failed (typed serving error).
    Serve(ServeError),
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::UnknownModel(id) => write!(f, "unknown model '{id}'"),
            RegistryError::UnknownVersion { id, version } => {
                write!(f, "model '{id}' has no resident version {version}")
            }
            RegistryError::StaleVersion { id, current, offered } => write!(
                f,
                "stale publish for '{id}': offered version {offered}, already serving {current}"
            ),
            RegistryError::RetireCurrent { id, version } => {
                write!(f, "version {version} is currently serving '{id}'; cannot retire it")
            }
            RegistryError::BadSplit { percent } => {
                write!(f, "split percentage {percent} outside 0..=100")
            }
            RegistryError::Serve(e) => write!(f, "serving failed: {e}"),
        }
    }
}
impl std::error::Error for RegistryError {}

impl RegistryError {
    /// Machine-readable kind for HTTP error bodies (mirrors
    /// [`ServeError::kind`]).
    pub fn kind(&self) -> &'static str {
        match self {
            RegistryError::UnknownModel(_) => "unknown_model",
            RegistryError::UnknownVersion { .. } => "unknown_version",
            RegistryError::StaleVersion { .. } => "stale_version",
            RegistryError::RetireCurrent { .. } => "retire_current",
            RegistryError::BadSplit { .. } => "bad_split",
            RegistryError::Serve(e) => e.kind(),
        }
    }
}

impl From<ServeError> for RegistryError {
    fn from(e: ServeError) -> RegistryError {
        RegistryError::Serve(e)
    }
}

/// A/B traffic split: `percent`% of un-pinned traffic goes to
/// `version`, the rest to the slot's current version.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Split {
    version: u64,
    percent: u32,
}

/// One model id's resident versions.
struct Slot {
    current: Arc<ModelEntry>,
    /// Older versions still resolvable (pinned lookups, A/B splits),
    /// in publication order (strictly increasing versions).
    retained: Vec<Arc<ModelEntry>>,
    split: Option<Split>,
}

impl Slot {
    fn find(&self, version: u64) -> Option<&Arc<ModelEntry>> {
        if self.current.version == version {
            return Some(&self.current);
        }
        self.retained.iter().find(|e| e.version == version)
    }
}

/// Point-in-time description of one published model (for `GET /models`
/// and the CLI).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfo {
    /// Model id.
    pub id: String,
    /// Currently-serving version.
    pub version: u64,
    /// Feature arity of the serving version.
    pub n_features: usize,
    /// Resident bytes of the serving version.
    pub resident_bytes: u64,
    /// Older versions still resident (pinned / A/B), ascending.
    pub retained: Vec<u64>,
    /// Active A/B split, if any: `(version, percent)` of un-pinned
    /// traffic diverted to `version`.
    pub split: Option<(u64, u32)>,
}

/// Thread-safe fleet registry. Locks recover from poisoning exactly as
/// the [`Router`](super::Router)'s do: every mutation leaves a valid
/// map behind, so a panicked publisher must not take routing down.
pub struct ModelRegistry {
    metrics: Arc<Metrics>,
    slots: RwLock<HashMap<String, Slot>>,
    /// Monotone ticket dispenser for the percentage split: ticket
    /// `t` goes to the split version iff `t % 100 < percent` —
    /// deterministic, lock-free, exact over any 100-request window.
    ticket: AtomicU64,
}

impl ModelRegistry {
    /// Empty registry charging residency to `metrics`.
    pub fn new(metrics: Arc<Metrics>) -> ModelRegistry {
        ModelRegistry { metrics, slots: RwLock::new(HashMap::new()), ticket: AtomicU64::new(0) }
    }

    /// The metrics sink fleet gauges are charged to.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn read(&self) -> RwLockReadGuard<'_, HashMap<String, Slot>> {
        self.slots.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn write(&self) -> RwLockWriteGuard<'_, HashMap<String, Slot>> {
        self.slots.write().unwrap_or_else(PoisonError::into_inner)
    }

    /// Publish `(id, version)`: new ids are inserted, existing ids are
    /// hot-swapped and the previous version **drains on this thread**
    /// (see the module docs for the swap-drain protocol). Versions must
    /// be strictly increasing per id.
    pub fn publish(
        &self,
        id: &str,
        version: u64,
        resident_bytes: u64,
        server: InferenceServer,
    ) -> Result<(), RegistryError> {
        self.publish_inner(id, version, resident_bytes, server, false)
    }

    /// Like [`Self::publish`], but the previous current version stays
    /// resident (resolvable by pinned version and eligible as an A/B
    /// split target) until [`Self::retire`]d.
    pub fn publish_retaining(
        &self,
        id: &str,
        version: u64,
        resident_bytes: u64,
        server: InferenceServer,
    ) -> Result<(), RegistryError> {
        self.publish_inner(id, version, resident_bytes, server, true)
    }

    fn publish_inner(
        &self,
        id: &str,
        version: u64,
        resident_bytes: u64,
        server: InferenceServer,
        retain: bool,
    ) -> Result<(), RegistryError> {
        // The outgoing entry must drop *outside* the write lock: its
        // drain joins worker threads, and holding the lock across that
        // would stall every concurrent resolve.
        let mut dropped: Option<Arc<ModelEntry>> = None;
        {
            let mut slots = self.write();
            if let Some(slot) = slots.get(id) {
                if version <= slot.current.version {
                    return Err(RegistryError::StaleVersion {
                        id: id.to_string(),
                        current: slot.current.version,
                        offered: version,
                    });
                }
            }
            let entry = Arc::new(ModelEntry::new(
                id.to_string(),
                version,
                resident_bytes,
                server,
                Arc::clone(&self.metrics),
            ));
            match slots.get_mut(id) {
                None => {
                    slots.insert(
                        id.to_string(),
                        Slot { current: entry, retained: Vec::new(), split: None },
                    );
                }
                Some(slot) => {
                    let old = std::mem::replace(&mut slot.current, entry);
                    if retain {
                        slot.retained.push(old);
                    } else {
                        dropped = Some(old);
                    }
                    // A split aimed at a version that just left
                    // residency is meaningless: clear it.
                    if let Some(s) = slot.split {
                        if slot.find(s.version).is_none() {
                            slot.split = None;
                        }
                    }
                }
            }
        }
        drop(dropped);
        Ok(())
    }

    /// Retire a retained (non-current) version. The entry drains on
    /// this thread once the last in-flight handle to it is gone.
    pub fn retire(&self, id: &str, version: u64) -> Result<(), RegistryError> {
        let removed;
        {
            let mut slots = self.write();
            let slot = slots
                .get_mut(id)
                .ok_or_else(|| RegistryError::UnknownModel(id.to_string()))?;
            if slot.current.version == version {
                return Err(RegistryError::RetireCurrent { id: id.to_string(), version });
            }
            let idx = slot
                .retained
                .iter()
                .position(|e| e.version == version)
                .ok_or(RegistryError::UnknownVersion { id: id.to_string(), version })?;
            removed = slot.retained.remove(idx);
            if slot.split.map(|s| s.version) == Some(version) {
                slot.split = None;
            }
        }
        drop(removed);
        Ok(())
    }

    /// Remove a model id entirely (current + retained versions). Every
    /// entry drains on this thread. Returns true if the id existed.
    pub fn remove(&self, id: &str) -> bool {
        let slot = self.write().remove(id);
        slot.is_some()
    }

    /// Divert `percent`% of un-pinned traffic for `id` to a resident
    /// `version` (typically an older retained one, serving as control
    /// while the new current version is canaried — or vice versa).
    pub fn set_split(&self, id: &str, version: u64, percent: u32) -> Result<(), RegistryError> {
        if percent > 100 {
            return Err(RegistryError::BadSplit { percent });
        }
        let mut slots = self.write();
        let slot =
            slots.get_mut(id).ok_or_else(|| RegistryError::UnknownModel(id.to_string()))?;
        if slot.find(version).is_none() {
            return Err(RegistryError::UnknownVersion { id: id.to_string(), version });
        }
        slot.split = Some(Split { version, percent });
        Ok(())
    }

    /// Drop `id`'s A/B split; all un-pinned traffic returns to the
    /// current version.
    pub fn clear_split(&self, id: &str) -> Result<(), RegistryError> {
        let mut slots = self.write();
        let slot =
            slots.get_mut(id).ok_or_else(|| RegistryError::UnknownModel(id.to_string()))?;
        slot.split = None;
        Ok(())
    }

    /// Resolve a model handle. `version: None` follows the slot's
    /// routing rule (A/B split if one is set, else the current
    /// version); `Some(v)` pins the lookup to a resident version.
    pub fn resolve(
        &self,
        id: &str,
        version: Option<u64>,
    ) -> Result<Arc<ModelEntry>, RegistryError> {
        let slots = self.read();
        let slot = slots.get(id).ok_or_else(|| RegistryError::UnknownModel(id.to_string()))?;
        match version {
            Some(v) => slot
                .find(v)
                .cloned()
                .ok_or(RegistryError::UnknownVersion { id: id.to_string(), version: v }),
            None => {
                if let Some(s) = slot.split {
                    let t = self.ticket.fetch_add(1, Ordering::Relaxed);
                    if t % 100 < u64::from(s.percent) {
                        if let Some(e) = slot.find(s.version) {
                            return Ok(Arc::clone(e));
                        }
                    }
                }
                Ok(Arc::clone(&slot.current))
            }
        }
    }

    /// Blocking inference against `(id, version)` — `None` follows the
    /// routing rule. One typed error space for lookup-then-serve.
    pub fn infer(
        &self,
        id: &str,
        version: Option<u64>,
        features: Vec<f32>,
    ) -> Result<Response, RegistryError> {
        let entry = self.resolve(id, version)?;
        Ok(entry.server().infer(features)?)
    }

    /// Published model ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self.read().keys().cloned().collect();
        v.sort();
        v
    }

    /// Point-in-time fleet listing, sorted by id.
    pub fn models(&self) -> Vec<ModelInfo> {
        let slots = self.read();
        let mut v: Vec<ModelInfo> = slots
            .iter()
            .map(|(id, slot)| ModelInfo {
                id: id.clone(),
                version: slot.current.version,
                n_features: slot.current.server.n_features(),
                resident_bytes: slot.current.resident_bytes,
                retained: slot.retained.iter().map(|e| e.version).collect(),
                split: slot.split.map(|s| (s.version, s.percent)),
            })
            .collect();
        v.sort_by(|a, b| a.id.cmp(&b.id));
        v
    }

    /// Total resident bytes across every version the registry still
    /// tracks (current + retained; excludes entries already handed off
    /// and draining).
    pub fn tracked_bytes(&self) -> u64 {
        let slots = self.read();
        slots
            .values()
            .map(|s| {
                s.current.resident_bytes
                    + s.retained.iter().map(|e| e.resident_bytes).sum::<u64>()
            })
            .sum()
    }
}

/// Outcome of one [`FleetLoader::reload`] scan.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReloadReport {
    /// `(id, version)` pairs published by this scan.
    pub loaded: Vec<(String, u64)>,
    /// Files whose fingerprint (mtime, length) was unchanged — skipped
    /// without re-reading the artifact.
    pub unchanged: usize,
    /// Files that failed to load: `(file name, error)`. A bad artifact
    /// never unpublishes the version already serving under its id.
    pub failed: Vec<(String, String)>,
}

/// Filesystem-backed fleet loader: scans one directory of model
/// artifacts — `*.bin` INTB binaries ([`crate::runtime::binfmt`]) and
/// `*.json` IR models — and publishes each file under its stem as the
/// model id. [`Self::reload`] rescans: files whose `(mtime, length)`
/// fingerprint changed are re-published with a bumped version (the
/// previous version drains per the swap-drain protocol), unchanged
/// files are skipped without touching the registry.
pub struct FleetLoader {
    dir: std::path::PathBuf,
    registry: Arc<ModelRegistry>,
    config: super::ServerConfig,
    /// id → (fingerprint, published version).
    seen: std::sync::Mutex<HashMap<String, ((std::time::SystemTime, u64), u64)>>,
}

impl FleetLoader {
    /// Loader over `dir`, publishing into `registry`; every published
    /// server is started with `config`.
    pub fn new(
        dir: impl Into<std::path::PathBuf>,
        registry: Arc<ModelRegistry>,
        config: super::ServerConfig,
    ) -> FleetLoader {
        FleetLoader {
            dir: dir.into(),
            registry,
            config,
            seen: std::sync::Mutex::new(HashMap::new()),
        }
    }

    /// The registry this loader publishes into.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Scan the directory and (re)publish every new or changed
    /// artifact. IO failure on the directory itself is the only hard
    /// error; per-file failures are collected in the report.
    pub fn reload(&self) -> std::io::Result<ReloadReport> {
        let mut report = ReloadReport::default();
        let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.is_file()
                    && matches!(
                        p.extension().and_then(|x| x.to_str()),
                        Some("bin") | Some("json")
                    )
            })
            .collect();
        files.sort();
        let mut seen = super::lock_unpoisoned(&self.seen);
        for path in files {
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
            else {
                continue;
            };
            let fname = path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or(&stem)
                .to_string();
            let fp = match std::fs::metadata(&path) {
                Ok(md) => (
                    md.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH),
                    md.len(),
                ),
                Err(e) => {
                    report.failed.push((fname, e.to_string()));
                    continue;
                }
            };
            if seen.get(&stem).map(|&(old_fp, _)| old_fp) == Some(fp) {
                report.unchanged += 1;
                continue;
            }
            match self.load_one(&path) {
                Ok((server, resident_bytes)) => {
                    let version = seen.get(&stem).map_or(1, |&(_, v)| v + 1);
                    match self.registry.publish(&stem, version, resident_bytes, server) {
                        Ok(()) => {
                            seen.insert(stem.clone(), (fp, version));
                            report.loaded.push((stem, version));
                        }
                        Err(e) => report.failed.push((fname, e.to_string())),
                    }
                }
                Err(e) => report.failed.push((fname, e)),
            }
        }
        Ok(report)
    }

    /// Load one artifact into a running server plus its resident-bytes
    /// figure. Binary artifacts go through the zero-copy loader over an
    /// `mmap(2)`-backed page-aligned view where the platform provides
    /// one ([`FileBin`](crate::runtime::FileBin)) — validation walks
    /// the mapped pages directly, so no heap copy of the artifact file
    /// is ever made; JSON goes through the IR.
    fn load_one(&self, path: &std::path::Path) -> Result<(InferenceServer, u64), String> {
        let file = crate::runtime::FileBin::open(path).map_err(|e| e.to_string())?;
        if crate::runtime::binfmt::is_binary(file.bytes()) {
            let view = file.view().map_err(|e| e.to_string())?;
            let forest = view.to_forest().map_err(|e| {
                format!("{e} (the coordinator's u32 engine serves RF artifacts only)")
            })?;
            let resident = view.resident_bytes() as u64;
            let engine = crate::inference::IntEngine::from_forest(forest);
            Ok((InferenceServer::start_with_engine(engine, self.config.clone()), resident))
        } else {
            let text = std::str::from_utf8(file.bytes()).map_err(|e| e.to_string())?;
            let model = crate::ir::Model::from_json(text).map_err(|e| e.to_string())?;
            if model.kind != crate::ir::ModelKind::RandomForest {
                return Err(
                    "GBT model: the coordinator's u32 engine serves RF models only".to_string()
                );
            }
            let resident = crate::runtime::binfmt::write_model(&model).len() as u64;
            Ok((InferenceServer::start(&model, None, self.config.clone()), resident))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{FaultPlan, ServerConfig};
    use crate::data::shuttle_like;
    use crate::ir::Model;
    use crate::trees::{ForestParams, RandomForest};

    fn model(seed: u64) -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(600, seed);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
            seed,
        );
        (ds, m)
    }

    fn quiet() -> ServerConfig {
        ServerConfig { faults: Some(FaultPlan::none()), ..Default::default() }
    }

    fn server_for(m: &Model) -> InferenceServer {
        InferenceServer::start(m, None, quiet())
    }

    #[test]
    fn publish_resolve_and_gauge_accounting() {
        let metrics = Arc::new(Metrics::new());
        let reg = ModelRegistry::new(Arc::clone(&metrics));
        let (ds, m1) = model(210);
        reg.publish("shuttle", 1, 4096, server_for(&m1)).unwrap();
        assert_eq!(reg.ids(), vec!["shuttle".to_string()]);
        let s = metrics.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (4096, 1));

        let e = reg.resolve("shuttle", None).unwrap();
        assert_eq!((e.id(), e.version(), e.resident_bytes()), ("shuttle", 1, 4096));
        let r = reg.infer("shuttle", None, ds.row(0).to_vec()).unwrap();
        assert_eq!(r.fixed.len(), ds.n_classes);

        // Hot-swap to v2 without retaining: v1 drains on this thread,
        // the gauges settle back to one resident version.
        let (_, m2) = model(211);
        reg.publish("shuttle", 2, 8192, server_for(&m2)).unwrap();
        let s = metrics.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (8192, 1));
        assert_eq!(reg.resolve("shuttle", None).unwrap().version(), 2);
        assert_eq!(
            reg.resolve("shuttle", Some(1)).err(),
            Some(RegistryError::UnknownVersion { id: "shuttle".into(), version: 1 })
        );

        // Stale publishes are typed errors, and the offered server
        // (constructed by the caller) just drains — no registry change.
        let (_, m3) = model(212);
        assert_eq!(
            reg.publish("shuttle", 2, 1, server_for(&m3)).err(),
            Some(RegistryError::StaleVersion { id: "shuttle".into(), current: 2, offered: 2 })
        );
        assert_eq!(metrics.snapshot().model_count, 1);

        assert!(reg.remove("shuttle"));
        assert!(!reg.remove("shuttle"));
        let s = metrics.snapshot();
        assert_eq!((s.model_bytes, s.model_count), (0, 0));
        assert_eq!(
            reg.resolve("shuttle", None).err(),
            Some(RegistryError::UnknownModel("shuttle".into()))
        );
    }

    #[test]
    fn hot_swap_changes_answers_and_pinned_version_keeps_old_ones() {
        let metrics = Arc::new(Metrics::new());
        let reg = ModelRegistry::new(metrics);
        let (ds, m1) = model(220);
        let (_, m2) = model(221);
        reg.publish("m", 1, 100, server_for(&m1)).unwrap();
        reg.publish_retaining("m", 2, 100, server_for(&m2)).unwrap();

        let o1 = crate::inference::IntEngine::compile(&m1);
        let o2 = crate::inference::IntEngine::compile(&m2);
        let mut differs = false;
        for i in 0..20 {
            let new = reg.infer("m", None, ds.row(i).to_vec()).unwrap();
            let old = reg.infer("m", Some(1), ds.row(i).to_vec()).unwrap();
            assert_eq!(new.fixed, o2.predict_fixed(ds.row(i)));
            assert_eq!(old.fixed, o1.predict_fixed(ds.row(i)));
            differs = differs || new.fixed != old.fixed;
        }
        assert!(differs, "models unexpectedly identical");

        let info = &reg.models()[0];
        assert_eq!(info.version, 2);
        assert_eq!(info.retained, vec![1]);
        assert_eq!(info.n_features, ds.n_features);
        assert_eq!(reg.tracked_bytes(), 200);

        assert_eq!(
            reg.retire("m", 2).err(),
            Some(RegistryError::RetireCurrent { id: "m".into(), version: 2 })
        );
        reg.retire("m", 1).unwrap();
        assert_eq!(
            reg.retire("m", 1).err(),
            Some(RegistryError::UnknownVersion { id: "m".into(), version: 1 })
        );
        assert_eq!(reg.resolve("m", Some(1)).err(),
            Some(RegistryError::UnknownVersion { id: "m".into(), version: 1 }));
        assert_eq!(reg.resolve("m", Some(2)).unwrap().version(), 2);
    }

    #[test]
    fn percentage_split_is_exact_over_a_window() {
        let metrics = Arc::new(Metrics::new());
        let reg = ModelRegistry::new(metrics);
        let (_, m1) = model(230);
        let (_, m2) = model(231);
        reg.publish("m", 1, 10, server_for(&m1)).unwrap();
        reg.publish_retaining("m", 2, 10, server_for(&m2)).unwrap();

        assert_eq!(
            reg.set_split("m", 1, 101).err(),
            Some(RegistryError::BadSplit { percent: 101 })
        );
        assert_eq!(
            reg.set_split("m", 7, 50).err(),
            Some(RegistryError::UnknownVersion { id: "m".into(), version: 7 })
        );

        // 30% of un-pinned traffic to the retained v1: the ticket
        // dispenser makes the split exact over any 100-resolve window.
        reg.set_split("m", 1, 30).unwrap();
        assert_eq!(reg.models()[0].split, Some((1, 30)));
        let mut v1 = 0;
        for _ in 0..200 {
            if reg.resolve("m", None).unwrap().version() == 1 {
                v1 += 1;
            }
        }
        assert_eq!(v1, 60);

        // Pinned lookups ignore the split entirely.
        assert_eq!(reg.resolve("m", Some(2)).unwrap().version(), 2);

        // Retiring the split target clears the split.
        reg.retire("m", 1).unwrap();
        assert_eq!(reg.models()[0].split, None);
        for _ in 0..50 {
            assert_eq!(reg.resolve("m", None).unwrap().version(), 2);
        }

        // clear_split on a split-less slot is a no-op; unknown ids are
        // typed errors.
        reg.clear_split("m").unwrap();
        assert_eq!(
            reg.clear_split("nope").err(),
            Some(RegistryError::UnknownModel("nope".into()))
        );
    }

    #[test]
    fn serving_failures_surface_as_typed_registry_errors() {
        let metrics = Arc::new(Metrics::new());
        let reg = ModelRegistry::new(metrics);
        let (_, m) = model(240);
        reg.publish("m", 1, 1, server_for(&m)).unwrap();
        let err = reg.infer("m", None, vec![1.0]).unwrap_err();
        assert_eq!(
            err,
            RegistryError::Serve(ServeError::WrongFeatureCount { expected: m.n_features, got: 1 })
        );
        assert!(err.to_string().contains("wrong feature count"), "{err}");
        assert!(RegistryError::StaleVersion { id: "x".into(), current: 3, offered: 2 }
            .to_string()
            .contains("stale publish"));
    }

    #[test]
    fn fleet_loader_publishes_and_bumps_versions() {
        let dir = std::env::temp_dir().join(format!("intreeger_fleet_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (ds, m1) = model(260);
        let (_, m2) = model(261);
        // One JSON artifact, one binary artifact, one hostile file, one
        // file the loader must ignore outright.
        std::fs::write(dir.join("alpha.json"), m1.to_json()).unwrap();
        std::fs::write(dir.join("beta.bin"), crate::runtime::binfmt::write_model(&m2)).unwrap();
        std::fs::write(dir.join("broken.json"), "{not json").unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();

        let metrics = Arc::new(Metrics::new());
        let reg = Arc::new(ModelRegistry::new(Arc::clone(&metrics)));
        let loader = FleetLoader::new(&dir, Arc::clone(&reg), quiet());
        let r = loader.reload().unwrap();
        assert_eq!(r.loaded, vec![("alpha".to_string(), 1), ("beta".to_string(), 1)]);
        assert_eq!(r.unchanged, 0);
        assert_eq!(r.failed.len(), 1, "{:?}", r.failed);
        assert_eq!(r.failed[0].0, "broken.json");
        assert_eq!(reg.ids(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(metrics.snapshot().model_count, 2);

        // Answers match the source model, whichever format carried it.
        let o2 = crate::inference::IntEngine::compile(&m2);
        let got = reg.infer("beta", None, ds.row(0).to_vec()).unwrap();
        assert_eq!(got.fixed, o2.predict_fixed(ds.row(0)));

        // Unchanged rescan publishes nothing (the broken file keeps
        // failing — it was never fingerprinted as loaded).
        let r = loader.reload().unwrap();
        assert_eq!(r.loaded, vec![]);
        assert_eq!(r.unchanged, 2);
        assert_eq!(r.failed.len(), 1);

        // Replacing alpha.json republishes it as version 2; the
        // length-bearing fingerprint defeats coarse mtime granularity.
        let (_, m3) = model(262);
        let mut j = m3.to_json();
        j.push('\n');
        std::fs::write(dir.join("alpha.json"), j).unwrap();
        let r = loader.reload().unwrap();
        assert_eq!(r.loaded, vec![("alpha".to_string(), 2)]);
        assert_eq!(reg.resolve("alpha", None).unwrap().version(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A publisher panicking while holding the registry lock must not
    /// take the fleet down: poison-recovering accessors keep resolve /
    /// publish / retire working on the always-valid map.
    #[test]
    fn registry_survives_a_poisoned_lock() {
        let metrics = Arc::new(Metrics::new());
        let reg = Arc::new(ModelRegistry::new(metrics));
        let (ds, m) = model(250);
        reg.publish("m", 1, 1, server_for(&m)).unwrap();
        let r2 = Arc::clone(&reg);
        let _ = std::thread::spawn(move || {
            let _guard = r2.slots.write().unwrap();
            panic!("poison the fleet lock");
        })
        .join();
        assert!(reg.slots.read().is_err(), "lock must actually be poisoned");
        reg.infer("m", None, ds.row(0).to_vec()).unwrap();
        let (_, m2) = model(251);
        reg.publish_retaining("m", 2, 1, server_for(&m2)).unwrap();
        assert_eq!(reg.models()[0].retained, vec![1]);
        reg.retire("m", 1).unwrap();
        assert!(reg.remove("m"));
    }
}
