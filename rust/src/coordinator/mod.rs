//! L3 coordinator: the deployment layer of the InTreeger framework.
//!
//! The paper ships inference as a generated C file; a production
//! deployment wraps that artifact in a serving runtime. This module is
//! that runtime, shaped like a miniature model server (vllm-router
//! style, scaled to tabular models):
//!
//! * [`router`] — a model registry mapping names to served models; each
//!   model can be hot-swapped (retrain → re-register).
//! * [`registry`] — the versioned fleet registry: `(model_id, version)`
//!   keys, atomic hot-swap with drain-on-drop, pinned-version and
//!   percentage A/B routing, and per-model memory accounting.
//! * [`batcher`] — dynamic batching policy: requests accumulate until
//!   `max_batch` or `max_wait` and are flushed as one batch.
//! * [`server`] — the execution layer: a **sharded pool of worker
//!   threads** (`ServerConfig::n_workers`) drains the request queue
//!   round-robin, so scalar throughput scales with cores. Each flushed
//!   batch runs through the tiled batch kernel
//!   ([`crate::inference::batch`]) — not a per-row loop; large batches
//!   on shard 0 can offload to the XLA/PJRT engine (the AOT-compiled
//!   Pallas path). All routes produce bit-identical u32 accumulators,
//!   so routing is invisible to clients.
//! * [`metrics`] — counters, per-request latency histograms, per-batch
//!   size/service-time histograms, and the failure-model counters
//!   (shed / expired / rejected / lost, worker panics/restarts,
//!   degraded flag).
//! * [`faults`] — deterministic fault injection ([`FaultPlan`],
//!   `INTREEGER_FAULTS`) powering the chaos suite.
//! * [`slab`] — the arena-owned feature-row slab behind the pooled
//!   admission path ([`InferenceServer::submit_pooled`]): rows are
//!   parsed in place at admission and returned to a free-list on every
//!   resolution path, so steady-state serving performs **zero** heap
//!   allocations per request.
//!
//! Everything is std-threads + channels (the build environment has no
//! async runtime), which also keeps the hot path allocation-free in
//! steady state.
//!
//! The serving stack has a **typed failure model** (see [`server`]):
//! every submitted request resolves with a [`Response`] or a
//! [`ServeError`] — admission sheds instead of blocking under overload,
//! TTLs expire at batch-formation time, and panicking worker shards are
//! supervised (requests answered `WorkerLost`, bounded-backoff restart,
//! degradation to the conservative scalar engine after repeated
//! failures).

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod registry;
pub mod router;
pub mod server;
pub mod slab;

pub use batcher::{BatchPolicy, Batcher, FlushReason};
pub use faults::{FaultPlan, Faults, FAULTS_ENV};
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{FleetLoader, ModelEntry, ModelInfo, ModelRegistry, RegistryError, ReloadReport};
pub use router::{RouteError, RouteSpec, Router};
pub use server::{
    calibrate_execution, ExecutionChoice, InferenceServer, ReplySlot, Request, Response, Route,
    ServeError, ServeResult, ServerConfig, DEGRADE_AFTER,
};
pub use slab::{FeatureSlab, SlabRow};

/// Lock a mutex, recovering from poisoning: the coordinator's
/// mutex-guarded state (metrics histograms, per-shard batchers) is
/// always structurally valid — each critical section is a single
/// record/push — so a thread that panicked while holding the lock
/// leaves usable data behind. Recovering keeps one crashed thread from
/// cascading into a poisoned-lock panic in every subsequent accessor.
pub(crate) fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

use crate::ir::Model;
use crate::runtime::PipelineManifest;
use std::path::Path;

/// Boot an inference server directly from an `intreeger pipeline`
/// artifact bundle: the RF model recorded in the bundle's
/// `manifest.json` is loaded and served (the coordinator's integer
/// engines need probability leaves, so a bundle holding only a GBT
/// model is rejected). Returns the model alongside the server so the
/// caller can shape demo traffic or validate responses.
///
/// Serves on the scalar batched route: a pipeline bundle's
/// `manifest.json` is the *pipeline* format, not an XLA tier manifest,
/// so the bundle directory can never double as an XLA artifact source —
/// to serve with XLA artifacts use `InferenceServer::start` (CLI:
/// `serve --model … --artifacts DIR`) instead.
pub fn server_from_pipeline(
    dir: &Path,
    config: ServerConfig,
) -> anyhow::Result<(InferenceServer, Model)> {
    let manifest = PipelineManifest::load(dir)?;
    let model = manifest.load_model(dir, "rf").map_err(|e| {
        anyhow::anyhow!("{e} (serving needs an RF model: probability leaves feed the u32 engine)")
    })?;
    let server = InferenceServer::start(&model, None, config);
    Ok((server, model))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{self, PipelineConfig};

    #[test]
    fn serve_boots_from_pipeline_bundle() {
        let ds = crate::data::shuttle_like(500, 77);
        let out = std::env::temp_dir()
            .join(format!("intreeger_serve_bundle_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&out);
        let cfg = PipelineConfig { n_trees: 3, max_depth: 4, bench: false, ..Default::default() };
        pipeline::run(&ds, &out, &cfg).expect("pipeline");

        let (server, model) = server_from_pipeline(&out, ServerConfig::default()).expect("boot");
        let oracle = crate::inference::IntEngine::compile(&model);
        for i in 0..20 {
            let r = server.infer(ds.row(i).to_vec()).expect("serve");
            assert_eq!(r.fixed, oracle.predict_fixed(ds.row(i)), "row {i}");
        }
    }

    #[test]
    fn serve_rejects_non_bundle_dir() {
        let dir = std::env::temp_dir()
            .join(format!("intreeger_serve_nobundle_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(server_from_pipeline(&dir, ServerConfig::default()).is_err());
    }
}
