//! L3 coordinator: the deployment layer of the InTreeger framework.
//!
//! The paper ships inference as a generated C file; a production
//! deployment wraps that artifact in a serving runtime. This module is
//! that runtime, shaped like a miniature model server (vllm-router
//! style, scaled to tabular models):
//!
//! * [`router`] — a model registry mapping names to served models; each
//!   model can be hot-swapped (retrain → re-register).
//! * [`batcher`] — dynamic batching policy: requests accumulate until
//!   `max_batch` or `max_wait` and are flushed as one batch.
//! * [`server`] — the execution layer: a **sharded pool of worker
//!   threads** (`ServerConfig::n_workers`) drains the request queue
//!   round-robin, so scalar throughput scales with cores. Each flushed
//!   batch runs through the tiled batch kernel
//!   ([`crate::inference::batch`]) — not a per-row loop; large batches
//!   on shard 0 can offload to the XLA/PJRT engine (the AOT-compiled
//!   Pallas path). All routes produce bit-identical u32 accumulators,
//!   so routing is invisible to clients.
//! * [`metrics`] — counters, per-request latency histograms, and
//!   per-batch size/service-time histograms.
//!
//! Everything is std-threads + channels (the build environment has no
//! async runtime), which also keeps the hot path allocation-light.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, FlushReason};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::Router;
pub use server::{InferenceServer, Request, Response, Route, ServerConfig};
