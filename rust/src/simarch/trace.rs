//! Dynamic work tracing: walk the compiled forest on real rows and count
//! the abstract operations one inference performs. These counts are the
//! variant-independent "shape" of the computation; [`super::cores`] maps
//! them to instructions/cycles per variant and core.

use crate::data::Dataset;
use crate::inference::compiled::{CompiledForest, LEAF};
use crate::ir::Model;

/// Average dynamic operation counts for one inference.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InferenceTrace {
    /// Branch nodes visited per inference (sum of leaf depths over trees).
    pub branches: f64,
    /// Leaves reached per inference (= number of trees).
    pub leaves: f64,
    /// Class-probability accumulations per inference (= leaves × classes).
    pub class_adds: f64,
    /// Input features (transform work for integer variants; loaded on
    /// demand by the float variant).
    pub features: f64,
    /// Output classes (final averaging divide for float variants).
    pub classes: f64,
    /// Static branch-node count of the model (code-size driver).
    pub static_branches: f64,
    /// Static leaf count.
    pub static_leaves: f64,
    /// Fraction of threshold immediates whose low 12 bits are zero (fit a
    /// single RISC-V `lui`, §IV-C Listing 2).
    pub imm20_fraction_thresholds: f64,
    /// Same for quantized leaf probabilities.
    pub imm20_fraction_probs: f64,
}

/// Trace the average dynamic work of `model` over up to `max_rows` rows
/// of `ds` (row sampling is deterministic: evenly strided).
pub fn trace_average(model: &Model, ds: &Dataset, max_rows: usize) -> InferenceTrace {
    let forest = CompiledForest::compile(model);
    let n_rows = ds.n_rows().min(max_rows.max(1));
    let stride = (ds.n_rows() / n_rows).max(1);

    let mut total_branches = 0u64;
    let mut rows_used = 0u64;
    let mut i = 0usize;
    while i < ds.n_rows() && rows_used < n_rows as u64 {
        let row = ds.row(i);
        for t in 0..forest.n_trees {
            total_branches += walk_depth(&forest, t, row);
        }
        rows_used += 1;
        i += stride;
    }
    let branches = total_branches as f64 / rows_used as f64;

    // Static immediate statistics (which immediates fit a 20-bit lui).
    let mut thr_total = 0usize;
    let mut thr_lui = 0usize;
    for (i, &f) in forest.feature.iter().enumerate() {
        if f != LEAF {
            thr_total += 1;
            if forest.thresh_ord[i] & 0xFFF == 0 {
                thr_lui += 1;
            }
        }
    }
    let mut prob_total = 0usize;
    let mut prob_lui = 0usize;
    for &q in &forest.leaf_u32 {
        prob_total += 1;
        if q & 0xFFF == 0 {
            prob_lui += 1;
        }
    }

    InferenceTrace {
        branches,
        leaves: forest.n_trees as f64,
        class_adds: (forest.n_trees * forest.n_classes) as f64,
        features: forest.n_features as f64,
        classes: forest.n_classes as f64,
        static_branches: thr_total as f64,
        static_leaves: (forest.leaf_u32.len() / forest.n_classes.max(1)) as f64,
        imm20_fraction_thresholds: if thr_total == 0 { 0.0 } else { thr_lui as f64 / thr_total as f64 },
        imm20_fraction_probs: if prob_total == 0 { 0.0 } else { prob_lui as f64 / prob_total as f64 },
    }
}

fn walk_depth(f: &CompiledForest, t: usize, row: &[f32]) -> u64 {
    let base = f.tree_offsets[t] as usize;
    let mut i = base;
    let mut depth = 0u64;
    loop {
        let feat = f.feature[i];
        if feat == LEAF {
            return depth;
        }
        depth += 1;
        let go_left = row[feat as usize] <= f.thresh_f32[i];
        i = base + if go_left { f.left[i] } else { f.right[i] } as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    #[test]
    fn trace_counts_consistent() {
        let ds = shuttle_like(2000, 60);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 6, ..Default::default() },
            6,
        );
        let tr = trace_average(&m, &ds, 300);
        assert_eq!(tr.leaves, 8.0);
        assert_eq!(tr.class_adds, 56.0);
        assert_eq!(tr.features, 7.0);
        assert_eq!(tr.classes, 7.0);
        // Every tree walks at least 1 branch (depth >= 1), at most depth 6.
        assert!(tr.branches >= 8.0 && tr.branches <= 48.0, "branches {}", tr.branches);
        assert!((0.0..=1.0).contains(&tr.imm20_fraction_thresholds));
        assert!((0.0..=1.0).contains(&tr.imm20_fraction_probs));
    }

    #[test]
    fn stump_trace_exact() {
        // A single stump: exactly 1 branch per inference.
        let ds = shuttle_like(500, 61);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 1, max_depth: 1, ..Default::default() },
            7,
        );
        let tr = trace_average(&m, &ds, 100);
        assert_eq!(tr.branches, 1.0);
        assert_eq!(tr.static_branches, 1.0);
        assert_eq!(tr.static_leaves, 2.0);
    }

    #[test]
    fn deeper_models_visit_more_branches() {
        let ds = shuttle_like(3000, 62);
        let shallow = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 5, max_depth: 2, ..Default::default() },
            8,
        );
        let deep = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 5, max_depth: 8, ..Default::default() },
            8,
        );
        let ts = trace_average(&shallow, &ds, 200);
        let td = trace_average(&deep, &ds, 200);
        assert!(td.branches > ts.branches);
    }
}
