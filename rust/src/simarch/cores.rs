//! Per-core cost models for the paper's four evaluation targets
//! (Table I), mapping the abstract inference trace to dynamic instruction
//! counts and cycles.
//!
//! The models encode first-order ISA/microarchitecture facts rather than
//! curve-fits:
//!
//! * Branch traversal cost is dominated by the feature load + the
//!   (data-dependent, poorly predictable) conditional branch; on the
//!   speculating cores the *comparison* mechanism matters less — except
//!   on ARMv7, where a VFP compare needs `vcmp` + `vmrs` (a flag-file
//!   transfer that stalls the pipeline), and on the in-order U74, where
//!   `fle.s` latency is exposed before `bnez` (paper Listing 4).
//! * Leaf accumulation is where the variants diverge hard: the float
//!   variants do FPU load/add/store per class, the integer variant does
//!   ALU add with an immediate — on x86 a single `add dword [mem], imm32`
//!   (§IV-C: "x86 and RISC-V have better dedicated instructions to
//!   immediate handling"), on RISC-V `lui(+addi)` + `addw` + `sw`, on
//!   ARMv7 a literal-pool `ldr` + `add` + `str` (paper Listing 3).
//! * The integer variants pay a per-feature order-preserving transform in
//!   the prologue — negligible for Shuttle's 7 features, material for
//!   ESA's 87 (this is what compresses ESA gains to a few percent,
//!   reproducing the paper's 4.8 % worst case).
//! * The FE310 has no FPU at all: float operations become soft-float
//!   libgcc calls, tens of cycles each — the paper's motivation for
//!   integer-only inference on ultra-low-power parts.

use super::trace::InferenceTrace;
use crate::inference::Variant;
use crate::ir::Model;

/// The four cores of Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Core {
    /// AMD EPYC 7282 — x86-64, 2.8 GHz, wide out-of-order.
    Epyc7282,
    /// ARM Cortex-A72 running ARMv7 code, 1.8 GHz.
    CortexA72,
    /// SiFive U74-MC — RV64GC, 1.2 GHz, dual-issue in-order.
    U74,
    /// SiFive FE310 — RV32IMAC, 16 MHz, single-issue, no FPU, QSPI flash.
    Fe310,
}

impl Core {
    /// Every evaluated core, in Table I order.
    pub fn all() -> [Core; 4] {
        [Core::Epyc7282, Core::CortexA72, Core::U74, Core::Fe310]
    }

    /// Application-class cores used in the paper's Fig 3 (the FE310 is
    /// evaluated separately in §IV-E).
    pub fn application_cores() -> [Core; 3] {
        [Core::Epyc7282, Core::CortexA72, Core::U74]
    }

    /// Display name (core + ISA).
    pub fn name(self) -> &'static str {
        match self {
            Core::Epyc7282 => "EPYC 7282 (x86-64)",
            Core::CortexA72 => "Cortex-A72 (ARMv7)",
            Core::U74 => "U74-MC (RV64GC)",
            Core::Fe310 => "FE310 (RV32IMAC)",
        }
    }

    /// The core's cost-model parameters (Table I + microarchitectural
    /// costs; see the module docs for provenance).
    pub fn params(self) -> CoreParams {
        match self {
            Core::Epyc7282 => CoreParams {
                core: self,
                isa: "x86-64",
                word_bits: 64,
                freq_hz: 2.8e9,
                issue_width: 4,
                icache_bytes: 32 * 1024,
                dcache_note: "32K L1D / 512K L2 / 16M L3",
                miss_penalty: 12.0,
                locality_beta: 0.05,
                instrs_per_line: 8.0,
                bytes_per_instr: 5.0,
                // branch node: load + cmp(+imm embedded) + jcc, speculated.
                branch_float: 1.9,
                branch_int: 1.3,
                mispredict_rate: 0.25,
                mispredict: 17.0,
                // leaf class add.
                leaf_add_float: 2.2,
                leaf_add_int: 0.7,
                transform_feature: 0.7,
                div_float: 4.0,
                // instruction counts per event:
                i_branch_float: 3.0, // movss/comiss mem + jcc
                i_branch_int: 2.0,   // cmp dword [mem], imm32 + jcc
                i_branch_int_extra_imm: 0.0, // imm embedded in cmp
                i_leaf_float: 3.0, // movss, addss, movss
                i_leaf_int: 1.0,   // add dword [mem], imm32
                i_leaf_int_extra_imm: 0.0,
                i_transform: 4.0,
                i_div: 3.0,
            },
            Core::CortexA72 => CoreParams {
                core: self,
                isa: "ARMv7",
                word_bits: 32,
                freq_hz: 1.8e9,
                issue_width: 3,
                icache_bytes: 48 * 1024,
                dcache_note: "32K L1D / 1M shared L2",
                miss_penalty: 14.0,
                locality_beta: 0.05,
                instrs_per_line: 16.0,
                bytes_per_instr: 4.0,
                // vldr + vcmp + vmrs (flag transfer stalls) + bcc.
                branch_float: 6.5,
                branch_int: 6.2, // ldr data + ldr pool + cmp + bcc (pool load pressure)
                mispredict_rate: 0.30,
                mispredict: 15.0,
                // vldr acc + vldr const + vadd + vstr in ARMv7-compat VFP
                // mode: the A72 treats legacy VFP ops conservatively (no
                // NEON dual-issue), leaving the vadd latency chain largely
                // exposed per class accumulator.
                leaf_add_float: 13.0,
                leaf_add_int: 1.6, // ldr/ldr/add/str, fully pipelined
                transform_feature: 3.0,
                div_float: 20.0,
                i_branch_float: 5.0, // ldr, vldr, vcmp, vmrs, bcc
                i_branch_int: 4.0,   // ldr, ldr(pool), cmp, bcc
                i_branch_int_extra_imm: 0.0,
                i_leaf_float: 4.0, // vldr, vldr, vadd, vstr
                i_leaf_int: 4.0,   // ldr, ldr(pool), add, str
                i_leaf_int_extra_imm: 0.0,
                i_transform: 4.0,
                i_div: 3.0,
            },
            Core::U74 => CoreParams {
                core: self,
                isa: "RV64GC",
                word_bits: 64,
                freq_hz: 1.2e9,
                issue_width: 2,
                icache_bytes: 32 * 1024,
                dcache_note: "32K L1D / 2M banked L2",
                miss_penalty: 20.0,
                locality_beta: 0.05,
                instrs_per_line: 9.0,
                bytes_per_instr: 3.6,
                // in-order: fmv.w.x + flw + fle.s(lat 4, exposed) + bnez
                // (paper Listing 4).
                branch_float: 6.0,
                branch_int: 3.0, // lw + lui + blt (Listing 2)
                mispredict_rate: 0.30,
                mispredict: 6.0,
                // flw, flw, fadd.s (lat 5, partially overlapped dual-issue),
                // fsw.
                leaf_add_float: 5.0,
                leaf_add_int: 3.0, // lw, lui+addiw, addw, sw
                transform_feature: 2.0,
                div_float: 16.0,
                i_branch_float: 4.0, // fmv/flw/fle/bnez
                i_branch_int: 3.0,   // lw/lui/blt
                i_branch_int_extra_imm: 1.0, // +addi when imm needs low 12 bits
                i_leaf_float: 4.0, // flw/flw/fadd/fsw
                i_leaf_int: 4.0,   // lw/lui/addw/sw
                i_leaf_int_extra_imm: 1.0, // +addiw (Listing 2 line 9)
                i_transform: 4.0,
                i_div: 3.0,
            },
            Core::Fe310 => CoreParams {
                core: self,
                isa: "RV32IMAC",
                word_bits: 32,
                freq_hz: 16.0e6,
                issue_width: 1,
                icache_bytes: 16 * 1024,
                dcache_note: "16K DTIM, 32M QSPI flash",
                miss_penalty: 24.0, // worst-case QSPI fetch (§IV-E)
                locality_beta: 0.16,
                instrs_per_line: 8.0,
                bytes_per_instr: 3.2, // RV32C mix
                // No FPU: float ops are libgcc soft-float calls.
                branch_float: 45.0, // __lesf2 call + compare
                branch_int: 4.0,
                mispredict_rate: 0.30,
                mispredict: 3.0, // short pipeline
                leaf_add_float: 60.0, // __addsf3
                leaf_add_int: 5.0,
                transform_feature: 4.0,
                div_float: 90.0, // __divsf3
                i_branch_float: 30.0, // call overhead + soft-float body
                i_branch_int: 3.0,
                i_branch_int_extra_imm: 1.0,
                i_leaf_float: 40.0,
                i_leaf_int: 4.0,
                i_leaf_int_extra_imm: 1.0,
                i_transform: 4.0,
                i_div: 60.0,
            },
        }
    }
}

/// Core model parameters (one row of Table I plus microarchitectural
/// costs; see module docs for the provenance of each number).
#[derive(Clone, Debug)]
pub struct CoreParams {
    /// Which core these parameters model.
    pub core: Core,
    /// ISA name as evaluated by the paper.
    pub isa: &'static str,
    /// Native word width (bits).
    pub word_bits: u32,
    /// Clock frequency (Hz).
    pub freq_hz: f64,
    /// Maximum instructions issued per cycle.
    pub issue_width: u32,
    /// L1 instruction-cache capacity (bytes).
    pub icache_bytes: u64,
    /// Free-text data-cache description (Table I column).
    pub dcache_note: &'static str,
    /// Cycles per instruction-fetch miss.
    pub miss_penalty: f64,
    /// Temporal-locality factor of tree code (hot upper levels stay
    /// cached); scales the footprint-driven miss estimate.
    pub locality_beta: f64,
    /// Instructions per cache line (code density for the fetch model).
    pub instrs_per_line: f64,
    /// Average code bytes per instruction (footprint estimate).
    pub bytes_per_instr: f64,

    /// Cycles per float-compare branch node.
    pub branch_float: f64,
    /// Cycles per integer-compare branch node.
    pub branch_int: f64,
    /// Fraction of branch nodes that mispredict.
    pub mispredict_rate: f64,
    /// Cycles per misprediction.
    pub mispredict: f64,
    /// Cycles per float leaf-class accumulation.
    pub leaf_add_float: f64,
    /// Cycles per integer leaf-class accumulation.
    pub leaf_add_int: f64,
    /// Cycles per FlInt feature transform.
    pub transform_feature: f64,
    /// Cycles per float divide (the RF probability average).
    pub div_float: f64,

    /// Instructions per float branch node.
    pub i_branch_float: f64,
    /// Instructions per integer branch node.
    pub i_branch_int: f64,
    /// Extra immediate-materialization instructions per integer branch.
    pub i_branch_int_extra_imm: f64,
    /// Instructions per float leaf accumulation.
    pub i_leaf_float: f64,
    /// Instructions per integer leaf accumulation.
    pub i_leaf_int: f64,
    /// Extra immediate-materialization instructions per integer leaf add.
    pub i_leaf_int_extra_imm: f64,
    /// Instructions per FlInt feature transform.
    pub i_transform: f64,
    /// Instructions per float divide.
    pub i_div: f64,
}

/// Cycles split by cause (for the §IV-C / §IV-D analysis output).
#[derive(Clone, Copy, Debug, Default)]
pub struct CycleBreakdown {
    /// Cycles spent in branch-node evaluation.
    pub traversal: f64,
    /// Cycles spent accumulating leaf class values.
    pub leaf_accum: f64,
    /// Per-inference fixed overhead (call, transform, final divide).
    pub prologue_epilogue: f64,
    /// Branch-misprediction penalty cycles.
    pub mispredict: f64,
    /// Instruction-fetch penalty cycles (see [`super::cache`]).
    pub fetch: f64,
}

impl CycleBreakdown {
    /// Sum of all categories.
    pub fn total(&self) -> f64 {
        self.traversal + self.leaf_accum + self.prologue_epilogue + self.mispredict + self.fetch
    }
}

/// Map a trace to (instructions, cycle breakdown, code bytes) for a
/// variant on a core. `model` supplies static sizes for the code
/// footprint estimate.
pub fn cost(
    tr: &InferenceTrace,
    variant: Variant,
    p: &CoreParams,
    model: &Model,
) -> (f64, CycleBreakdown, u64) {
    let is_float_cmp = variant == Variant::Float;
    let is_float_acc = variant != Variant::IntTreeger;

    // ---- dynamic instruction count --------------------------------------
    let rv_extra_thr = p.i_branch_int_extra_imm * (1.0 - tr.imm20_fraction_thresholds);
    let rv_extra_prob = p.i_leaf_int_extra_imm * (1.0 - tr.imm20_fraction_probs);

    let i_branch = if is_float_cmp { p.i_branch_float } else { p.i_branch_int + rv_extra_thr };
    let i_leaf = if is_float_acc { p.i_leaf_float } else { p.i_leaf_int + rv_extra_prob };
    let i_prologue = if is_float_cmp { 0.0 } else { tr.features * p.i_transform };
    let i_epilogue = if is_float_acc { tr.classes * p.i_div } else { 0.0 };
    // result zeroing + call/return framing per tree
    let i_misc = tr.classes + 2.0 * tr.leaves;

    let instructions =
        tr.branches * i_branch + tr.class_adds * i_leaf + i_prologue + i_epilogue + i_misc;

    // ---- cycles ----------------------------------------------------------
    let c_branch = if is_float_cmp { p.branch_float } else { p.branch_int };
    let c_leaf = if is_float_acc { p.leaf_add_float } else { p.leaf_add_int };

    let traversal = tr.branches * c_branch;
    let leaf_accum = tr.class_adds * c_leaf;
    let mut prologue_epilogue = tr.classes * 0.5 + tr.leaves * 1.0; // zeroing + frames
    if !is_float_cmp {
        prologue_epilogue += tr.features * p.transform_feature;
    }
    if is_float_acc {
        prologue_epilogue += tr.classes * p.div_float;
    }
    let mispredict = tr.branches * p.mispredict_rate * p.mispredict;

    let breakdown = CycleBreakdown { traversal, leaf_accum, prologue_epilogue, mispredict, fetch: 0.0 };

    // ---- static code footprint (if-else layout) --------------------------
    let leaves = tr.static_leaves;
    let code_instrs = tr.static_branches * i_branch + leaves * tr.classes * i_leaf
        + i_prologue
        + i_epilogue
        + 8.0 * tr.leaves; // function prologues etc.
    let code_bytes = (code_instrs * p.bytes_per_instr) as u64 + 256;

    let _ = model;
    (instructions, breakdown, code_bytes)
}

/// Render Table I (the experiment-setup table) as text.
pub fn table_i() -> String {
    let mut out = String::new();
    out.push_str(
        "| Core              | ISA      | Word | Frequency | Memory hierarchy            |\n",
    );
    out.push_str(
        "|-------------------|----------|------|-----------|------------------------------|\n",
    );
    for core in Core::all() {
        let p = core.params();
        let freq = if p.freq_hz >= 1e9 {
            format!("{:.1} GHz", p.freq_hz / 1e9)
        } else {
            format!("{:.0} MHz", p.freq_hz / 1e6)
        };
        out.push_str(&format!(
            "| {:<17} | {:<8} | {:>4} | {:>9} | {:<28} |\n",
            core.name().split(" (").next().unwrap(),
            p.isa,
            p.word_bits,
            freq,
            format!("{}K I$ / {}", p.icache_bytes / 1024, p.dcache_note),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_trace() -> InferenceTrace {
        InferenceTrace {
            branches: 100.0,
            leaves: 20.0,
            class_adds: 140.0,
            features: 7.0,
            classes: 7.0,
            static_branches: 500.0,
            static_leaves: 520.0,
            imm20_fraction_thresholds: 0.1,
            imm20_fraction_probs: 0.0,
        }
    }

    fn toy_model() -> Model {
        let ds = crate::data::shuttle_like(300, 70);
        crate::trees::RandomForest::train(
            &ds,
            &crate::trees::ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
            1,
        )
    }

    #[test]
    fn float_costs_exceed_int_everywhere() {
        let tr = toy_trace();
        let m = toy_model();
        for core in Core::all() {
            let p = core.params();
            let (fi, fb, _) = cost(&tr, Variant::Float, &p, &m);
            let (ii, ib, _) = cost(&tr, Variant::IntTreeger, &p, &m);
            assert!(fb.total() > ib.total(), "{core:?} cycles");
            // instruction counts: int never more than float on x86/ARM;
            // RISC-V may add imm-materialization instructions, so allow a
            // small margin there.
            assert!(ii <= fi * 1.15, "{core:?} instrs {ii} vs {fi}");
        }
    }

    #[test]
    fn fe310_float_catastrophic() {
        // No FPU: the float variant must be many times slower.
        let tr = toy_trace();
        let m = toy_model();
        let p = Core::Fe310.params();
        let (_, fb, _) = cost(&tr, Variant::Float, &p, &m);
        let (_, ib, _) = cost(&tr, Variant::IntTreeger, &p, &m);
        assert!(fb.total() / ib.total() > 5.0);
    }

    #[test]
    fn flint_between_float_and_int() {
        let tr = toy_trace();
        let m = toy_model();
        for core in Core::application_cores() {
            let p = core.params();
            let (_, f, _) = cost(&tr, Variant::Float, &p, &m);
            let (_, fl, _) = cost(&tr, Variant::FlInt, &p, &m);
            let (_, it, _) = cost(&tr, Variant::IntTreeger, &p, &m);
            assert!(f.total() >= fl.total() && fl.total() >= it.total(), "{core:?}");
        }
    }

    #[test]
    fn imm20_fraction_reduces_rv_instructions() {
        let mut tr = toy_trace();
        let m = toy_model();
        let p = Core::U74.params();
        tr.imm20_fraction_thresholds = 0.0;
        let (hi, _, _) = cost(&tr, Variant::IntTreeger, &p, &m);
        tr.imm20_fraction_thresholds = 1.0;
        let (lo, _, _) = cost(&tr, Variant::IntTreeger, &p, &m);
        assert!(lo < hi);
    }

    #[test]
    fn table_i_renders_all_cores() {
        let t = table_i();
        for name in ["EPYC 7282", "Cortex-A72", "U74-MC", "FE310"] {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
        assert!(t.contains("RV64GC") && t.contains("RV32IMAC"));
    }

    #[test]
    fn code_bytes_scale_with_model_size() {
        let mut tr = toy_trace();
        let m = toy_model();
        let p = Core::U74.params();
        let (_, _, small) = cost(&tr, Variant::IntTreeger, &p, &m);
        tr.static_branches *= 10.0;
        tr.static_leaves *= 10.0;
        let (_, _, big) = cost(&tr, Variant::IntTreeger, &p, &m);
        assert!(big > small * 5);
    }
}
