//! Architecture simulation — the hardware-testbed substitute.
//!
//! The paper measures elapsed cycles with `perf` on four cores (Table I):
//! AMD EPYC-7282 (x86-64), ARM Cortex-A72 in ARMv7 mode, SiFive U74
//! (RV64GC) and SiFive FE310 (RV32IMAC @ 16 MHz). None of that hardware
//! is available here, so this module reproduces the experiment as a
//! **trace-driven cost model**:
//!
//! 1. [`trace`] walks the compiled forest on real test rows and counts the
//!    dynamic work of one inference: branch nodes visited, leaf-class
//!    accumulations, feature transforms — split by the numeric variant.
//! 2. [`cores`] maps those abstract operations to instruction counts and
//!    cycles using per-core parameters (issue behaviour, FPU latencies,
//!    immediate-materialization rules per ISA — the §IV-C discussion).
//! 3. [`cache`] adds an instruction-fetch penalty from the code-footprint
//!    vs I-cache-size relationship (dominant on the FE310's QSPI flash,
//!    §IV-E).
//!
//! The model is calibrated to first-order ISA facts, not fitted to the
//! paper's curves; EXPERIMENTS.md compares its output against Fig 3's
//! reported shape (who wins, by what factor, how gains scale with class
//! count). The x86 column is additionally *measured* for real (gcc -O3 on
//! this host; `codegen::compile`), giving one anchored point.

pub mod cache;
pub mod cores;
pub mod fe310;
pub mod trace;

pub use cores::{Core, CoreParams, CycleBreakdown};
pub use trace::{trace_average, InferenceTrace};

use crate::data::Dataset;
use crate::inference::Variant;
use crate::ir::Model;

/// Result of simulating one (model, variant, core) combination.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Core simulated.
    pub core: Core,
    /// Numeric variant simulated.
    pub variant: Variant,
    /// Average dynamic instructions per inference.
    pub instructions: f64,
    /// Average cycles per inference (incl. fetch penalties).
    pub cycles: f64,
    /// Cycles by category, for the §IV-C analysis.
    pub breakdown: CycleBreakdown,
    /// Estimated code footprint of the generated if-else C (bytes).
    pub code_bytes: u64,
}

impl SimResult {
    /// Instructions per cycle.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.instructions / self.cycles
        }
    }

    /// Wall-clock seconds per inference at the core's frequency.
    pub fn seconds(&self) -> f64 {
        self.cycles / self.core.params().freq_hz
    }
}

/// Simulate average per-inference cost of `model` compiled as `variant`,
/// on `core`, over (a sample of) the rows of `ds`.
pub fn simulate(model: &Model, ds: &Dataset, variant: Variant, core: Core, max_rows: usize) -> SimResult {
    let tr = trace_average(model, ds, max_rows);
    let params = core.params();
    let (instructions, breakdown, code_bytes) = cores::cost(&tr, variant, &params, model);
    let fetch = cache::fetch_penalty_cycles(instructions, code_bytes, &params);
    SimResult {
        core,
        variant,
        instructions,
        cycles: breakdown.total() + fetch,
        breakdown: CycleBreakdown { fetch, ..breakdown },
        code_bytes,
    }
}

/// Speedup of variant `b` over variant `a` (cycles ratio a/b).
pub fn speedup(a: &SimResult, b: &SimResult) -> f64 {
    a.cycles / b.cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{esa_like, shuttle_like};
    use crate::trees::{ForestParams, RandomForest};

    fn sim_all(ds: &Dataset, n_trees: usize, core: Core) -> [SimResult; 3] {
        let m = RandomForest::train(
            ds,
            &ForestParams { n_trees, max_depth: 7, ..Default::default() },
            5,
        );
        [
            simulate(&m, ds, Variant::Float, core, 200),
            simulate(&m, ds, Variant::FlInt, core, 200),
            simulate(&m, ds, Variant::IntTreeger, core, 200),
        ]
    }

    /// The paper's headline ordering: float slowest, InTreeger fastest,
    /// FlInt in between — on every core.
    #[test]
    fn variant_ordering_holds_on_all_cores() {
        let ds = shuttle_like(3000, 50);
        for core in Core::all() {
            let [f, fl, it] = sim_all(&ds, 20, core);
            assert!(f.cycles > fl.cycles, "{core:?}: float {} !> flint {}", f.cycles, fl.cycles);
            assert!(fl.cycles >= it.cycles, "{core:?}: flint {} !>= int {}", fl.cycles, it.cycles);
        }
    }

    /// Gains scale with class count: Shuttle (7 classes) gains more than
    /// ESA (2 classes) — §IV-D's main observation.
    #[test]
    fn class_count_drives_gains() {
        let shuttle = shuttle_like(3000, 51);
        let esa = esa_like(2000, 51);
        for core in [Core::CortexA72, Core::U74] {
            let [sf, _, si] = sim_all(&shuttle, 20, core);
            let [ef, _, ei] = sim_all(&esa, 20, core);
            let s_gain = speedup(&sf, &si);
            let e_gain = speedup(&ef, &ei);
            assert!(
                s_gain > e_gain,
                "{core:?}: shuttle {s_gain:.3} should beat esa {e_gain:.3}"
            );
            assert!(e_gain > 1.0, "{core:?}: esa gain {e_gain:.3} must still be > 1");
        }
    }

    /// Paper's best case: Shuttle/ARMv7/50 trees ≈ 2.1x. Accept a band.
    #[test]
    fn armv7_shuttle_headline_band() {
        let ds = shuttle_like(4000, 52);
        let [f, _, it] = sim_all(&ds, 50, Core::CortexA72);
        let s = speedup(&f, &it);
        assert!(s > 1.5 && s < 2.8, "headline speedup {s:.3} outside band");
    }

    /// IPC must be physically plausible (< issue width, > 0.1).
    #[test]
    fn ipc_plausible() {
        let ds = shuttle_like(2000, 53);
        for core in Core::all() {
            let [f, _, it] = sim_all(&ds, 10, core);
            for r in [&f, &it] {
                assert!(r.ipc() > 0.1 && r.ipc() <= core.params().issue_width as f64 + 0.01,
                    "{core:?} {:?} ipc {}", r.variant, r.ipc());
            }
        }
    }

    #[test]
    fn seconds_scale_with_frequency() {
        let ds = shuttle_like(1000, 54);
        let m = RandomForest::train(&ds, &ForestParams { n_trees: 5, max_depth: 5, ..Default::default() }, 5);
        let fast = simulate(&m, &ds, Variant::IntTreeger, Core::Epyc7282, 100);
        let slow = simulate(&m, &ds, Variant::IntTreeger, Core::Fe310, 100);
        assert!(slow.seconds() > fast.seconds() * 50.0);
    }
}
