//! Instruction-fetch model: estimates the extra cycles spent fetching
//! code that does not fit the instruction cache.
//!
//! If-else tree code has a large static footprint (every node is distinct
//! instructions) but strong *temporal locality at the top levels* — the
//! root of every tree is executed every inference, leaves only 1/2^d of
//! the time. The model captures this with a single locality factor
//! (`locality_beta`): the per-instruction miss probability is
//!
//! ```text
//! miss/instr = beta * max(0, 1 - icache/code) / instrs_per_line
//! ```
//!
//! calibrated on the paper's one hard data point: the FE310 use case
//! (§IV-E) reports IPC = 0.746 for a 42 KB integer-only model running
//! from QSPI flash behind a 16 KB I-cache with up to 24-cycle fills.

use super::cores::CoreParams;

/// Extra fetch cycles for `instructions` dynamic instructions of a binary
/// whose code footprint is `code_bytes`.
pub fn fetch_penalty_cycles(instructions: f64, code_bytes: u64, p: &CoreParams) -> f64 {
    let miss = miss_rate_per_instr(code_bytes, p);
    instructions * miss * p.miss_penalty
}

/// Estimated I-fetch misses per instruction.
pub fn miss_rate_per_instr(code_bytes: u64, p: &CoreParams) -> f64 {
    if code_bytes <= p.icache_bytes {
        return 0.0;
    }
    let overflow = 1.0 - p.icache_bytes as f64 / code_bytes as f64;
    p.locality_beta * overflow / p.instrs_per_line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simarch::Core;

    #[test]
    fn fits_in_cache_is_free() {
        let p = Core::U74.params();
        assert_eq!(fetch_penalty_cycles(1e6, p.icache_bytes, &p), 0.0);
        assert_eq!(fetch_penalty_cycles(1e6, 100, &p), 0.0);
    }

    #[test]
    fn penalty_grows_with_footprint() {
        let p = Core::Fe310.params();
        let a = fetch_penalty_cycles(1e4, 20 * 1024, &p);
        let b = fetch_penalty_cycles(1e4, 60 * 1024, &p);
        let c = fetch_penalty_cycles(1e4, 600 * 1024, &p);
        assert!(a < b && b < c);
        assert!(a > 0.0);
    }

    #[test]
    fn fe310_calibration_matches_paper_ipc_band() {
        // §IV-E: 42,382-byte text, IPC 0.746 with base CPI ~1.05 on the
        // single-issue FE310 ⇒ fetch adds ~0.29 cycles/instr.
        let p = Core::Fe310.params();
        let per_instr = miss_rate_per_instr(42_382, &p) * p.miss_penalty;
        assert!(per_instr > 0.1 && per_instr < 0.6, "fetch/instr = {per_instr}");
    }

    #[test]
    fn miss_rate_bounded() {
        for core in Core::all() {
            let p = core.params();
            let m = miss_rate_per_instr(u64::MAX / 2, &p);
            assert!(m <= 1.0 / p.instrs_per_line + 1e-9);
        }
    }
}
