//! FE310 microcontroller use case (§IV-E): memory-footprint estimation
//! and bare-metal performance phenomenology for the SparkFun RED-V
//! (SiFive FE310 @ 16 MHz, RV32IMAC, no FPU, XIP from QSPI flash).
//!
//! The paper deploys a Shuttle RF (30 trees, depth ≤ 5) and reports:
//! text = 42 382 B, data = 8 B, bss = 1 152 B, 7 243 185 instructions per
//! inference *loop iteration batch*, IPC = 0.746, 1.66 inferences/s.
//! (The instruction number corresponds to their firmware loop; per single
//! inference the interesting quantities are the footprint and IPC, which
//! we reproduce.)

use super::cache;
use super::cores::Core;
use super::trace::trace_average;
use crate::data::Dataset;
use crate::inference::Variant;
use crate::ir::Model;

/// Memory footprint estimate of the generated integer-only if-else C on
/// RV32IMAC.
#[derive(Clone, Copy, Debug)]
pub struct Footprint {
    /// Code section size (bytes).
    pub text_bytes: u64,
    /// Initialized-data section size (bytes).
    pub data_bytes: u64,
    /// Zero-initialized reservation (bytes).
    pub bss_bytes: u64,
}

impl Footprint {
    /// Total firmware footprint (text + data + bss).
    pub fn total(&self) -> u64 {
        self.text_bytes + self.data_bytes + self.bss_bytes
    }
}

/// Estimate the linked firmware footprint for a model (integer-only
/// if-else variant + minimal bare-metal runtime).
pub fn footprint(model: &Model) -> Footprint {
    let stats = crate::ir::stats::stats(model);
    let p = Core::Fe310.params();
    // Integer branch: lw + lui(+addi ~50%) + blt ≈ 3.5 instrs.
    // Integer leaf: per *nonzero* class value: lw + lui(+addi ~85%) +
    // addw + sw ≈ 4.85 instrs. Zero-valued adds (`result[c] += 0u`) are
    // removed by gcc -O3, and most leaves of a largely-separable dataset
    // like Shuttle are pure — this elision is what makes the paper's
    // 42 KB text section possible for 30 trees x 7 classes.
    let branch_instrs = 3.5;
    let nonzero_leaf_values: usize = model
        .trees
        .iter()
        .flat_map(|t| t.nodes.iter())
        .map(|n| match n {
            crate::ir::Node::Leaf { values } => values.iter().filter(|&&v| v != 0.0).count(),
            _ => 0,
        })
        .sum();
    let model_instrs =
        stats.n_branches as f64 * branch_instrs + nonzero_leaf_values as f64 * 4.85;
    // Bare-metal runtime (crt0, trap handlers, counters instrumentation).
    let runtime_bytes = 2_600u64;
    Footprint {
        text_bytes: (model_instrs * p.bytes_per_instr) as u64 + runtime_bytes,
        data_bytes: 8,
        bss_bytes: 1_152, // stack/bss reservation as in the paper's firmware
    }
}

/// Bare-metal use-case simulation output.
#[derive(Clone, Copy, Debug)]
pub struct UseCaseResult {
    /// Estimated firmware memory footprint.
    pub footprint: Footprint,
    /// Average dynamic instructions per inference.
    pub instructions_per_inference: f64,
    /// Average cycles per inference (incl. QSPI fetch penalty).
    pub cycles_per_inference: f64,
    /// Instructions per cycle.
    pub ipc: f64,
    /// Throughput at 16 MHz.
    pub inferences_per_second: f64,
    /// Latency per inference (s).
    pub seconds_per_inference: f64,
}

/// Run the §IV-E experiment: the given model deployed integer-only on the
/// FE310, averaged over rows of `ds`.
pub fn use_case(model: &Model, ds: &Dataset, max_rows: usize) -> UseCaseResult {
    let fp = footprint(model);
    let tr = trace_average(model, ds, max_rows);
    let p = Core::Fe310.params();
    let (instrs, breakdown, _) = super::cores::cost(&tr, Variant::IntTreeger, &p, model);
    // Fetch penalty uses the *linked* footprint (what XIP actually fetches).
    let fetch = cache::fetch_penalty_cycles(instrs, fp.text_bytes, &p);
    let cycles = breakdown.total() + fetch;
    let secs = cycles / p.freq_hz;
    UseCaseResult {
        footprint: fp,
        instructions_per_inference: instrs,
        cycles_per_inference: cycles,
        ipc: instrs / cycles,
        inferences_per_second: 1.0 / secs,
        seconds_per_inference: secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn paper_model(ds: &Dataset) -> Model {
        RandomForest::train(
            ds,
            &ForestParams { n_trees: 30, max_depth: 5, ..Default::default() },
            11,
        )
    }

    #[test]
    fn footprint_in_paper_band() {
        // Paper: 42,382 B text for Shuttle / 30 trees / depth 5. Synthetic
        // trees differ in exact node counts; accept the right order.
        let ds = shuttle_like(20_000, 71);
        let m = paper_model(&ds);
        let fp = footprint(&m);
        assert!(
            fp.text_bytes > 15_000 && fp.text_bytes < 90_000,
            "text = {} B",
            fp.text_bytes
        );
        assert_eq!(fp.data_bytes, 8);
        assert_eq!(fp.bss_bytes, 1_152);
    }

    #[test]
    fn ipc_matches_paper_band() {
        // Paper: IPC = 0.746 (QSPI fetch dominated).
        let ds = shuttle_like(20_000, 72);
        let m = paper_model(&ds);
        let r = use_case(&m, &ds, 300);
        assert!(r.ipc > 0.5 && r.ipc < 0.95, "ipc = {}", r.ipc);
    }

    #[test]
    fn throughput_plausible_at_16mhz() {
        let ds = shuttle_like(20_000, 73);
        let m = paper_model(&ds);
        let r = use_case(&m, &ds, 300);
        // The paper reports 1.66 inf/s for their (much larger) firmware
        // loop; a bare predict() call is far cheaper. Sanity: between
        // 100 inf/s and 50k inf/s at 16 MHz.
        assert!(
            r.inferences_per_second > 100.0 && r.inferences_per_second < 50_000.0,
            "inf/s = {}",
            r.inferences_per_second
        );
        assert!((r.seconds_per_inference * r.inferences_per_second - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_models_bigger_footprint() {
        let ds = shuttle_like(8_000, 74);
        let small = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 5, max_depth: 4, ..Default::default() },
            1,
        );
        let big = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 40, max_depth: 7, ..Default::default() },
            1,
        );
        assert!(footprint(&big).text_bytes > footprint(&small).text_bytes * 3);
    }
}
