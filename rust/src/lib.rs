//! # InTreeger — end-to-end integer-only decision tree inference
//!
//! Reproduction of *InTreeger: An End-to-End Framework for Integer-Only
//! Decision Tree Inference* (Bart et al., 2025).
//!
//! The crate implements the full pipeline the paper describes:
//!
//! 1. **Training substrate** ([`trees`]) — CART decision trees, Random
//!    Forests and gradient-boosted trees trained from scratch on a
//!    [`data::Dataset`] (the paper uses scikit-learn; we build the
//!    equivalent so the framework is self-contained).
//! 2. **Model IR** ([`ir`]) — a Treelite-like intermediate representation
//!    every trainer lowers into and every backend consumes.
//! 3. **Integer transforms** — [`flint`] (order-preserving reinterpretation
//!    of IEEE-754 floats so threshold comparisons run on the integer ALU)
//!    and [`quant`] (leaf-probability → `u32` fixed point with scaling
//!    factor `2^32 / n_trees`, the paper's §III-A contribution).
//! 4. **Inference engines** ([`inference`]) — executable float / FlInt /
//!    integer-only engines with semantics identical to the generated C,
//!    plus the batch-first tiled traversal kernel ([`inference::batch`])
//!    that serves whole batches bit-identically to the per-row path.
//! 5. **Code generation** ([`codegen`]) — architecture-agnostic C output
//!    (if-else and native-tree layouts, three numeric variants) plus a
//!    gcc compile-and-run harness.
//! 6. **Architecture simulation** ([`simarch`]) — trace-driven cost models
//!    for the paper's four cores (EPYC-7282/x86, Cortex-A72/ARMv7,
//!    U74/RV64, FE310/RV32) standing in for the hardware testbed.
//! 7. **Energy model** ([`energy`]) — the paper's §IV-F Joulescope
//!    methodology (power-trace synthesis + the `E_saved` formula).
//! 8. **Deployment runtime** ([`runtime`], [`coordinator`]) — a PJRT/XLA
//!    batched inference engine (AOT-lowered JAX+Pallas forest traversal)
//!    behind a dynamic-batching request router drained by a sharded
//!    worker pool, fronted by a zero-copy HTTP/1.1 serving layer
//!    ([`net`]) with deadline-aware adaptive batch formation.
//! 9. **End-to-end pipeline** ([`pipeline`]) — one call (or one
//!    `intreeger pipeline` command) from a CSV to trained, quantized,
//!    **verified** integer-only C plus a machine-readable report; the
//!    "no loss of precision" claim is checked on a stratified holdout
//!    on every run.
//!
//! See `README.md` (repo root) for the quickstart and CLI reference,
//! `DESIGN.md` for the module map, the batch execution core and its
//! batched-vs-scalar parity invariant, and `EXPERIMENTS.md` for the
//! experiment index with paper-vs-measured notes.

// The docs gate: every public item documents itself, and CI runs
// rustdoc with `-D warnings` so a missing doc or a broken intra-doc
// link fails the build rather than rotting silently.
#![warn(missing_docs)]

pub mod codegen;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod flint;
pub mod inference;
pub mod ir;
pub mod net;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod simarch;
pub mod trees;
pub mod util;
