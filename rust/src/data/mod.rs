//! Dataset substrate: in-memory tabular datasets, train/test splitting,
//! CSV I/O and deterministic synthetic generators shaped like the paper's
//! two evaluation datasets (UCI Statlog *Shuttle* and the *ESA Anomaly*
//! dataset). The real datasets are not redistributable / not available in
//! this environment, so [`synth`] builds statistical stand-ins with the
//! same shape, class cardinality and imbalance — see DESIGN.md
//! §Substitutions.

pub mod csv;
pub mod synth;

pub use synth::{esa_like, shuttle_like, SynthSpec};

use crate::util::Rng;

/// A dense, row-major tabular classification dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    /// Row-major feature matrix, `n_rows * n_features` values.
    pub features: Vec<f32>,
    /// Class label per row, in `[0, n_classes)`.
    pub labels: Vec<u32>,
    pub n_features: usize,
    pub n_classes: usize,
}

impl Dataset {
    /// Build a dataset, validating shape invariants.
    ///
    /// Features must be finite: NaN has no place in the FlInt ordered
    /// domain (a negative-NaN bit pattern would order *below* -inf while
    /// IEEE comparison semantics route NaN to the right/else branch —
    /// the float and integer variants would diverge). Rejecting NaN/inf
    /// at the boundary keeps the hot loops guard-free.
    pub fn new(features: Vec<f32>, labels: Vec<u32>, n_features: usize, n_classes: usize) -> Self {
        assert!(n_features > 0, "n_features must be positive");
        assert_eq!(
            features.len(),
            labels.len() * n_features,
            "features length must equal n_rows * n_features"
        );
        assert!(
            labels.iter().all(|&l| (l as usize) < n_classes),
            "labels must be < n_classes"
        );
        assert!(features.iter().all(|v| v.is_finite()), "features must be finite (no NaN/inf)");
        Dataset { features, labels, n_features, n_classes }
    }

    pub fn n_rows(&self) -> usize {
        self.labels.len()
    }

    /// Borrow row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Class frequency histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Select a subset of rows by index (indices may repeat — used for
    /// bootstrap sampling).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(idx.len() * self.n_features);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset { features, labels, n_features: self.n_features, n_classes: self.n_classes }
    }

    /// Randomized train/test split; `test_frac` of rows go to the test set.
    /// The paper uses a 75/25 split (§IV-B).
    pub fn train_test_split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.n_rows();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_test = ((n as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test.min(n));
        (self.select(train_idx), self.select(test_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            vec![0, 1, 0, 1],
            2,
            2,
        )
    }

    #[test]
    fn shape_accessors() {
        let d = toy();
        assert_eq!(d.n_rows(), 4);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        assert_eq!(d.class_counts(), vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "features length")]
    fn bad_shape_panics() {
        Dataset::new(vec![0.0; 7], vec![0, 1, 0, 1], 2, 2);
    }

    #[test]
    #[should_panic(expected = "features must be finite")]
    fn nan_features_panic() {
        Dataset::new(vec![0.0, f32::NAN, 2.0, 3.0], vec![0, 1], 2, 2);
    }

    #[test]
    #[should_panic(expected = "labels must be")]
    fn bad_label_panics() {
        Dataset::new(vec![0.0; 8], vec![0, 1, 0, 5], 2, 2);
    }

    #[test]
    fn select_with_repeats() {
        let d = toy();
        let s = d.select(&[0, 0, 3]);
        assert_eq!(s.n_rows(), 3);
        assert_eq!(s.row(0), s.row(1));
        assert_eq!(s.labels, vec![0, 0, 1]);
    }

    #[test]
    fn split_partitions_rows() {
        let d = shuttle_like(1000, 42);
        let mut rng = Rng::new(7);
        let (train, test) = d.train_test_split(0.25, &mut rng);
        assert_eq!(train.n_rows() + test.n_rows(), 1000);
        assert_eq!(test.n_rows(), 250);
        assert_eq!(train.n_features, d.n_features);
    }

    #[test]
    fn split_deterministic() {
        let d = shuttle_like(200, 1);
        let (a1, b1) = d.train_test_split(0.25, &mut Rng::new(3));
        let (a2, b2) = d.train_test_split(0.25, &mut Rng::new(3));
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }
}
