//! Dataset substrate: in-memory tabular datasets, train/test splitting,
//! CSV I/O and deterministic synthetic generators shaped like the paper's
//! two evaluation datasets (UCI Statlog *Shuttle* and the *ESA Anomaly*
//! dataset). The real datasets are not redistributable / not available in
//! this environment, so [`synth`] builds statistical stand-ins with the
//! same shape, class cardinality and imbalance — see DESIGN.md
//! §Substitutions.

pub mod csv;
pub mod synth;

pub use synth::{esa_like, shuttle_like, SynthSpec};

use crate::util::Rng;

/// A dense, row-major tabular classification dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    /// Row-major feature matrix, `n_rows * n_features` values.
    pub features: Vec<f32>,
    /// Class label per row, in `[0, n_classes)`.
    pub labels: Vec<u32>,
    /// Feature columns per row.
    pub n_features: usize,
    /// Number of distinct classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Build a dataset, validating shape invariants.
    ///
    /// Features must be finite: NaN has no place in the FlInt ordered
    /// domain (a negative-NaN bit pattern would order *below* -inf while
    /// IEEE comparison semantics route NaN to the right/else branch —
    /// the float and integer variants would diverge). Rejecting NaN/inf
    /// at the boundary keeps the hot loops guard-free.
    pub fn new(features: Vec<f32>, labels: Vec<u32>, n_features: usize, n_classes: usize) -> Self {
        assert!(n_features > 0, "n_features must be positive");
        assert_eq!(
            features.len(),
            labels.len() * n_features,
            "features length must equal n_rows * n_features"
        );
        assert!(
            labels.iter().all(|&l| (l as usize) < n_classes),
            "labels must be < n_classes"
        );
        assert!(features.iter().all(|v| v.is_finite()), "features must be finite (no NaN/inf)");
        Dataset { features, labels, n_features, n_classes }
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.labels.len()
    }

    /// Borrow row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Class frequency histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Select a subset of rows by index (indices may repeat — used for
    /// bootstrap sampling).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(idx.len() * self.n_features);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset { features, labels, n_features: self.n_features, n_classes: self.n_classes }
    }

    /// Randomized train/test split; `test_frac` of rows go to the test set.
    /// The paper uses a 75/25 split (§IV-B).
    pub fn train_test_split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.n_rows();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_test = ((n as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test.min(n));
        (self.select(train_idx), self.select(test_idx))
    }

    /// Stratified train/test split: each class is shuffled and split
    /// independently, so the test side preserves class proportions even
    /// for rare classes (which a plain random split can drop entirely —
    /// fatal for a holdout that must *verify* per-class behaviour, as
    /// the pipeline's parity stage does). Deterministic in `rng`.
    pub fn stratified_split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&test_frac), "test_frac must be in [0, 1]");
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); self.n_classes];
        for (i, &l) in self.labels.iter().enumerate() {
            by_class[l as usize].push(i);
        }
        let mut train_idx = Vec::new();
        let mut test_idx = Vec::new();
        for idx in &mut by_class {
            rng.shuffle(idx);
            let n_test = ((idx.len() as f64) * test_frac).round() as usize;
            let n_test = n_test.min(idx.len());
            test_idx.extend_from_slice(&idx[..n_test]);
            train_idx.extend_from_slice(&idx[n_test..]);
        }
        // De-sort by class so downstream row order carries no signal.
        rng.shuffle(&mut train_idx);
        rng.shuffle(&mut test_idx);
        (self.select(&train_idx), self.select(&test_idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            vec![0, 1, 0, 1],
            2,
            2,
        )
    }

    #[test]
    fn shape_accessors() {
        let d = toy();
        assert_eq!(d.n_rows(), 4);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        assert_eq!(d.class_counts(), vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "features length")]
    fn bad_shape_panics() {
        Dataset::new(vec![0.0; 7], vec![0, 1, 0, 1], 2, 2);
    }

    #[test]
    #[should_panic(expected = "features must be finite")]
    fn nan_features_panic() {
        Dataset::new(vec![0.0, f32::NAN, 2.0, 3.0], vec![0, 1], 2, 2);
    }

    #[test]
    #[should_panic(expected = "labels must be")]
    fn bad_label_panics() {
        Dataset::new(vec![0.0; 8], vec![0, 1, 0, 5], 2, 2);
    }

    #[test]
    fn select_with_repeats() {
        let d = toy();
        let s = d.select(&[0, 0, 3]);
        assert_eq!(s.n_rows(), 3);
        assert_eq!(s.row(0), s.row(1));
        assert_eq!(s.labels, vec![0, 0, 1]);
    }

    #[test]
    fn split_partitions_rows() {
        let d = shuttle_like(1000, 42);
        let mut rng = Rng::new(7);
        let (train, test) = d.train_test_split(0.25, &mut rng);
        assert_eq!(train.n_rows() + test.n_rows(), 1000);
        assert_eq!(test.n_rows(), 250);
        assert_eq!(train.n_features, d.n_features);
    }

    #[test]
    fn stratified_split_preserves_class_proportions() {
        let d = shuttle_like(4000, 11);
        let mut rng = Rng::new(5);
        let (train, test) = d.stratified_split(0.25, &mut rng);
        assert_eq!(train.n_rows() + test.n_rows(), d.n_rows());
        let total = d.class_counts();
        let tr = train.class_counts();
        let te = test.class_counts();
        for c in 0..d.n_classes {
            assert_eq!(tr[c] + te[c], total[c], "class {c} rows lost");
            // Per-class split ratio within one row of round(0.25 * n_c).
            let want = ((total[c] as f64) * 0.25).round() as usize;
            assert!(
                (te[c] as i64 - want as i64).unsigned_abs() <= 1,
                "class {c}: test has {} of {}, want ~{want}",
                te[c],
                total[c]
            );
            // Any class with >= 2 rows appears on both sides... only when
            // rounding keeps one on each side; classes with >= 4 rows and
            // frac 0.25 always keep a training row.
            if total[c] >= 4 {
                assert!(tr[c] > 0, "class {c} vanished from training");
            }
        }
    }

    #[test]
    fn stratified_split_deterministic() {
        let d = shuttle_like(500, 2);
        let (a1, b1) = d.stratified_split(0.3, &mut Rng::new(9));
        let (a2, b2) = d.stratified_split(0.3, &mut Rng::new(9));
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn split_deterministic() {
        let d = shuttle_like(200, 1);
        let (a1, b1) = d.train_test_split(0.25, &mut Rng::new(3));
        let (a2, b2) = d.train_test_split(0.25, &mut Rng::new(3));
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }
}
