//! Minimal CSV I/O for datasets — the entry point of the end-to-end
//! pipeline ("takes a training dataset as input", paper §I). The last
//! column is the class label; all other columns are numeric features.
//! No external dependencies: the generated models must stay freestanding
//! and so does the framework.

use super::Dataset;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Errors from CSV parsing.
#[derive(Debug)]
pub enum CsvError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Shape(String),
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvError::Io(e) => write!(f, "io error: {e}"),
            CsvError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            CsvError::Shape(msg) => write!(f, "shape error: {msg}"),
        }
    }
}

impl std::error::Error for CsvError {}

impl From<std::io::Error> for CsvError {
    fn from(e: std::io::Error) -> Self {
        CsvError::Io(e)
    }
}

/// Parse a dataset from CSV text. `has_header` skips the first line.
/// Labels must be non-negative integers in the last column; `n_classes`
/// is inferred as `max(label) + 1`.
pub fn parse(text: &str, has_header: bool) -> Result<Dataset, CsvError> {
    let mut features = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut n_features: Option<usize> = None;

    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && has_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols.len() < 2 {
            return Err(CsvError::Parse {
                line: lineno + 1,
                msg: "need at least one feature and a label".into(),
            });
        }
        let nf = cols.len() - 1;
        match n_features {
            None => n_features = Some(nf),
            Some(expect) if expect != nf => {
                return Err(CsvError::Shape(format!(
                    "row {} has {} features, expected {}",
                    lineno + 1,
                    nf,
                    expect
                )))
            }
            _ => {}
        }
        for c in &cols[..nf] {
            let v = c.parse::<f32>().map_err(|e| CsvError::Parse {
                line: lineno + 1,
                msg: format!("bad feature '{c}': {e}"),
            })?;
            if !v.is_finite() {
                return Err(CsvError::Parse {
                    line: lineno + 1,
                    msg: format!("non-finite feature '{c}' (NaN/inf rejected; see Dataset::new)"),
                });
            }
            features.push(v);
        }
        let raw_label = cols[nf].parse::<f64>().map_err(|e| CsvError::Parse {
            line: lineno + 1,
            msg: format!("bad label '{}': {e}", cols[nf]),
        })?;
        if raw_label < 0.0 || raw_label.fract() != 0.0 {
            return Err(CsvError::Parse {
                line: lineno + 1,
                msg: format!("label must be a non-negative integer, got {raw_label}"),
            });
        }
        labels.push(raw_label as u32);
    }

    let n_features = n_features.ok_or_else(|| CsvError::Shape("empty csv".into()))?;
    let n_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(Dataset::new(features, labels, n_features, n_classes))
}

/// Read a dataset from a CSV file.
pub fn read_file(path: &Path, has_header: bool) -> Result<Dataset, CsvError> {
    let file = std::fs::File::open(path)?;
    let mut text = String::new();
    for line in std::io::BufReader::new(file).lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    parse(&text, has_header)
}

/// Write a dataset to a CSV file (features..., label).
pub fn write_file(path: &Path, ds: &Dataset) -> Result<(), CsvError> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.n_rows() {
        for v in ds.row(i) {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", ds.labels[i])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let d = parse("1.0,2.0,0\n3.5,-4.0,1\n", false).unwrap();
        assert_eq!(d.n_rows(), 2);
        assert_eq!(d.n_features, 2);
        assert_eq!(d.n_classes, 2);
        assert_eq!(d.row(1), &[3.5, -4.0]);
    }

    #[test]
    fn parse_header_and_blank_lines() {
        let d = parse("a,b,label\n1,2,0\n\n3,4,1\n", true).unwrap();
        assert_eq!(d.n_rows(), 2);
    }

    #[test]
    fn parse_rejects_ragged_rows() {
        assert!(matches!(parse("1,2,0\n1,0\n", false), Err(CsvError::Shape(_))));
    }

    #[test]
    fn parse_rejects_bad_label() {
        assert!(parse("1,2,0.5\n", false).is_err());
        assert!(parse("1,2,-1\n", false).is_err());
        assert!(parse("1,2,x\n", false).is_err());
    }

    #[test]
    fn parse_rejects_non_finite() {
        assert!(parse("nan,2,0\n", false).is_err());
        assert!(parse("1,inf,0\n", false).is_err());
        assert!(parse("1,-inf,0\n", false).is_err());
    }

    /// Fuzz: arbitrary byte soup must never panic — only parse or Err.
    #[test]
    fn prop_parser_never_panics() {
        crate::util::check::check(
            "csv_fuzz",
            |r| {
                let n = r.below(120);
                (0..n)
                    .map(|_| b" ,.\n0123456789eE+-naif\t"[r.below(22)] as char)
                    .collect::<String>()
            },
            |text| {
                let _ = parse(text, false);
                let _ = parse(text, true);
                Ok(())
            },
        );
    }

    #[test]
    fn parse_rejects_empty() {
        assert!(parse("", false).is_err());
    }

    #[test]
    fn roundtrip_file() {
        let d = crate::data::shuttle_like(50, 4);
        let dir = std::env::temp_dir().join("intreeger_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ds.csv");
        write_file(&p, &d).unwrap();
        let d2 = read_file(&p, false).unwrap();
        assert_eq!(d.labels, d2.labels);
        assert_eq!(d.n_features, d2.n_features);
        // floats survive the default Display roundtrip exactly
        assert_eq!(d.features, d2.features);
    }
}
