//! Minimal CSV I/O for datasets — the entry point of the end-to-end
//! pipeline ("takes a training dataset as input", paper §I). The last
//! column is the class label; all other columns are numeric features.
//! No external dependencies: the generated models must stay freestanding
//! and so does the framework.

use super::Dataset;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Errors from CSV parsing.
#[derive(Debug)]
pub enum CsvError {
    /// Underlying file I/O failure.
    Io(std::io::Error),
    /// A cell failed to parse (1-based line number + cause).
    Parse {
        /// 1-based line number of the offending row.
        line: usize,
        /// Human-readable cause.
        msg: String,
    },
    /// Structurally inconsistent input (ragged rows, bad target column,
    /// empty file).
    Shape(String),
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvError::Io(e) => write!(f, "io error: {e}"),
            CsvError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            CsvError::Shape(msg) => write!(f, "shape error: {msg}"),
        }
    }
}

impl std::error::Error for CsvError {}

impl From<std::io::Error> for CsvError {
    fn from(e: std::io::Error) -> Self {
        CsvError::Io(e)
    }
}

/// Parse a dataset from CSV text. `has_header` skips the first line.
/// Labels must be non-negative integers in the last column; `n_classes`
/// is inferred as `max(label) + 1`.
pub fn parse(text: &str, has_header: bool) -> Result<Dataset, CsvError> {
    parse_core(text, has_header, None)
}

/// Parse a dataset with an explicit label column. `target` is a header
/// name (requires `has_header`) or a zero-based column index; `None`
/// falls back to the last column. The remaining columns become features
/// in their original order — the pipeline's "any CSV, any label column"
/// entry point.
pub fn parse_with_target(
    text: &str,
    has_header: bool,
    target: Option<&str>,
) -> Result<Dataset, CsvError> {
    let Some(target) = target else { return parse(text, has_header) };
    let col = if has_header {
        let header = text
            .lines()
            .next()
            .ok_or_else(|| CsvError::Shape("empty csv".into()))?;
        let names: Vec<&str> = header.split(',').map(str::trim).collect();
        match names.iter().position(|n| *n == target) {
            Some(i) => i,
            None => target.parse::<usize>().map_err(|_| {
                CsvError::Shape(format!("target '{target}' is neither a header column ({names:?}) nor an index"))
            })?,
        }
    } else {
        target.parse::<usize>().map_err(|_| {
            CsvError::Shape(format!(
                "--target must be a zero-based column index when the csv has no header, got '{target}'"
            ))
        })?
    };
    parse_core(text, has_header, Some(col))
}

/// Shared row parser; `label_col = None` means the last column.
fn parse_core(text: &str, has_header: bool, label_col: Option<usize>) -> Result<Dataset, CsvError> {
    let mut features = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut n_features: Option<usize> = None;

    for (lineno, line) in text.lines().enumerate() {
        if lineno == 0 && has_header {
            continue;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        if cols.len() < 2 {
            return Err(CsvError::Parse {
                line: lineno + 1,
                msg: "need at least one feature and a label".into(),
            });
        }
        let lc = match label_col {
            None => cols.len() - 1,
            Some(c) if c < cols.len() => c,
            Some(c) => {
                return Err(CsvError::Shape(format!(
                    "label column {c} out of range: row {} has {} columns",
                    lineno + 1,
                    cols.len()
                )))
            }
        };
        let nf = cols.len() - 1;
        match n_features {
            None => n_features = Some(nf),
            Some(expect) if expect != nf => {
                return Err(CsvError::Shape(format!(
                    "row {} has {} features, expected {}",
                    lineno + 1,
                    nf,
                    expect
                )))
            }
            _ => {}
        }
        for (ci, c) in cols.iter().enumerate() {
            if ci == lc {
                continue;
            }
            let v = c.parse::<f32>().map_err(|e| CsvError::Parse {
                line: lineno + 1,
                msg: format!("bad feature '{c}': {e}"),
            })?;
            if !v.is_finite() {
                return Err(CsvError::Parse {
                    line: lineno + 1,
                    msg: format!("non-finite feature '{c}' (NaN/inf rejected; see Dataset::new)"),
                });
            }
            features.push(v);
        }
        let raw_label = cols[lc].parse::<f64>().map_err(|e| CsvError::Parse {
            line: lineno + 1,
            msg: format!("bad label '{}': {e}", cols[lc]),
        })?;
        if raw_label < 0.0 || raw_label.fract() != 0.0 {
            return Err(CsvError::Parse {
                line: lineno + 1,
                msg: format!("label must be a non-negative integer, got {raw_label}"),
            });
        }
        labels.push(raw_label as u32);
    }

    let n_features = n_features.ok_or_else(|| CsvError::Shape("empty csv".into()))?;
    let n_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(Dataset::new(features, labels, n_features, n_classes))
}

/// Read a dataset from a CSV file.
pub fn read_file(path: &Path, has_header: bool) -> Result<Dataset, CsvError> {
    read_file_with_target(path, has_header, None)
}

/// Read a dataset from a CSV file with an explicit label column (see
/// [`parse_with_target`]).
pub fn read_file_with_target(
    path: &Path,
    has_header: bool,
    target: Option<&str>,
) -> Result<Dataset, CsvError> {
    let file = std::fs::File::open(path)?;
    let mut text = String::new();
    for line in std::io::BufReader::new(file).lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    parse_with_target(&text, has_header, target)
}

/// Write a dataset to a CSV file (features..., label).
pub fn write_file(path: &Path, ds: &Dataset) -> Result<(), CsvError> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for i in 0..ds.n_rows() {
        for v in ds.row(i) {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", ds.labels[i])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let d = parse("1.0,2.0,0\n3.5,-4.0,1\n", false).unwrap();
        assert_eq!(d.n_rows(), 2);
        assert_eq!(d.n_features, 2);
        assert_eq!(d.n_classes, 2);
        assert_eq!(d.row(1), &[3.5, -4.0]);
    }

    #[test]
    fn parse_header_and_blank_lines() {
        let d = parse("a,b,label\n1,2,0\n\n3,4,1\n", true).unwrap();
        assert_eq!(d.n_rows(), 2);
    }

    #[test]
    fn parse_rejects_ragged_rows() {
        assert!(matches!(parse("1,2,0\n1,0\n", false), Err(CsvError::Shape(_))));
    }

    #[test]
    fn parse_rejects_bad_label() {
        assert!(parse("1,2,0.5\n", false).is_err());
        assert!(parse("1,2,-1\n", false).is_err());
        assert!(parse("1,2,x\n", false).is_err());
    }

    #[test]
    fn target_by_header_name() {
        let text = "label,a,b\n0,1.0,2.0\n1,3.5,-4.0\n";
        let d = parse_with_target(text, true, Some("label")).unwrap();
        assert_eq!(d.n_rows(), 2);
        assert_eq!(d.n_features, 2);
        assert_eq!(d.labels, vec![0, 1]);
        assert_eq!(d.row(1), &[3.5, -4.0]);
    }

    #[test]
    fn target_by_index_middle_column() {
        let text = "1.0,0,2.0\n3.5,1,-4.0\n";
        let d = parse_with_target(text, false, Some("1")).unwrap();
        assert_eq!(d.labels, vec![0, 1]);
        // Features keep their original order with the label removed.
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(1), &[3.5, -4.0]);
    }

    #[test]
    fn target_none_is_last_column() {
        let text = "1.0,2.0,1\n";
        let a = parse_with_target(text, false, None).unwrap();
        let b = parse(text, false).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn target_errors_are_clean() {
        // unknown header name
        assert!(matches!(
            parse_with_target("a,b,c\n1,2,0\n", true, Some("nope")),
            Err(CsvError::Shape(_))
        ));
        // name without a header
        assert!(matches!(
            parse_with_target("1,2,0\n", false, Some("label")),
            Err(CsvError::Shape(_))
        ));
        // index out of range
        assert!(matches!(
            parse_with_target("1,2,0\n", false, Some("7")),
            Err(CsvError::Shape(_))
        ));
    }

    #[test]
    fn parse_rejects_non_finite() {
        assert!(parse("nan,2,0\n", false).is_err());
        assert!(parse("1,inf,0\n", false).is_err());
        assert!(parse("1,-inf,0\n", false).is_err());
    }

    /// Fuzz: arbitrary byte soup must never panic — only parse or Err.
    #[test]
    fn prop_parser_never_panics() {
        crate::util::check::check(
            "csv_fuzz",
            |r| {
                let n = r.below(120);
                (0..n)
                    .map(|_| b" ,.\n0123456789eE+-naif\t"[r.below(22)] as char)
                    .collect::<String>()
            },
            |text| {
                let _ = parse(text, false);
                let _ = parse(text, true);
                Ok(())
            },
        );
    }

    #[test]
    fn parse_rejects_empty() {
        assert!(parse("", false).is_err());
    }

    #[test]
    fn roundtrip_file() {
        let d = crate::data::shuttle_like(50, 4);
        let dir = std::env::temp_dir().join("intreeger_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ds.csv");
        write_file(&p, &d).unwrap();
        let d2 = read_file(&p, false).unwrap();
        assert_eq!(d.labels, d2.labels);
        assert_eq!(d.n_features, d2.n_features);
        // floats survive the default Display roundtrip exactly
        assert_eq!(d.features, d2.features);
    }
}
