//! Deterministic synthetic stand-ins for the paper's evaluation datasets.
//!
//! The paper evaluates on two datasets we cannot redistribute here:
//!
//! * **Statlog (Shuttle)** — 58 000 instances, 7 numeric features,
//!    7 classes, heavily imbalanced (~80 % of rows are class 1).
//! * **ESA Anomaly Dataset** (first 3 months) — 262 081 instances,
//!   87 telemetry channels, binarized to 2 classes (anomaly ≈ rare).
//!
//! [`shuttle_like`] and [`esa_like`] generate datasets with the same shape,
//! class cardinality and imbalance. Labels are produced by a random
//! axis-aligned *latent decision tree* (a "teacher") plus label noise, so
//! that tree learners fit the data well but not perfectly — this yields
//! realistic leaf-probability distributions, which is what the paper's
//! probability-to-integer conversion (§III-A) must preserve.

use super::Dataset;
use crate::util::Rng;

/// Parameters for the synthetic generator.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Rows to generate.
    pub n_rows: usize,
    /// Feature columns.
    pub n_features: usize,
    /// Distinct classes.
    pub n_classes: usize,
    /// Depth of the latent teacher tree that assigns class structure.
    pub teacher_depth: usize,
    /// Probability that a row's label is resampled from the class prior
    /// (label noise — keeps leaf probabilities away from {0,1}).
    pub label_noise: f64,
    /// Per-class prior used for imbalance and for noisy labels.
    pub class_prior: Vec<f64>,
    /// Feature value range (uniform base distribution).
    pub range: (f32, f32),
}

impl SynthSpec {
    /// Spec matching the Shuttle dataset's shape: 7 features, 7 classes,
    /// ~80 % mass on one class.
    pub fn shuttle(n_rows: usize) -> Self {
        // Approximate Statlog (Shuttle) class distribution: class 0 ("Rad
        // Flow") dominates.
        let prior = vec![0.786, 0.0008, 0.003, 0.154, 0.0556, 0.0003, 0.0003];
        SynthSpec {
            n_rows,
            n_features: 7,
            n_classes: 7,
            // Low label noise: the real Shuttle data is largely separable
            // (classifiers reach >99.9 %), which makes depth-limited trees
            // prune early — important for the §IV-E footprint numbers.
            teacher_depth: 6,
            label_noise: 0.02,
            class_prior: prior,
            range: (-120.0, 160.0),
        }
    }

    /// Spec matching the binarized ESA anomaly dataset: 87 channels,
    /// 2 classes with a rare positive (~5 %).
    pub fn esa(n_rows: usize) -> Self {
        SynthSpec {
            n_rows,
            n_features: 87,
            n_classes: 2,
            teacher_depth: 8,
            label_noise: 0.05,
            class_prior: vec![0.95, 0.05],
            range: (-4.0, 4.0),
        }
    }
}

/// A node of the latent teacher tree.
enum TeacherNode {
    Branch { feature: usize, threshold: f32, left: usize, right: usize },
    /// `noisy` marks an ambiguous region: only rows landing here get
    /// label noise. Keeping most regions exactly separable matches real
    /// tabular data (Shuttle is >99.9 % learnable) and lets depth-limited
    /// trees reach pure nodes and prune — which drives the §IV-E
    /// footprint numbers.
    Leaf { class: u32, noisy: bool },
}

struct Teacher {
    nodes: Vec<TeacherNode>,
}

impl Teacher {
    /// Grow a random full tree of the given depth. Leaf classes are drawn
    /// from the prior so the marginal class distribution approximates it.
    fn grow(spec: &SynthSpec, rng: &mut Rng) -> Teacher {
        let mut nodes = Vec::new();
        Self::grow_rec(spec, rng, &mut nodes, spec.teacher_depth);
        Teacher { nodes }
    }

    fn grow_rec(spec: &SynthSpec, rng: &mut Rng, nodes: &mut Vec<TeacherNode>, depth: usize) -> usize {
        let id = nodes.len();
        if depth == 0 {
            let class = sample_prior(&spec.class_prior, rng);
            // ~30 % of regions are ambiguous; the rest are separable.
            let noisy = rng.chance(0.3);
            nodes.push(TeacherNode::Leaf { class, noisy });
            return id;
        }
        nodes.push(TeacherNode::Leaf { class: 0, noisy: false }); // placeholder
        let feature = rng.below(spec.n_features);
        // Thresholds away from the extremes so both sides get mass.
        let t = rng.uniform_in(
            spec.range.0 + 0.2 * (spec.range.1 - spec.range.0),
            spec.range.1 - 0.2 * (spec.range.1 - spec.range.0),
        );
        let left = Self::grow_rec(spec, rng, nodes, depth - 1);
        let right = Self::grow_rec(spec, rng, nodes, depth - 1);
        nodes[id] = TeacherNode::Branch { feature, threshold: t, left, right };
        id
    }

    fn classify(&self, row: &[f32]) -> (u32, bool) {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                TeacherNode::Branch { feature, threshold, left, right } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
                TeacherNode::Leaf { class, noisy } => return (*class, *noisy),
            }
        }
    }
}

fn sample_prior(prior: &[f64], rng: &mut Rng) -> u32 {
    let u = rng.uniform();
    let mut acc = 0.0;
    for (c, &p) in prior.iter().enumerate() {
        acc += p;
        if u < acc {
            return c as u32;
        }
    }
    (prior.len() - 1) as u32
}

/// Generate a dataset from a spec. Deterministic in `seed`.
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    assert!((spec.class_prior.iter().sum::<f64>() - 1.0).abs() < 1e-6, "prior must sum to 1");
    assert_eq!(spec.class_prior.len(), spec.n_classes);
    let mut rng = Rng::new(seed);
    let teacher = Teacher::grow(spec, &mut rng);

    let mut features = Vec::with_capacity(spec.n_rows * spec.n_features);
    let mut labels = Vec::with_capacity(spec.n_rows);
    for _ in 0..spec.n_rows {
        let base = features.len();
        for _ in 0..spec.n_features {
            // Mixture of uniform base + a gaussian cluster component so
            // features have non-trivial marginals (like real telemetry).
            let v = if rng.chance(0.7) {
                rng.uniform_in(spec.range.0, spec.range.1)
            } else {
                let mid = 0.5 * (spec.range.0 + spec.range.1);
                let std = 0.15 * (spec.range.1 - spec.range.0);
                rng.gauss_f32(mid, std)
            };
            features.push(v);
        }
        let row = &features[base..];
        let (mut label, noisy_region) = teacher.classify(row);
        // Noise is concentrated in ambiguous regions (scaled up 3x there
        // so the dataset-wide noise rate stays ~label_noise).
        if noisy_region && rng.chance(spec.label_noise * 3.0) {
            label = sample_prior(&spec.class_prior, &mut rng);
        }
        labels.push(label);
    }
    Dataset::new(features, labels, spec.n_features, spec.n_classes)
}

/// Shuttle-shaped dataset (7 features, 7 classes, imbalanced). The paper's
/// full size is 58 000 rows; pass that for the faithful shape or something
/// smaller for quick tests.
pub fn shuttle_like(n_rows: usize, seed: u64) -> Dataset {
    generate(&SynthSpec::shuttle(n_rows), seed)
}

/// ESA-anomaly-shaped dataset (87 features, 2 classes, rare positive).
/// The paper uses 262 081 rows; benchmarks default to a scaled subset.
pub fn esa_like(n_rows: usize, seed: u64) -> Dataset {
    generate(&SynthSpec::esa(n_rows), seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuttle_shape() {
        let d = shuttle_like(2000, 0);
        assert_eq!(d.n_rows(), 2000);
        assert_eq!(d.n_features, 7);
        assert_eq!(d.n_classes, 7);
    }

    #[test]
    fn esa_shape() {
        let d = esa_like(1000, 0);
        assert_eq!(d.n_features, 87);
        assert_eq!(d.n_classes, 2);
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(shuttle_like(500, 9), shuttle_like(500, 9));
        assert_ne!(shuttle_like(500, 9), shuttle_like(500, 10));
    }

    #[test]
    fn esa_positive_class_is_rare() {
        let d = esa_like(20_000, 3);
        let counts = d.class_counts();
        let pos_frac = counts[1] as f64 / d.n_rows() as f64;
        assert!(pos_frac > 0.01 && pos_frac < 0.25, "pos_frac = {pos_frac}");
    }

    #[test]
    fn shuttle_majority_class_dominates() {
        let d = shuttle_like(20_000, 3);
        let counts = d.class_counts();
        let max_frac = *counts.iter().max().unwrap() as f64 / d.n_rows() as f64;
        assert!(max_frac > 0.4, "max class frac = {max_frac}");
        // More than one class must actually occur.
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 3);
    }

    #[test]
    fn labels_are_learnable() {
        // A depth-limited latent tree + noise means labels correlate with
        // features: the same feature vector classified by the teacher equals
        // the label for most rows. Implicitly verified by the trees module's
        // accuracy tests; here we just sanity-check noise isn't total.
        let d = shuttle_like(5000, 8);
        // With 8% label noise the majority class should not be 100%.
        let counts = d.class_counts();
        assert!(*counts.iter().max().unwrap() < d.n_rows());
    }
}
