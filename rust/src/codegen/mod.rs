//! C code generation — the paper's end product: an "architecture-agnostic
//! integer-only C implementation" of the trained model (§I), in the style
//! of tl2cgen's if-else trees.
//!
//! Three numeric variants are generated, matching the paper's comparison
//! (§IV, Listings 2–4):
//!
//! * [`Variant::Float`] — float compares + float accumulation,
//! * [`Variant::FlInt`] — integer compares + float accumulation,
//! * [`Variant::IntTreeger`] — integer compares + `u32` accumulation
//!   (no float arithmetic appears anywhere in the generated inference path).
//!
//! Two layouts are generated for the layout ablation (Asadi et al.'s
//! distinction the paper builds on, §II-B):
//!
//! * [`ifelse`] — nested `if/else` blocks, one function per tree (what
//!   the paper evaluates; code-heavy, data-light),
//! * [`native`] — node arrays walked by a loop (smaller code, more data).
//!
//! [`compile`] drives gcc over the generated source and runs the binary
//! for parity and measurement — on this x86 host that is a *real*
//! measurement of the paper's x86 column, not a simulation.

pub mod compile;
pub mod ifelse;
pub mod native;
pub mod quickscorer;

pub use compile::{CBinary, CompileError};
pub use ifelse::generate_ifelse;
pub use native::{generate_native, generate_native_predicated};
pub use quickscorer::generate_quickscorer;

use crate::inference::Variant;
use crate::ir::Model;

/// Code layout style.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Nested `if/else` blocks, one function per tree (what the paper
    /// evaluates; code-heavy, data-light).
    IfElse,
    /// Node arrays walked by a loop (smaller code, more data).
    Native,
    /// Child-adjacent node tables walked by a predicated fixed-trip loop
    /// — the generated-C mirror of the Rust branchless batch kernel.
    NativePredicated,
    /// Feature-sorted condition streams + `u64` false-leaf bitmasks —
    /// the generated-C mirror of the Rust QuickScorer kernel
    /// ([`quickscorer`]; requires every tree to have ≤ 64 leaves).
    QuickScorer,
}

impl Layout {
    /// CLI / report name of the layout.
    pub fn name(self) -> &'static str {
        match self {
            Layout::IfElse => "ifelse",
            Layout::Native => "native",
            Layout::NativePredicated => "native-predicated",
            Layout::QuickScorer => "quickscorer",
        }
    }

    /// Every layout, in CLI listing order — the single source of truth
    /// the argument parser and the generated usage text both iterate.
    pub fn all() -> [Layout; 4] {
        [Layout::IfElse, Layout::Native, Layout::NativePredicated, Layout::QuickScorer]
    }

    /// Parse a CLI layout name (inverse of [`Self::name`]).
    pub fn from_name(name: &str) -> Option<Layout> {
        Layout::all().into_iter().find(|l| l.name() == name)
    }
}

/// Generate C source for a model in the given layout and numeric variant.
pub fn generate(model: &Model, layout: Layout, variant: Variant) -> String {
    match layout {
        Layout::IfElse => generate_ifelse(model, variant),
        Layout::Native => generate_native(model, variant),
        Layout::NativePredicated => generate_native_predicated(model, variant),
        Layout::QuickScorer => generate_quickscorer(model, variant),
    }
}

/// Format an f32 as a C literal that round-trips bit-exactly
/// (C99 hexadecimal float literal).
pub(crate) fn f32_lit(x: f32) -> String {
    if x == 0.0 {
        return "0.0f".to_string();
    }
    if x.is_infinite() || x.is_nan() {
        panic!("non-finite constant in generated code");
    }
    let bits = x.to_bits();
    let sign = if bits >> 31 == 1 { "-" } else { "" };
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0 {
        // subnormal: value = 0.mant * 2^-126
        format!("{sign}0x0.{:06x}p-126f", mant << 1)
    } else {
        format!("{sign}0x1.{:06x}p{}f", mant << 1, exp - 127)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_names_roundtrip() {
        assert_eq!(Layout::all().len(), 4);
        for l in Layout::all() {
            assert_eq!(Layout::from_name(l.name()), Some(l));
        }
        assert_eq!(Layout::from_name("nope"), None);
    }

    #[test]
    fn f32_lit_roundtrips() {
        for &x in &[1.0f32, 87.5, 0.1, 1.5e-40, -3.25, f32::MAX, f32::MIN_POSITIVE] {
            let lit = f32_lit(x);
            let parsed = parse_hexfloat(&lit);
            assert_eq!(parsed.to_bits(), x.to_bits(), "{x} -> {lit}");
        }
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..2000 {
            let x = crate::util::check::finite_f32(&mut rng);
            let lit = f32_lit(x);
            let parsed = parse_hexfloat(&lit);
            assert_eq!(
                parsed.to_bits(),
                crate::flint::canon_zero(x).to_bits(),
                "{x} -> {lit}"
            );
        }
    }

    /// Reference hexfloat parser for the test
    /// (format: [-]0xH.HHHHHHp±Ef).
    fn parse_hexfloat(s: &str) -> f32 {
        let s = s.strip_suffix('f').unwrap();
        let (sign, s) = match s.strip_prefix('-') {
            Some(rest) => (-1.0f64, rest),
            None => (1.0f64, s),
        };
        if s == "0.0" {
            return if sign < 0.0 { -0.0 } else { 0.0 };
        }
        let s = s.strip_prefix("0x").unwrap();
        let (mant_str, exp_str) = s.split_once('p').unwrap();
        let (int_part, frac_part) = mant_str.split_once('.').unwrap();
        let int_v = u64::from_str_radix(int_part, 16).unwrap() as f64;
        let frac_v = u64::from_str_radix(frac_part, 16).unwrap() as f64
            / 16f64.powi(frac_part.len() as i32);
        let exp: i32 = exp_str.parse().unwrap();
        (sign * (int_v + frac_v) * 2f64.powi(exp)) as f32
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn f32_lit_rejects_nan() {
        f32_lit(f32::NAN);
    }

    #[test]
    fn zero_literal() {
        assert_eq!(f32_lit(0.0), "0.0f");
        assert_eq!(f32_lit(-0.0), "0.0f");
    }
}
