//! QuickScorer-layout C code generation: the bitvector forest kernel
//! ([`crate::inference::quickscorer`]) as architecture-agnostic,
//! integer-only C — static per-feature condition arrays sorted by
//! threshold, `u64` false-leaf masks, no recursion, no node structs, no
//! tree walks.
//!
//! The emitted `predict()` is the exact algorithm the Rust kernel runs:
//! per feature, scan the sorted condition stream and AND each false
//! condition's mask into its tree's bitvector until the first true
//! condition; the exit leaf of every tree is then the lowest set bit.
//! For the integer variants every operation in the inference path is
//! u32/u64 integer arithmetic (the trailing-zero count is a portable
//! shift loop — no compiler builtins), so the generated C inherits the
//! paper's integer-only guarantee on any architecture.
//!
//! The layout requires every tree to fit a `u64` mask
//! ([`QS_MAX_LEAVES`] leaves); models with wider trees are rejected with
//! a pointer at `--layout native-predicated` (the Rust runtime kernel
//! falls back per tree instead — C stays single-strategy on purpose).

use super::ifelse::{acc_type, assert_rawbits_thresholds, harness, GenOpts};
use crate::flint::SplitEncoding;
use crate::inference::quickscorer::{QsPlan, QS_MAX_LEAVES};
use crate::inference::Variant;
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;
use std::fmt::Write;

/// Generate QuickScorer-layout C for a model (default options).
pub fn generate_quickscorer(model: &Model, variant: Variant) -> String {
    generate_quickscorer_with(model, variant, GenOpts::default())
}

/// Generate QuickScorer-layout C with explicit options.
pub fn generate_quickscorer_with(model: &Model, variant: Variant, opts: GenOpts) -> String {
    assert_eq!(model.kind, ModelKind::RandomForest, "C generation targets RF models");
    model.validate().expect("model must be valid");
    assert_rawbits_thresholds(model, opts);
    assert!(!model.trees.is_empty(), "quickscorer layout needs at least one tree");
    // One block spanning the whole forest: the C output is a per-row
    // kernel, so cache-blocking over trees buys nothing there.
    let plan = QsPlan::build_with(model, model.trees.len());
    assert!(
        plan.fallback.is_empty(),
        "quickscorer layout requires every tree to have <= {QS_MAX_LEAVES} leaves \
         (trees {:?} exceed it); generate --layout native-predicated instead",
        plan.fallback
    );
    let block = &plan.blocks[0];

    let mut out = String::new();
    super::ifelse::header(&mut out, model, variant, "quickscorer", opts);

    let n_cond = block.masks.len();
    // C forbids zero-length arrays; a forest of single-leaf trees has no
    // conditions, so pad with one dead entry the loops never read.
    let pad = n_cond == 0;
    let thresh: Vec<String> = if pad {
        vec![if variant == Variant::Float { "0.0f".into() } else { "0u".into() }]
    } else {
        (0..n_cond)
            .map(|i| match (variant, opts.encoding) {
                (Variant::Float, _) => super::f32_lit(f32::from_bits(block.thresh_f32[i])),
                (_, SplitEncoding::RawBitsNonNegative) => {
                    format!("0x{:08x}u", block.thresh_f32[i])
                }
                (_, SplitEncoding::OrderedUnsigned) => format!("0x{:08x}u", block.thresh_ord[i]),
            })
            .collect()
    };
    let tree_of: Vec<String> = if pad {
        vec!["0".into()]
    } else {
        block.tree_of.iter().map(|t| t.to_string()).collect()
    };
    let masks: Vec<String> = if pad {
        vec!["0ull".into()]
    } else {
        block.masks.iter().map(|m| format!("0x{m:016x}ull")).collect()
    };

    // Leaf values in payload-row order (IR node order — the same
    // assignment every other layout and the Rust engines use).
    let mut leaf_vals: Vec<String> = Vec::new();
    for tree in &model.trees {
        for node in &tree.nodes {
            if let Node::Leaf { values } = node {
                for &p in values {
                    leaf_vals.push(match variant {
                        Variant::Float | Variant::FlInt => super::f32_lit(p),
                        Variant::IntTreeger => {
                            format!("{}u", prob_to_fixed(p, model.trees.len()))
                        }
                    });
                }
            }
        }
    }

    let thresh_ty = if variant == Variant::Float { "float" } else { "uint32_t" };
    let acc = acc_type(variant);

    let _ = writeln!(out, "#define N_COND {n_cond}");
    let _ = writeln!(
        out,
        "static const uint32_t qs_off[N_FEATURES + 1] = {{{}}};",
        join(&block.feature_offsets)
    );
    let _ = writeln!(
        out,
        "static const {thresh_ty} qs_thresh[{}] = {{{}}};",
        thresh.len(),
        thresh.join(",")
    );
    let _ = writeln!(
        out,
        "static const uint16_t qs_tree[{}] = {{{}}};",
        tree_of.len(),
        tree_of.join(",")
    );
    let _ = writeln!(
        out,
        "static const uint64_t qs_mask[{}] = {{{}}};",
        masks.len(),
        masks.join(",")
    );
    let _ = writeln!(
        out,
        "static const uint64_t qs_init[N_TREES] = {{{}}};",
        block.init.iter().map(|v| format!("0x{v:016x}ull")).collect::<Vec<_>>().join(",")
    );
    let _ = writeln!(
        out,
        "static const uint32_t qs_leafofs[N_TREES] = {{{}}};",
        join(&block.leaf_offsets[..block.n_trees])
    );
    let _ = writeln!(
        out,
        "static const uint32_t qs_leafidx[{}] = {{{}}};",
        block.leaf_payloads.len(),
        join(&block.leaf_payloads)
    );
    let _ = writeln!(
        out,
        "static const {acc} it_leaf[{}] = {{{}}};",
        leaf_vals.len(),
        leaf_vals.join(",")
    );
    let _ = writeln!(out);

    // Portable trailing-zero count: integer shifts only, no builtins.
    // The bitvector is never zero (the exit leaf always survives).
    let _ = writeln!(
        out,
        "static inline uint32_t it_ctz64(uint64_t v) {{\n\
         \x20 uint32_t c = 0u;\n\
         \x20 while (!(v & 1ull)) {{ v >>= 1; ++c; }}\n\
         \x20 return c;\n}}"
    );
    let _ = writeln!(out);

    let _ = writeln!(out, "void predict(const float *data, {acc} *result) {{");
    if variant != Variant::Float {
        let _ = writeln!(out, "  uint32_t d[N_FEATURES];");
        let loader = match opts.encoding {
            SplitEncoding::OrderedUnsigned => "it_map(it_load_bits(data + i))",
            SplitEncoding::RawBitsNonNegative => "it_load_bits(data + i)",
        };
        let _ = writeln!(out, "  for (int i = 0; i < N_FEATURES; ++i) d[i] = {loader};");
    }
    let _ = writeln!(out, "  uint64_t v[N_TREES];");
    let _ = writeln!(out, "  for (int t = 0; t < N_TREES; ++t) v[t] = qs_init[t];");
    // The false conditions of a feature are a prefix of its
    // threshold-sorted stream: AND masks until the first true condition.
    // The compare is the literal negation of `<=`-goes-left so even NaN
    // inputs route exactly like the other layouts (NaN never breaks).
    let cmp = match (variant, opts.encoding) {
        (Variant::Float, _) => "!(data[f] <= qs_thresh[i])".to_string(),
        (_, SplitEncoding::RawBitsNonNegative) => {
            "(int32_t)d[f] > (int32_t)qs_thresh[i]".to_string()
        }
        (_, SplitEncoding::OrderedUnsigned) => "d[f] > qs_thresh[i]".to_string(),
    };
    let _ = writeln!(out, "  for (int f = 0; f < N_FEATURES; ++f) {{");
    let _ = writeln!(out, "    for (uint32_t i = qs_off[f]; i < qs_off[f + 1]; ++i) {{");
    let _ = writeln!(out, "      if (!({cmp})) break;");
    let _ = writeln!(out, "      v[qs_tree[i]] &= qs_mask[i];");
    let _ = writeln!(out, "    }}");
    let _ = writeln!(out, "  }}");
    let zero = if variant == Variant::IntTreeger { "0u" } else { "0.0f" };
    let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] = {zero};");
    let _ = writeln!(out, "  for (int t = 0; t < N_TREES; ++t) {{");
    let _ = writeln!(
        out,
        "    const {acc} *leaf = it_leaf + \
         (size_t)qs_leafidx[qs_leafofs[t] + it_ctz64(v[t])] * N_CLASSES;"
    );
    let _ = writeln!(out, "    for (int c = 0; c < N_CLASSES; ++c) result[c] += leaf[c];");
    let _ = writeln!(out, "  }}");
    if variant != Variant::IntTreeger {
        let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] /= (float)N_TREES;");
    }
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);

    harness(&mut out, model, variant);
    out
}

fn join<T: std::fmt::Display>(xs: &[T]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::flint::ordered_u32;
    use crate::ir::{ModelKind, Tree};
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> Model {
        let ds = shuttle_like(700, 51);
        RandomForest::train(&ds, &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() }, 5)
    }

    /// Golden test: a hand-built deterministic stump pins every emitted
    /// table and the scan/extract loops byte-for-byte.
    #[test]
    fn quickscorer_golden_stump() {
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 2 },
                    Node::Leaf { values: vec![0.9, 0.1] },
                    Node::Leaf { values: vec![0.2, 0.8] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        };
        let src = generate_quickscorer(&m, Variant::IntTreeger);
        let t = ordered_u32(0.5);
        let q = |p: f32| prob_to_fixed(p, 1);
        for line in [
            "#define N_COND 1".to_string(),
            "static const uint32_t qs_off[N_FEATURES + 1] = {0,1};".to_string(),
            format!("static const uint32_t qs_thresh[1] = {{0x{t:08x}u}};"),
            "static const uint16_t qs_tree[1] = {0};".to_string(),
            "static const uint64_t qs_mask[1] = {0xfffffffffffffffeull};".to_string(),
            "static const uint64_t qs_init[N_TREES] = {0x0000000000000003ull};".to_string(),
            "static const uint32_t qs_leafofs[N_TREES] = {0};".to_string(),
            "static const uint32_t qs_leafidx[2] = {0,1};".to_string(),
            format!(
                "static const uint32_t it_leaf[4] = {{{}u,{}u,{}u,{}u}};",
                q(0.9),
                q(0.1),
                q(0.2),
                q(0.8)
            ),
            "      if (!(d[f] > qs_thresh[i])) break;".to_string(),
            "      v[qs_tree[i]] &= qs_mask[i];".to_string(),
            "    const uint32_t *leaf = it_leaf + \
             (size_t)qs_leafidx[qs_leafofs[t] + it_ctz64(v[t])] * N_CLASSES;"
                .to_string(),
        ] {
            assert!(src.contains(&line), "missing golden line:\n{line}\nin:\n{src}");
        }
        // No node machinery anywhere: the whole point of the layout.
        for absent in ["it_left", "it_right", "it_feat", "it_depth", "it_root"] {
            assert!(!src.contains(absent), "node-walk table {absent} leaked");
        }
    }

    #[test]
    fn emits_all_variants_and_stays_integer_only_for_int() {
        let m = model();
        for v in [Variant::Float, Variant::FlInt, Variant::IntTreeger] {
            let src = generate_quickscorer(&m, v);
            for t in ["qs_off", "qs_thresh", "qs_tree", "qs_mask", "qs_init", "qs_leafidx", "it_leaf"]
            {
                assert!(src.contains(t), "{}: missing table {t}", v.name());
            }
            assert!(src.contains("layout: quickscorer"), "{}", v.name());
        }
        let src = generate_quickscorer(&m, Variant::IntTreeger);
        let inference = src.split("#ifndef INTREEGER_NO_MAIN").next().unwrap();
        assert!(!inference.contains("0x1."), "float literal leaked");
        assert!(!inference.contains("float *result"));
    }

    #[test]
    #[should_panic(expected = "<= 64 leaves")]
    fn rejects_trees_wider_than_a_u64_mask() {
        // A right-leaning chain with 65 leaves: branch i sits at node
        // 2i with a leaf left child at 2i+1 and the next branch (or the
        // final leaf) at 2i+2.
        let n_branches = 64usize;
        let mut fixed = Vec::with_capacity(2 * n_branches + 1);
        for i in 0..n_branches {
            fixed.push(Node::Branch {
                feature: 0,
                threshold: i as f32,
                left: (2 * i + 1) as u32,
                right: (2 * i + 2) as u32,
            });
            fixed.push(Node::Leaf { values: vec![0.5, 0.5] });
        }
        fixed.push(Node::Leaf { values: vec![0.5, 0.5] });
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree { nodes: fixed }],
            base_score: vec![0.0, 0.0],
        };
        m.validate().expect("chain must validate");
        generate_quickscorer(&m, Variant::IntTreeger);
    }

    #[test]
    fn rawbits_requires_nonneg_thresholds() {
        let mut m = model();
        for node in &mut m.trees[0].nodes {
            if let Node::Branch { threshold, .. } = node {
                *threshold = -1.0;
                break;
            }
        }
        let opts = GenOpts { encoding: SplitEncoding::RawBitsNonNegative, ..Default::default() };
        let r = std::panic::catch_unwind(|| {
            generate_quickscorer_with(&m, Variant::IntTreeger, opts)
        });
        assert!(r.is_err(), "negative threshold must be rejected under raw-bits");
    }

    /// End-to-end: the QuickScorer C binary is bit-identical to the Rust
    /// integer engine (gcc-gated), including threshold-exact rows.
    #[test]
    fn quickscorer_c_matches_engines() {
        use crate::codegen::compile::{gcc_available, CBinary};
        use crate::inference::IntEngine;
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let ds = shuttle_like(1000, 52);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() },
            8,
        );
        let engine = IntEngine::compile(&m);
        let src = generate_quickscorer(&m, Variant::IntTreeger);
        let bin = CBinary::compile(&src, Variant::IntTreeger, m.n_features, m.n_classes, "qs")
            .expect("compile quickscorer C");
        let n = 200usize;
        let mut rows = ds.features[..n * ds.n_features].to_vec();
        // Pin a few values exactly onto thresholds (the <= boundary).
        if let Node::Branch { feature, threshold, .. } = &m.trees[0].nodes[0] {
            for r in (0..n).step_by(7) {
                rows[r * ds.n_features + *feature as usize] = *threshold;
            }
        }
        let got = bin.predict_u32(&rows).expect("run quickscorer C");
        for i in 0..n {
            let row = &rows[i * ds.n_features..(i + 1) * ds.n_features];
            assert_eq!(got[i], engine.predict_fixed(row), "row {i}");
        }
    }
}
