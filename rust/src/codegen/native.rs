//! Native-tree code generation: the forest as constant node arrays walked
//! by a loop (Asadi et al.'s "native" layout, §II-B) — the layout-ablation
//! counterpart to [`super::ifelse`]. Much smaller `.text`, larger
//! `.rodata`; the paper argues if-else trees suit RAM-limited
//! microcontrollers better, which bench `layout_ablation` quantifies.
//!
//! [`generate_native_predicated`] additionally emits the **predicated
//! child-adjacent** form mirroring the Rust batch core's branchless
//! kernel (`inference::batch`): nodes are laid out BFS child-adjacent so
//! there is no `it_right` table at all, leaves self-loop behind a flag
//! bit in the feature word, and each tree's walk is a fixed-trip loop
//! with an arithmetic descent step — the paper's generated-C deliverable
//! inherits the branchless optimization.

use super::ifelse::{acc_type, assert_rawbits_thresholds, harness, GenOpts};
use crate::flint::{ordered_u32, SplitEncoding};
use crate::inference::compiled::{child_adjacent_order, FEATURE_MASK, LEAF, LEAF_BIT, MAX_FEATURES};
use crate::inference::{NodeOrder, Variant};
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;
use std::fmt::Write;

/// Generate native-layout C for a model (default options).
pub fn generate_native(model: &Model, variant: Variant) -> String {
    generate_native_with(model, variant, GenOpts::default())
}

/// Generate native-layout C with explicit options.
pub fn generate_native_with(model: &Model, variant: Variant, opts: GenOpts) -> String {
    assert_eq!(model.kind, ModelKind::RandomForest, "C generation targets RF models");
    model.validate().expect("model must be valid");
    assert_rawbits_thresholds(model, opts);

    let mut out = String::new();
    super::ifelse::header(&mut out, model, variant, "native", opts);

    // Flatten all trees into one node table. Leaf marker: feature == -1,
    // with `left` indexing the leaf-value table.
    let mut feat: Vec<i32> = Vec::new();
    let mut thresh: Vec<String> = Vec::new();
    let mut left: Vec<u32> = Vec::new();
    let mut right: Vec<u32> = Vec::new();
    let mut roots: Vec<u32> = Vec::new();
    let mut leaf_vals: Vec<String> = Vec::new();
    let mut n_leaves = 0u32;

    for tree in &model.trees {
        let base = feat.len() as u32;
        roots.push(base);
        for node in &tree.nodes {
            match node {
                Node::Branch { feature, threshold, left: l, right: r } => {
                    feat.push(*feature as i32);
                    thresh.push(match (variant, opts.encoding) {
                        (Variant::Float, _) => super::f32_lit(*threshold),
                        (_, SplitEncoding::RawBitsNonNegative) => {
                            format!("0x{:08x}u", threshold.to_bits())
                        }
                        (_, SplitEncoding::OrderedUnsigned) => {
                            format!("0x{:08x}u", ordered_u32(*threshold))
                        }
                    });
                    left.push(base + *l);
                    right.push(base + *r);
                }
                Node::Leaf { values } => {
                    feat.push(-1);
                    thresh.push(if variant == Variant::Float { "0.0f".into() } else { "0u".into() });
                    left.push(n_leaves);
                    right.push(0);
                    n_leaves += 1;
                    for &p in values {
                        leaf_vals.push(match variant {
                            Variant::Float | Variant::FlInt => super::f32_lit(p),
                            Variant::IntTreeger => {
                                format!("{}u", prob_to_fixed(p, model.trees.len()))
                            }
                        });
                    }
                }
            }
        }
    }

    let thresh_ty = if variant == Variant::Float { "float" } else { "uint32_t" };
    let acc = acc_type(variant);

    let _ = writeln!(out, "#define N_NODES {}", feat.len());
    let _ = writeln!(out, "static const int32_t it_feat[N_NODES] = {{{}}};", join(&feat));
    let _ = writeln!(out, "static const {thresh_ty} it_thresh[N_NODES] = {{{}}};", thresh.join(","));
    let _ = writeln!(out, "static const uint32_t it_left[N_NODES] = {{{}}};", join(&left));
    let _ = writeln!(out, "static const uint32_t it_right[N_NODES] = {{{}}};", join(&right));
    let _ = writeln!(out, "static const uint32_t it_root[N_TREES] = {{{}}};", join(&roots));
    let _ = writeln!(
        out,
        "static const {acc} it_leaf[{}] = {{{}}};",
        leaf_vals.len(),
        leaf_vals.join(",")
    );
    let _ = writeln!(out);

    let _ = writeln!(out, "void predict(const float *data, {acc} *result) {{");
    if variant != Variant::Float {
        let _ = writeln!(out, "  uint32_t d[N_FEATURES];");
        let loader = match opts.encoding {
            SplitEncoding::OrderedUnsigned => "it_map(it_load_bits(data + i))",
            SplitEncoding::RawBitsNonNegative => "it_load_bits(data + i)",
        };
        let _ = writeln!(out, "  for (int i = 0; i < N_FEATURES; ++i) d[i] = {loader};");
    }
    let zero = if variant == Variant::IntTreeger { "0u" } else { "0.0f" };
    let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] = {zero};");
    let _ = writeln!(out, "  for (int t = 0; t < N_TREES; ++t) {{");
    let _ = writeln!(out, "    uint32_t i = it_root[t];");
    let _ = writeln!(out, "    while (it_feat[i] >= 0) {{");
    let cmp = match (variant, opts.encoding) {
        (Variant::Float, _) => "data[it_feat[i]] <= it_thresh[i]",
        (_, SplitEncoding::RawBitsNonNegative) => {
            "(int32_t)d[it_feat[i]] <= (int32_t)it_thresh[i]"
        }
        (_, SplitEncoding::OrderedUnsigned) => "d[it_feat[i]] <= it_thresh[i]",
    };
    let _ = writeln!(out, "      i = ({cmp}) ? it_left[i] : it_right[i];");
    let _ = writeln!(out, "    }}");
    let _ = writeln!(
        out,
        "    const {acc} *leaf = it_leaf + (size_t)it_left[i] * N_CLASSES;"
    );
    let _ = writeln!(out, "    for (int c = 0; c < N_CLASSES; ++c) result[c] += leaf[c];");
    let _ = writeln!(out, "  }}");
    if variant != Variant::IntTreeger {
        let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] /= (float)N_TREES;");
    }
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);

    harness(&mut out, model, variant);
    out
}

/// Generate predicated child-adjacent native C (default options).
pub fn generate_native_predicated(model: &Model, variant: Variant) -> String {
    generate_native_predicated_with(model, variant, GenOpts::default())
}

/// Generate predicated child-adjacent native C with explicit options.
///
/// The emitted tables mirror the Rust 8-byte node encoding:
/// * `it_ff` — feature index | `0x8000` leaf flag (leaves read feature 0,
///   harmlessly — the descent step is masked by the flag);
/// * `it_tw` — threshold word (float or integer encoding per variant);
/// * `it_left` — **global** left-child index; `right = left + 1` by the
///   child-adjacent layout, so no right table exists; leaves self-loop;
/// * `it_payload` — leaf-value row index (C keeps it in a side table so
///   the float variant's `it_tw` can stay a `float` array);
/// * `it_root` / `it_depth` — per-tree start index and fixed trip count.
///
/// Each tree's walk is `it_depth[t]` iterations of the branch-free step
/// `i = it_left[i] + ((x > it_tw[i]) & is_branch)` — no data-dependent
/// branch anywhere in the loop body.
pub fn generate_native_predicated_with(model: &Model, variant: Variant, opts: GenOpts) -> String {
    assert_eq!(model.kind, ModelKind::RandomForest, "C generation targets RF models");
    model.validate().expect("model must be valid");
    assert_rawbits_thresholds(model, opts);
    assert!(
        model.n_features <= MAX_FEATURES,
        "predicated encoding supports at most {MAX_FEATURES} features"
    );
    // The emitted C mirrors the Rust Node8 bit layout — derive the
    // literals from the shared constants so the two cannot drift.
    let flag_shift = LEAF_BIT.trailing_zeros();

    let mut out = String::new();
    super::ifelse::header(&mut out, model, variant, "native-predicated", opts);

    let mut ff: Vec<u32> = Vec::new();
    let mut tw: Vec<String> = Vec::new();
    let mut left_glob: Vec<u32> = Vec::new();
    let mut payload: Vec<u32> = Vec::new();
    let mut roots: Vec<u32> = Vec::new();
    let mut depths: Vec<u32> = Vec::new();
    let mut leaf_vals: Vec<String> = Vec::new();
    let mut n_leaves = 0u32;

    let leaf_tw = if variant == Variant::Float { "0.0f".to_string() } else { "0u".to_string() };
    // Per-tree scratch SoA in IR order, permuted to BFS child-adjacent.
    let mut feature: Vec<u32> = Vec::new();
    let mut thresh: Vec<String> = Vec::new();
    let mut left: Vec<u32> = Vec::new();
    let mut right: Vec<u32> = Vec::new();
    let mut pay: Vec<u32> = Vec::new();
    for tree in &model.trees {
        let base = ff.len() as u32;
        roots.push(base);
        depths.push(tree.depth() as u32);
        feature.clear();
        thresh.clear();
        left.clear();
        right.clear();
        pay.clear();
        for node in &tree.nodes {
            match node {
                Node::Branch { feature: f, threshold, left: l, right: r } => {
                    feature.push(*f);
                    thresh.push(match (variant, opts.encoding) {
                        (Variant::Float, _) => super::f32_lit(*threshold),
                        (_, SplitEncoding::RawBitsNonNegative) => {
                            format!("0x{:08x}u", threshold.to_bits())
                        }
                        (_, SplitEncoding::OrderedUnsigned) => {
                            format!("0x{:08x}u", ordered_u32(*threshold))
                        }
                    });
                    left.push(*l);
                    right.push(*r);
                    pay.push(0);
                }
                Node::Leaf { values } => {
                    feature.push(LEAF);
                    thresh.push(leaf_tw.clone());
                    left.push(0);
                    right.push(0);
                    pay.push(n_leaves);
                    n_leaves += 1;
                    for &p in values {
                        leaf_vals.push(match variant {
                            Variant::Float | Variant::FlInt => super::f32_lit(p),
                            Variant::IntTreeger => {
                                format!("{}u", prob_to_fixed(p, model.trees.len()))
                            }
                        });
                    }
                }
            }
        }
        let order = child_adjacent_order(&feature, &left, &right, NodeOrder::Breadth);
        let mut new_of = vec![0u32; order.len()];
        for (new, &old) in order.iter().enumerate() {
            new_of[old as usize] = new as u32;
        }
        for (new, &old) in order.iter().enumerate() {
            let i = old as usize;
            if feature[i] == LEAF {
                ff.push(LEAF_BIT as u32);
                tw.push(thresh[i].clone());
                left_glob.push(base + new as u32); // self-loop
                payload.push(pay[i]);
            } else {
                ff.push(feature[i]);
                tw.push(thresh[i].clone());
                left_glob.push(base + new_of[left[i] as usize]);
                payload.push(0);
            }
        }
    }

    let thresh_ty = if variant == Variant::Float { "float" } else { "uint32_t" };
    let acc = acc_type(variant);

    let _ = writeln!(out, "#define N_NODES {}", ff.len());
    let _ = writeln!(out, "static const uint16_t it_ff[N_NODES] = {{{}}};", join(&ff));
    let _ = writeln!(out, "static const {thresh_ty} it_tw[N_NODES] = {{{}}};", tw.join(","));
    let _ = writeln!(out, "static const uint32_t it_left[N_NODES] = {{{}}};", join(&left_glob));
    let _ = writeln!(out, "static const uint32_t it_payload[N_NODES] = {{{}}};", join(&payload));
    let _ = writeln!(out, "static const uint32_t it_root[N_TREES] = {{{}}};", join(&roots));
    let _ = writeln!(out, "static const uint32_t it_depth[N_TREES] = {{{}}};", join(&depths));
    let _ = writeln!(
        out,
        "static const {acc} it_leaf[{}] = {{{}}};",
        leaf_vals.len(),
        leaf_vals.join(",")
    );
    let _ = writeln!(out);

    let _ = writeln!(out, "void predict(const float *data, {acc} *result) {{");
    if variant != Variant::Float {
        let _ = writeln!(out, "  uint32_t d[N_FEATURES];");
        let loader = match opts.encoding {
            SplitEncoding::OrderedUnsigned => "it_map(it_load_bits(data + i))",
            SplitEncoding::RawBitsNonNegative => "it_load_bits(data + i)",
        };
        let _ = writeln!(out, "  for (int i = 0; i < N_FEATURES; ++i) d[i] = {loader};");
    }
    let zero = if variant == Variant::IntTreeger { "0u" } else { "0.0f" };
    let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] = {zero};");
    let _ = writeln!(out, "  for (int t = 0; t < N_TREES; ++t) {{");
    let _ = writeln!(out, "    uint32_t i = it_root[t];");
    let _ = writeln!(out, "    const uint32_t depth = it_depth[t];");
    let x = format!("f & 0x{FEATURE_MASK:04x}u");
    let cmp = match (variant, opts.encoding) {
        // Literal negation of `<=`-goes-left so even NaN inputs route
        // exactly like the ifelse/native layouts (NaN fails both
        // compares; `>` would flip it). Integer domains are total orders.
        (Variant::Float, _) => format!("!(data[{x}] <= it_tw[i])"),
        (_, SplitEncoding::RawBitsNonNegative) => {
            format!("(int32_t)d[{x}] > (int32_t)it_tw[i]")
        }
        (_, SplitEncoding::OrderedUnsigned) => format!("d[{x}] > it_tw[i]"),
    };
    let _ = writeln!(out, "    for (uint32_t s = 0; s < depth; ++s) {{");
    let _ = writeln!(out, "      const uint32_t f = it_ff[i];");
    let _ = writeln!(out, "      /* predicated descent: leaves self-loop (flag masks the step) */");
    let _ = writeln!(out, "      i = it_left[i] + ((({cmp}) ? 1u : 0u) & (1u ^ (f >> {flag_shift})));");
    let _ = writeln!(out, "    }}");
    let _ = writeln!(
        out,
        "    const {acc} *leaf = it_leaf + (size_t)it_payload[i] * N_CLASSES;"
    );
    let _ = writeln!(out, "    for (int c = 0; c < N_CLASSES; ++c) result[c] += leaf[c];");
    let _ = writeln!(out, "  }}");
    if variant != Variant::IntTreeger {
        let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] /= (float)N_TREES;");
    }
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);

    harness(&mut out, model, variant);
    out
}

fn join<T: std::fmt::Display>(xs: &[T]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> Model {
        let ds = shuttle_like(600, 33);
        RandomForest::train(&ds, &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() }, 2)
    }

    #[test]
    fn native_emits_tables() {
        let src = generate_native(&model(), Variant::IntTreeger);
        for t in ["it_feat", "it_thresh", "it_left", "it_right", "it_root", "it_leaf"] {
            assert!(src.contains(t), "missing table {t}");
        }
        assert!(src.contains("while (it_feat[i] >= 0)"));
    }

    #[test]
    fn native_int_is_integer_only() {
        let src = generate_native(&model(), Variant::IntTreeger);
        let inference = src.split("#ifndef INTREEGER_NO_MAIN").next().unwrap();
        assert!(!inference.contains("0x1."), "float literal leaked");
        assert!(!inference.contains("float *result"));
    }

    /// Golden test of the predicated child-adjacent form: a hand-built
    /// deterministic stump pins every emitted table and the fixed-trip
    /// predict loop byte-for-byte (table values via the same pure,
    /// separately-tested transforms).
    #[test]
    fn predicated_golden_stump() {
        use crate::ir::{ModelKind, Tree};
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 2 },
                    Node::Leaf { values: vec![0.9, 0.1] },
                    Node::Leaf { values: vec![0.2, 0.8] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        };
        let src = generate_native_predicated(&m, Variant::IntTreeger);
        let t = ordered_u32(0.5);
        let q = |p: f32| prob_to_fixed(p, 1);
        for line in [
            "#define N_NODES 3".to_string(),
            "static const uint16_t it_ff[N_NODES] = {0,32768,32768};".to_string(),
            format!(
                "static const uint32_t it_tw[N_NODES] = {{0x{t:08x}u,0u,0u}};"
            ),
            "static const uint32_t it_left[N_NODES] = {1,1,2};".to_string(),
            "static const uint32_t it_payload[N_NODES] = {0,0,1};".to_string(),
            "static const uint32_t it_root[N_TREES] = {0};".to_string(),
            "static const uint32_t it_depth[N_TREES] = {1};".to_string(),
            format!(
                "static const uint32_t it_leaf[4] = {{{}u,{}u,{}u,{}u}};",
                q(0.9),
                q(0.1),
                q(0.2),
                q(0.8)
            ),
            "    for (uint32_t s = 0; s < depth; ++s) {".to_string(),
            "      const uint32_t f = it_ff[i];".to_string(),
            "      i = it_left[i] + (((d[f & 0x7fffu] > it_tw[i]) ? 1u : 0u) & (1u ^ (f >> 15)));"
                .to_string(),
            "    const uint32_t *leaf = it_leaf + (size_t)it_payload[i] * N_CLASSES;".to_string(),
        ] {
            assert!(src.contains(&line), "missing golden line:\n{line}\nin:\n{src}");
        }
        // The compact claim: no explicit right-child table anywhere.
        assert!(!src.contains("it_right"), "predicated form must not emit a right table");
    }

    #[test]
    fn predicated_emits_all_variants_and_stays_integer_only_for_int() {
        let m = model();
        for v in [Variant::Float, Variant::FlInt, Variant::IntTreeger] {
            let src = generate_native_predicated(&m, v);
            for t in ["it_ff", "it_tw", "it_left", "it_payload", "it_root", "it_depth", "it_leaf"] {
                assert!(src.contains(t), "{}: missing table {t}", v.name());
            }
            assert!(!src.contains("it_right"), "{}: right table leaked", v.name());
            assert!(src.contains("layout: native-predicated"), "{}", v.name());
        }
        let src = generate_native_predicated(&m, Variant::IntTreeger);
        let inference = src.split("#ifndef INTREEGER_NO_MAIN").next().unwrap();
        assert!(!inference.contains("0x1."), "float literal leaked");
        assert!(!inference.contains("float *result"));
    }

    #[test]
    fn predicated_rawbits_requires_nonneg_thresholds() {
        let mut m = model();
        for node in &mut m.trees[0].nodes {
            if let Node::Branch { threshold, .. } = node {
                *threshold = -1.0;
                break;
            }
        }
        let opts = GenOpts { encoding: SplitEncoding::RawBitsNonNegative, ..Default::default() };
        let r = std::panic::catch_unwind(|| {
            generate_native_predicated_with(&m, Variant::IntTreeger, opts)
        });
        assert!(r.is_err(), "negative threshold must be rejected under raw-bits");
    }

    /// End-to-end: the predicated C binary is bit-identical to the
    /// branchy native form and to the Rust engines (gcc-gated).
    #[test]
    fn predicated_c_matches_engines() {
        use crate::codegen::compile::{gcc_available, CBinary};
        use crate::inference::IntEngine;
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let ds = shuttle_like(1000, 35);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() },
            7,
        );
        let engine = IntEngine::compile(&m);
        let src = generate_native_predicated(&m, Variant::IntTreeger);
        let bin = CBinary::compile(&src, Variant::IntTreeger, m.n_features, m.n_classes, "natpred")
            .expect("compile predicated C");
        let n = 200usize;
        let rows = &ds.features[..n * ds.n_features];
        let got = bin.predict_u32(rows).expect("run predicated C");
        for i in 0..n {
            assert_eq!(got[i], engine.predict_fixed(ds.row(i)), "row {i}");
        }
    }

    #[test]
    fn native_much_smaller_than_ifelse_for_big_models() {
        let ds = shuttle_like(4000, 34);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 20, max_depth: 8, ..Default::default() },
            3,
        );
        let ifelse = crate::codegen::generate_ifelse(&m, Variant::IntTreeger);
        let native = generate_native(&m, Variant::IntTreeger);
        assert!(native.len() < ifelse.len());
    }
}
