//! Native-tree code generation: the forest as constant node arrays walked
//! by a loop (Asadi et al.'s "native" layout, §II-B) — the layout-ablation
//! counterpart to [`super::ifelse`]. Much smaller `.text`, larger
//! `.rodata`; the paper argues if-else trees suit RAM-limited
//! microcontrollers better, which bench `layout_ablation` quantifies.

use super::ifelse::{acc_type, harness, GenOpts};
use crate::flint::{ordered_u32, SplitEncoding};
use crate::inference::Variant;
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;
use std::fmt::Write;

/// Generate native-layout C for a model (default options).
pub fn generate_native(model: &Model, variant: Variant) -> String {
    generate_native_with(model, variant, GenOpts::default())
}

/// Generate native-layout C with explicit options.
pub fn generate_native_with(model: &Model, variant: Variant, opts: GenOpts) -> String {
    assert_eq!(model.kind, ModelKind::RandomForest, "C generation targets RF models");
    model.validate().expect("model must be valid");

    let mut out = String::new();
    super::ifelse::header(&mut out, model, variant, "native", opts);

    // Flatten all trees into one node table. Leaf marker: feature == -1,
    // with `left` indexing the leaf-value table.
    let mut feat: Vec<i32> = Vec::new();
    let mut thresh: Vec<String> = Vec::new();
    let mut left: Vec<u32> = Vec::new();
    let mut right: Vec<u32> = Vec::new();
    let mut roots: Vec<u32> = Vec::new();
    let mut leaf_vals: Vec<String> = Vec::new();
    let mut n_leaves = 0u32;

    for tree in &model.trees {
        let base = feat.len() as u32;
        roots.push(base);
        for node in &tree.nodes {
            match node {
                Node::Branch { feature, threshold, left: l, right: r } => {
                    feat.push(*feature as i32);
                    thresh.push(match (variant, opts.encoding) {
                        (Variant::Float, _) => super::f32_lit(*threshold),
                        (_, SplitEncoding::RawBitsNonNegative) => {
                            format!("0x{:08x}u", threshold.to_bits())
                        }
                        (_, SplitEncoding::OrderedUnsigned) => {
                            format!("0x{:08x}u", ordered_u32(*threshold))
                        }
                    });
                    left.push(base + *l);
                    right.push(base + *r);
                }
                Node::Leaf { values } => {
                    feat.push(-1);
                    thresh.push(if variant == Variant::Float { "0.0f".into() } else { "0u".into() });
                    left.push(n_leaves);
                    right.push(0);
                    n_leaves += 1;
                    for &p in values {
                        leaf_vals.push(match variant {
                            Variant::Float | Variant::FlInt => super::f32_lit(p),
                            Variant::IntTreeger => {
                                format!("{}u", prob_to_fixed(p, model.trees.len()))
                            }
                        });
                    }
                }
            }
        }
    }

    let thresh_ty = if variant == Variant::Float { "float" } else { "uint32_t" };
    let acc = acc_type(variant);

    let _ = writeln!(out, "#define N_NODES {}", feat.len());
    let _ = writeln!(out, "static const int32_t it_feat[N_NODES] = {{{}}};", join(&feat));
    let _ = writeln!(out, "static const {thresh_ty} it_thresh[N_NODES] = {{{}}};", thresh.join(","));
    let _ = writeln!(out, "static const uint32_t it_left[N_NODES] = {{{}}};", join(&left));
    let _ = writeln!(out, "static const uint32_t it_right[N_NODES] = {{{}}};", join(&right));
    let _ = writeln!(out, "static const uint32_t it_root[N_TREES] = {{{}}};", join(&roots));
    let _ = writeln!(
        out,
        "static const {acc} it_leaf[{}] = {{{}}};",
        leaf_vals.len(),
        leaf_vals.join(",")
    );
    let _ = writeln!(out);

    let _ = writeln!(out, "void predict(const float *data, {acc} *result) {{");
    if variant != Variant::Float {
        let _ = writeln!(out, "  uint32_t d[N_FEATURES];");
        let loader = match opts.encoding {
            SplitEncoding::OrderedUnsigned => "it_map(it_load_bits(data + i))",
            SplitEncoding::RawBitsNonNegative => "it_load_bits(data + i)",
        };
        let _ = writeln!(out, "  for (int i = 0; i < N_FEATURES; ++i) d[i] = {loader};");
    }
    let zero = if variant == Variant::IntTreeger { "0u" } else { "0.0f" };
    let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] = {zero};");
    let _ = writeln!(out, "  for (int t = 0; t < N_TREES; ++t) {{");
    let _ = writeln!(out, "    uint32_t i = it_root[t];");
    let _ = writeln!(out, "    while (it_feat[i] >= 0) {{");
    let cmp = match (variant, opts.encoding) {
        (Variant::Float, _) => "data[it_feat[i]] <= it_thresh[i]",
        (_, SplitEncoding::RawBitsNonNegative) => {
            "(int32_t)d[it_feat[i]] <= (int32_t)it_thresh[i]"
        }
        (_, SplitEncoding::OrderedUnsigned) => "d[it_feat[i]] <= it_thresh[i]",
    };
    let _ = writeln!(out, "      i = ({cmp}) ? it_left[i] : it_right[i];");
    let _ = writeln!(out, "    }}");
    let _ = writeln!(
        out,
        "    const {acc} *leaf = it_leaf + (size_t)it_left[i] * N_CLASSES;"
    );
    let _ = writeln!(out, "    for (int c = 0; c < N_CLASSES; ++c) result[c] += leaf[c];");
    let _ = writeln!(out, "  }}");
    if variant != Variant::IntTreeger {
        let _ = writeln!(out, "  for (int c = 0; c < N_CLASSES; ++c) result[c] /= (float)N_TREES;");
    }
    let _ = writeln!(out, "}}");
    let _ = writeln!(out);

    harness(&mut out, model, variant);
    out
}

fn join<T: std::fmt::Display>(xs: &[T]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> Model {
        let ds = shuttle_like(600, 33);
        RandomForest::train(&ds, &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() }, 2)
    }

    #[test]
    fn native_emits_tables() {
        let src = generate_native(&model(), Variant::IntTreeger);
        for t in ["it_feat", "it_thresh", "it_left", "it_right", "it_root", "it_leaf"] {
            assert!(src.contains(t), "missing table {t}");
        }
        assert!(src.contains("while (it_feat[i] >= 0)"));
    }

    #[test]
    fn native_int_is_integer_only() {
        let src = generate_native(&model(), Variant::IntTreeger);
        let inference = src.split("#ifndef INTREEGER_NO_MAIN").next().unwrap();
        assert!(!inference.contains("0x1."), "float literal leaked");
        assert!(!inference.contains("float *result"));
    }

    #[test]
    fn native_much_smaller_than_ifelse_for_big_models() {
        let ds = shuttle_like(4000, 34);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 20, max_depth: 8, ..Default::default() },
            3,
        );
        let ifelse = crate::codegen::generate_ifelse(&m, Variant::IntTreeger);
        let native = generate_native(&m, Variant::IntTreeger);
        assert!(native.len() < ifelse.len());
    }
}
