//! gcc compile-and-run harness for generated C.
//!
//! On this x86 host the generated code is *actually compiled and
//! executed* (with `-O3`, as in the paper's §IV methodology), providing
//! (a) end-to-end parity checks of the generated artifact against the
//! reference engines and (b) real x86 performance measurements for the
//! Fig 3 x86 column.

use crate::inference::Variant;
use std::io::Write as _;
use std::path::PathBuf;
use std::process::{Command, Stdio};

/// Compile/run failure.
#[derive(Debug)]
pub enum CompileError {
    /// Filesystem/process I/O failure.
    Io(std::io::Error),
    /// gcc exited non-zero.
    Gcc {
        /// gcc's exit code, if any.
        status: Option<i32>,
        /// gcc's stderr.
        stderr: String,
    },
    /// The compiled binary exited non-zero.
    Run {
        /// The binary's exit code, if any.
        status: Option<i32>,
        /// The binary's stderr.
        stderr: String,
    },
    /// The binary's output did not match the expected wire format.
    Protocol(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Io(e) => write!(f, "io: {e}"),
            CompileError::Gcc { status, stderr } => write!(f, "gcc failed ({status:?}): {stderr}"),
            CompileError::Run { status, stderr } => {
                write!(f, "binary failed ({status:?}): {stderr}")
            }
            CompileError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<std::io::Error> for CompileError {
    fn from(e: std::io::Error) -> Self {
        CompileError::Io(e)
    }
}

/// A compiled generated-C binary.
pub struct CBinary {
    path: PathBuf,
    n_features: usize,
    n_classes: usize,
    variant: Variant,
    /// Size of the stripped binary's .text section (bytes), if computed.
    pub text_size: Option<u64>,
}

/// True when a C compiler is available on this host.
pub fn gcc_available() -> bool {
    Command::new("gcc").arg("--version").stdout(Stdio::null()).stderr(Stdio::null()).status().map(|s| s.success()).unwrap_or(false)
}

impl CBinary {
    /// Compile `source` with gcc -O3 into a unique temp binary.
    pub fn compile(
        source: &str,
        variant: Variant,
        n_features: usize,
        n_classes: usize,
        tag: &str,
    ) -> Result<CBinary, CompileError> {
        let dir = std::env::temp_dir().join("intreeger_cc");
        std::fs::create_dir_all(&dir)?;
        let id = format!(
            "{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
        );
        let c_path = dir.join(format!("{id}.c"));
        let bin_path = dir.join(id);
        std::fs::write(&c_path, source)?;
        let out = Command::new("gcc")
            .args(["-O3", "-std=gnu11", "-o"])
            .arg(&bin_path)
            .arg(&c_path)
            .output()?;
        if !out.status.success() {
            return Err(CompileError::Gcc {
                status: out.status.code(),
                stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
            });
        }
        let text_size = text_section_size(&bin_path);
        Ok(CBinary { path: bin_path, n_features, n_classes, variant, text_size })
    }

    fn run_mode(&self, mode: &str, rows: &[f32], extra: &[String]) -> Result<Vec<u8>, CompileError> {
        let n = rows.len() / self.n_features;
        let mut cmd = Command::new(&self.path);
        cmd.arg(mode).arg(n.to_string()).args(extra);
        cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = cmd.spawn()?;
        {
            let stdin = child.stdin.as_mut().unwrap();
            let bytes: Vec<u8> = rows.iter().flat_map(|v| v.to_le_bytes()).collect();
            stdin.write_all(&bytes)?;
        }
        let out = child.wait_with_output()?;
        if !out.status.success() {
            return Err(CompileError::Run {
                status: out.status.code(),
                stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
            });
        }
        Ok(out.stdout)
    }

    /// Run `predict` over rows (`rows.len()` must be a multiple of
    /// `n_features`), returning per-row f32 outputs. For the integer
    /// variant the raw u32 outputs are widened via their probability
    /// interpretation is NOT applied — use [`Self::predict_u32`].
    pub fn predict_f32(&self, rows: &[f32]) -> Result<Vec<Vec<f32>>, CompileError> {
        assert_ne!(self.variant, Variant::IntTreeger, "use predict_u32 for the int variant");
        let raw = self.run_mode("predict", rows, &[])?;
        let n = rows.len() / self.n_features;
        let want = n * self.n_classes * 4;
        if raw.len() != want {
            return Err(CompileError::Protocol(format!("expected {want} bytes, got {}", raw.len())));
        }
        Ok((0..n)
            .map(|i| {
                (0..self.n_classes)
                    .map(|c| {
                        let o = (i * self.n_classes + c) * 4;
                        f32::from_le_bytes(raw[o..o + 4].try_into().unwrap())
                    })
                    .collect()
            })
            .collect())
    }

    /// Run `predict` for the integer variant, returning u32 fixed-point
    /// accumulator vectors.
    pub fn predict_u32(&self, rows: &[f32]) -> Result<Vec<Vec<u32>>, CompileError> {
        assert_eq!(self.variant, Variant::IntTreeger);
        let raw = self.run_mode("predict", rows, &[])?;
        let n = rows.len() / self.n_features;
        let want = n * self.n_classes * 4;
        if raw.len() != want {
            return Err(CompileError::Protocol(format!("expected {want} bytes, got {}", raw.len())));
        }
        Ok((0..n)
            .map(|i| {
                (0..self.n_classes)
                    .map(|c| {
                        let o = (i * self.n_classes + c) * 4;
                        u32::from_le_bytes(raw[o..o + 4].try_into().unwrap())
                    })
                    .collect()
            })
            .collect())
    }

    /// Run the `bench` mode: time `reps` passes over the rows inside the
    /// C process and return nanoseconds per inference.
    pub fn bench_ns(&self, rows: &[f32], reps: usize) -> Result<f64, CompileError> {
        let raw = self.run_mode("bench", rows, &[reps.to_string()])?;
        let text = String::from_utf8_lossy(&raw);
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("ns_per_inference ") {
                return rest
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| CompileError::Protocol(format!("bad ns value: {e}")));
            }
        }
        Err(CompileError::Protocol(format!("no ns_per_inference in output: {text}")))
    }

    /// The numeric variant this binary was generated for.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Path of the compiled binary on disk.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for CBinary {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
        let _ = std::fs::remove_file(self.path.with_extension("c"));
    }
}

/// Parse `size`-style .text section size of a binary (returns None if the
/// `size` tool is unavailable).
fn text_section_size(path: &std::path::Path) -> Option<u64> {
    let out = Command::new("size").arg(path).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8_lossy(&out.stdout);
    // format:   text    data     bss     dec     hex filename
    let line = text.lines().nth(1)?;
    line.split_whitespace().next()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{generate, Layout};
    use crate::data::shuttle_like;
    use crate::inference::{Engine, FloatEngine, IntEngine};
    use crate::trees::{ForestParams, RandomForest};

    fn setup() -> (crate::data::Dataset, crate::ir::Model) {
        let ds = shuttle_like(1200, 41);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            4,
        );
        (ds, m)
    }

    fn rows_of(ds: &crate::data::Dataset, n: usize) -> Vec<f32> {
        ds.features[..n * ds.n_features].to_vec()
    }

    #[test]
    fn generated_float_c_matches_float_engine() {
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let (ds, m) = setup();
        let src = generate(&m, Layout::IfElse, Variant::Float);
        let bin = CBinary::compile(&src, Variant::Float, ds.n_features, ds.n_classes, "t_float")
            .expect("compile");
        let rows = rows_of(&ds, 64);
        let got = bin.predict_f32(&rows).expect("run");
        let engine = FloatEngine::compile(&m);
        for (i, probs) in got.iter().enumerate() {
            let want = engine.predict_proba(&rows[i * ds.n_features..(i + 1) * ds.n_features]);
            for (a, b) in probs.iter().zip(&want) {
                // The C code accumulates in the same order; results should
                // agree to the last ulp or two (gcc may fuse differently).
                assert!((a - b).abs() <= 2.0 * f32::EPSILON * 8.0, "row {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn generated_int_c_matches_int_engine_exactly() {
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let (ds, m) = setup();
        let src = generate(&m, Layout::IfElse, Variant::IntTreeger);
        let bin = CBinary::compile(&src, Variant::IntTreeger, ds.n_features, ds.n_classes, "t_int")
            .expect("compile");
        let rows = rows_of(&ds, 64);
        let got = bin.predict_u32(&rows).expect("run");
        let engine = IntEngine::compile(&m);
        for (i, fixed) in got.iter().enumerate() {
            let want = engine.predict_fixed(&rows[i * ds.n_features..(i + 1) * ds.n_features]);
            assert_eq!(fixed, &want, "row {i}: integer outputs must be bit-identical");
        }
    }

    #[test]
    fn native_layout_matches_ifelse_exactly() {
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let (ds, m) = setup();
        let a = CBinary::compile(
            &generate(&m, Layout::IfElse, Variant::IntTreeger),
            Variant::IntTreeger,
            ds.n_features,
            ds.n_classes,
            "t_ie",
        )
        .unwrap();
        let b = CBinary::compile(
            &generate(&m, Layout::Native, Variant::IntTreeger),
            Variant::IntTreeger,
            ds.n_features,
            ds.n_classes,
            "t_nat",
        )
        .unwrap();
        let rows = rows_of(&ds, 32);
        assert_eq!(a.predict_u32(&rows).unwrap(), b.predict_u32(&rows).unwrap());
    }

    #[test]
    fn bench_mode_returns_positive_ns() {
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let (ds, m) = setup();
        let src = generate(&m, Layout::IfElse, Variant::IntTreeger);
        let bin = CBinary::compile(&src, Variant::IntTreeger, ds.n_features, ds.n_classes, "t_b")
            .unwrap();
        let rows = rows_of(&ds, 128);
        let ns = bin.bench_ns(&rows, 50).expect("bench");
        assert!(ns > 0.0 && ns < 1e7, "ns = {ns}");
    }

    #[test]
    fn text_size_reported() {
        if !gcc_available() {
            eprintln!("gcc unavailable; skipping");
            return;
        }
        let (ds, m) = setup();
        let src = generate(&m, Layout::IfElse, Variant::IntTreeger);
        let bin =
            CBinary::compile(&src, Variant::IntTreeger, ds.n_features, ds.n_classes, "t_sz").unwrap();
        if let Some(sz) = bin.text_size {
            assert!(sz > 1000, "text {sz}");
        }
    }
}
