//! Energy model (§IV-F) — the Joulescope-JS220-on-a-Raspberry-Pi
//! substitute.
//!
//! The paper's §IV-F result is *derived from runtimes*: both
//! implementations draw the same load power (2.81 W measured; the
//! difference was "not statistically significant"), so the saving comes
//! purely from the integer version finishing earlier and the device
//! dropping back to baseline power (1.81–1.82 W) for the remainder:
//!
//! ```text
//! E_saved = 1 - (T_int·P_high + (T_float − T_int)·P_low) / (T_float·P_high)
//! ```
//!
//! This module implements that formula, the measurement methodology
//! (baseline with periodic background bumps — Fig 5a — plus flat-top load
//! windows, Fig 5b/c), and a synthetic trace generator so the Fig 5
//! power-profile plots can be regenerated without the instrument.

use crate::util::Rng;

/// Power model parameters (defaults = the paper's measured values).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Idle power floor (W). Paper: ~1.67 W.
    pub idle_w: f64,
    /// Average baseline incl. periodic background work (W). Paper: ~1.82.
    pub baseline_avg_w: f64,
    /// Power while running an inference workload (W). Paper: 2.81, for
    /// both float and integer implementations.
    pub load_w: f64,
    /// Period of the background-process bump (s). Fig 5a shows a ~2 s
    /// periodic riser to just under 2 W.
    pub background_period_s: f64,
    /// Peak power of the periodic background bump (W).
    pub background_peak_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            idle_w: 1.67,
            baseline_avg_w: 1.82,
            load_w: 2.81,
            background_period_s: 2.0,
            background_peak_w: 1.98,
        }
    }
}

/// The paper's E_saved formula (§IV-F). `t_int`/`t_float` are runtimes in
/// seconds for the same workload; `p_high` the load power; `p_low` the
/// baseline power.
pub fn e_saved(t_int: f64, t_float: f64, p_high: f64, p_low: f64) -> f64 {
    assert!(t_int > 0.0 && t_float > 0.0 && p_high > 0.0 && p_low >= 0.0);
    1.0 - (t_int * p_high + (t_float - t_int) * p_low) / (t_float * p_high)
}

/// Energy (J) consumed running a workload for `t` seconds at load power,
/// then idling at baseline for `t_total - t` (equal-time comparison).
pub fn energy_equal_time(t_run: f64, t_total: f64, m: &PowerModel) -> f64 {
    assert!(t_total >= t_run);
    t_run * m.load_w + (t_total - t_run) * m.baseline_avg_w
}

/// One sample of a synthetic power trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    /// Time since trace start (s).
    pub t_s: f64,
    /// Instantaneous power (W).
    pub power_w: f64,
}

/// Synthesize a Fig 5-style power trace: `pre_s` of baseline, `run_s` of
/// load, `post_s` of baseline, sampled at `hz` with small measurement
/// noise. Deterministic in `seed`.
pub fn synth_trace(m: &PowerModel, pre_s: f64, run_s: f64, post_s: f64, hz: f64, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    let total = pre_s + run_s + post_s;
    let n = (total * hz) as usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = i as f64 / hz;
        let in_load = t >= pre_s && t < pre_s + run_s;
        let mut p = if in_load { m.load_w } else { m.idle_w };
        if !in_load {
            // periodic background process (Fig 5a)
            let phase = (t / m.background_period_s).fract();
            if phase < 0.18 {
                p = m.background_peak_w;
            }
        }
        p += rng.gauss() * 0.012; // instrument noise (JS220 is precise)
        out.push(Sample { t_s: t, power_w: p });
    }
    out
}

/// Mean power over a trace window `[t0, t1)`.
pub fn mean_power(trace: &[Sample], t0: f64, t1: f64) -> f64 {
    let vals: Vec<f64> =
        trace.iter().filter(|s| s.t_s >= t0 && s.t_s < t1).map(|s| s.power_w).collect();
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

/// Integrated energy (J) of a trace via trapezoid-free rectangle sum.
pub fn trace_energy(trace: &[Sample], hz: f64) -> f64 {
    trace.iter().map(|s| s.power_w / hz).sum()
}

/// Full §IV-F experiment result.
#[derive(Clone, Copy, Debug)]
pub struct EnergyResult {
    /// Float-implementation runtime (s).
    pub t_float_s: f64,
    /// Integer-implementation runtime (s).
    pub t_int_s: f64,
    /// Load power while running (W).
    pub p_high_w: f64,
    /// Baseline power while idle (W).
    pub p_low_w: f64,
    /// Fractional energy saving (the paper's E_saved formula).
    pub e_saved: f64,
    /// Energy of the float run alone (J).
    pub e_float_j: f64,
    /// Energy of the integer run over the same wall-clock window (J).
    pub e_int_j: f64,
}

/// Evaluate the experiment from two measured runtimes.
pub fn evaluate(t_float_s: f64, t_int_s: f64, m: &PowerModel) -> EnergyResult {
    EnergyResult {
        t_float_s,
        t_int_s,
        p_high_w: m.load_w,
        p_low_w: m.baseline_avg_w,
        e_saved: e_saved(t_int_s, t_float_s, m.load_w, m.baseline_avg_w),
        e_float_j: t_float_s * m.load_w,
        e_int_j: energy_equal_time(t_int_s, t_float_s, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's worked numbers: T_float = 19.36 s, T_int = 7.79 s,
    /// P_high = 2.81 W, P_low = 1.81 W ⇒ E_saved ≈ 21.3 %.
    #[test]
    fn paper_worked_example() {
        let e = e_saved(7.79, 19.36, 2.81, 1.81);
        assert!((e - 0.213).abs() < 0.005, "E_saved = {e}");
    }

    #[test]
    fn equal_runtimes_save_nothing() {
        assert!(e_saved(5.0, 5.0, 2.81, 1.81).abs() < 1e-12);
    }

    #[test]
    fn zero_baseline_gives_runtime_ratio() {
        // With P_low = 0, saving = 1 - T_int/T_float (the paper's "closer
        // to 50%" optimized-environment scenario).
        let e = e_saved(7.79, 19.36, 2.81, 0.0);
        assert!((e - (1.0 - 7.79 / 19.36)).abs() < 1e-12);
    }

    #[test]
    fn evaluate_consistent() {
        let r = evaluate(19.36, 7.79, &PowerModel::default());
        assert!((r.e_saved - (1.0 - r.e_int_j / r.e_float_j)).abs() < 1e-9);
        assert!(r.e_saved > 0.19 && r.e_saved < 0.24);
    }

    #[test]
    fn trace_windows_match_model() {
        let m = PowerModel::default();
        let tr = synth_trace(&m, 5.0, 10.0, 5.0, 1000.0, 1);
        let base = mean_power(&tr, 0.0, 5.0);
        let load = mean_power(&tr, 5.5, 14.5);
        // Baseline average should land between idle and peak, near 1.7–1.9.
        assert!(base > m.idle_w - 0.05 && base < m.background_peak_w, "base {base}");
        assert!((load - m.load_w).abs() < 0.02, "load {load}");
    }

    #[test]
    fn trace_energy_positive_and_consistent() {
        let m = PowerModel::default();
        let tr = synth_trace(&m, 1.0, 2.0, 1.0, 500.0, 2);
        let e = trace_energy(&tr, 500.0);
        // rough bound: 4 s between idle and load power
        assert!(e > 4.0 * m.idle_w * 0.9 && e < 4.0 * m.load_w * 1.1, "E = {e}");
    }

    #[test]
    fn trace_deterministic() {
        let m = PowerModel::default();
        assert_eq!(synth_trace(&m, 1.0, 1.0, 1.0, 100.0, 7), synth_trace(&m, 1.0, 1.0, 1.0, 100.0, 7));
    }
}
