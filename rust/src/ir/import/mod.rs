//! Front-end importers: external training frameworks → the common IR.
//!
//! The paper's pipeline (Fig 1) accepts models from "a Python RF training
//! library of choice, such as XGBoost, LightGBM, and scikit-learn" via
//! Treelite. This module is that ingestion layer: each importer parses
//! the framework's native dump format into [`crate::ir::Model`], after
//! which every backend (codegen, engines, simulators, XLA packer) works
//! unchanged.
//!
//! * [`xgboost`] — XGBoost's JSON dump (`Booster.get_dump(dump_format=
//!   "json")`), `<`-style splits converted to our `<=` convention by
//!   taking the f32 predecessor of each threshold.
//! * [`lightgbm`] — LightGBM's text model format (`Booster.save_model`),
//!   columnar per-tree arrays with `~leaf`-encoded children.
//!
//! scikit-learn needs no importer here: the in-crate trainer
//! ([`crate::trees`]) implements the same CART/RF semantics natively.

pub mod lightgbm;
pub mod xgboost;

use crate::flint::{ordered_u32, ordered_u32_inv};

/// Largest f32 strictly below `t` under total order — converts a
/// `x < t` split into our `x <= pred(t)` convention exactly (both sides
/// classify every finite f32 identically).
pub fn f32_pred(t: f32) -> f32 {
    assert!(t.is_finite(), "threshold must be finite");
    let mut o = ordered_u32(t);
    // Stepping once suffices except at t == ±0.0, where the ordered
    // domain's inverse lands on -0.0 (numerically equal to t); step again.
    loop {
        assert!(o > 0, "no predecessor below -f32::MAX");
        o -= 1;
        let p = ordered_u32_inv(o);
        if p < t {
            return p;
        }
    }
}

/// Import error type shared by the front-ends.
#[derive(Debug)]
pub struct ImportError(
    /// Human-readable cause.
    pub String,
);

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model import error: {}", self.0)
    }
}
impl std::error::Error for ImportError {}

pub(crate) fn err<T>(msg: impl Into<String>) -> Result<T, ImportError> {
    Err(ImportError(msg.into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, finite_f32};
    use crate::prop_ensure;

    #[test]
    fn pred_is_strictly_below_and_adjacent() {
        for &t in &[1.5f32, 87.5, -3.0, 1e-30, f32::MAX, -0.0] {
            let p = f32_pred(t);
            assert!(p < t || (t == 0.0 && p < 0.0), "{p} !< {t}");
        }
    }

    /// The defining property: for all finite x, `x < t ⇔ x <= pred(t)`.
    #[test]
    fn prop_pred_converts_lt_to_le() {
        check(
            "pred_converts_lt_to_le",
            |r| (finite_f32(r), finite_f32(r)),
            |&(x, t)| {
                if ordered_u32(t) == 0 {
                    return Ok(()); // -MAX has no predecessor; importers reject
                }
                let p = f32_pred(t);
                prop_ensure!((x < t) == (x <= p), "x={x} t={t} pred={p}");
                Ok(())
            },
        );
    }
}
