//! LightGBM front-end: parse the text model format written by
//! `Booster.save_model()` into the IR.
//!
//! The format is a sequence of `key=value` blocks; the header carries
//! `num_class`/`max_feature_idx`, then one block per tree:
//!
//! ```text
//! Tree=0
//! num_leaves=3
//! split_feature=0 1
//! threshold=0.5 -1.25
//! decision_type=2 2
//! left_child=1 -1
//! right_child=-2 -3
//! leaf_value=0.1 -0.2 0.3
//! ```
//!
//! Internal nodes are indexed positively, leaves as `~leaf_index`
//! (negative: `-1` = leaf 0, `-2` = leaf 1, ...). `decision_type=2` is
//! the numerical `<=` split — the same convention as our IR, so
//! thresholds import verbatim (no predecessor trick needed).

use super::{err, ImportError};
use crate::ir::{Model, ModelKind, Node, Tree, MAX_CLASSES, MAX_FEATURES, MAX_TREES};
use std::collections::HashMap;

/// Import a LightGBM text model.
pub fn import(text: &str) -> Result<Model, ImportError> {
    let mut header: HashMap<&str, &str> = HashMap::new();
    let mut tree_blocks: Vec<HashMap<&str, &str>> = Vec::new();
    let mut current: Option<HashMap<&str, &str>> = None;

    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some((k, v)) = line.split_once('=') {
            if k == "Tree" {
                if let Some(block) = current.take() {
                    tree_blocks.push(block);
                }
                current = Some(HashMap::new());
                let _ = v;
            } else if let Some(block) = current.as_mut() {
                block.insert(k, v);
            } else {
                header.insert(k, v);
            }
        } else if line == "end of trees" {
            if let Some(block) = current.take() {
                tree_blocks.push(block);
            }
        }
    }
    if let Some(block) = current.take() {
        tree_blocks.push(block);
    }
    if tree_blocks.is_empty() {
        return err("no Tree blocks found");
    }

    let num_class: usize = header.get("num_class").and_then(|v| v.parse().ok()).unwrap_or(1);
    let n_classes = if num_class <= 1 { 2 } else { num_class };
    let n_features: usize = header
        .get("max_feature_idx")
        .and_then(|v| v.parse::<usize>().ok())
        .map(|m| m + 1)
        .ok_or_else(|| ImportError("missing max_feature_idx".into()))?;
    // Header-declared sizes drive allocations below (every leaf vector is
    // n_classes long) — bound them before trusting them.
    if n_features > MAX_FEATURES {
        return err(format!("max_feature_idx implies {n_features} features (limit {MAX_FEATURES})"));
    }
    if n_classes > MAX_CLASSES {
        return err(format!("num_class {num_class} exceeds limit {MAX_CLASSES}"));
    }
    if tree_blocks.len() > MAX_TREES {
        return err(format!("{} trees exceeds limit {MAX_TREES}", tree_blocks.len()));
    }
    let round_robin = if num_class <= 1 { 1 } else { num_class };
    if tree_blocks.len() % round_robin != 0 {
        return err(format!(
            "tree count {} not a multiple of num_class {num_class}",
            tree_blocks.len()
        ));
    }

    let mut trees = Vec::with_capacity(tree_blocks.len());
    for (ti, block) in tree_blocks.iter().enumerate() {
        let class = if round_robin == 1 { 1 } else { ti % n_classes };
        trees.push(parse_tree(block, ti, n_features, n_classes, class)?);
    }

    let model = Model {
        kind: ModelKind::Gbt,
        n_features,
        n_classes,
        trees,
        base_score: vec![0.0; n_classes],
    };
    model.validate().map_err(|e| ImportError(format!("imported model invalid: {e}")))?;
    Ok(model)
}

fn floats(block: &HashMap<&str, &str>, key: &str, ti: usize) -> Result<Vec<f64>, ImportError> {
    block
        .get(key)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing '{key}'")))?
        .split_whitespace()
        .map(|s| s.parse::<f64>().map_err(|e| ImportError(format!("tree {ti} {key}: {e}"))))
        .collect()
}

fn ints(block: &HashMap<&str, &str>, key: &str, ti: usize) -> Result<Vec<i64>, ImportError> {
    block
        .get(key)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing '{key}'")))?
        .split_whitespace()
        .map(|s| s.parse::<i64>().map_err(|e| ImportError(format!("tree {ti} {key}: {e}"))))
        .collect()
}

fn parse_tree(
    block: &HashMap<&str, &str>,
    ti: usize,
    n_features: usize,
    n_classes: usize,
    class: usize,
) -> Result<Tree, ImportError> {
    let leaf_value = floats(block, "leaf_value", ti)?;
    let num_leaves = leaf_value.len();

    // Single-leaf trees (constant) have no split arrays.
    if num_leaves == 1 {
        let mut values = vec![0.0f32; n_classes];
        values[class] = leaf_value[0] as f32;
        return Ok(Tree { nodes: vec![Node::Leaf { values }] });
    }

    let split_feature = ints(block, "split_feature", ti)?;
    let threshold = floats(block, "threshold", ti)?;
    let left_child = ints(block, "left_child", ti)?;
    let right_child = ints(block, "right_child", ti)?;
    let n_internal = split_feature.len();
    if threshold.len() != n_internal || left_child.len() != n_internal || right_child.len() != n_internal {
        return err(format!("tree {ti}: ragged split arrays"));
    }
    if n_internal + 1 != num_leaves {
        return err(format!(
            "tree {ti}: {n_internal} internal nodes but {num_leaves} leaves"
        ));
    }
    if let Some(dt) = block.get("decision_type") {
        if dt.split_whitespace().any(|d| d != "2") {
            return err(format!("tree {ti}: only numerical (<=) decision_type=2 supported"));
        }
    }

    // Rebuild as a flat IR tree, internal node 0 = root.
    let mut nodes: Vec<Node> = Vec::new();
    build(
        0,
        &mut nodes,
        &split_feature,
        &threshold,
        &left_child,
        &right_child,
        &leaf_value,
        n_features,
        n_classes,
        class,
        ti,
        0,
    )?;
    Ok(Tree { nodes })
}

#[allow(clippy::too_many_arguments)]
fn build(
    idx: i64,
    nodes: &mut Vec<Node>,
    split_feature: &[i64],
    threshold: &[f64],
    left_child: &[i64],
    right_child: &[i64],
    leaf_value: &[f64],
    n_features: usize,
    n_classes: usize,
    class: usize,
    ti: usize,
    depth: usize,
) -> Result<u32, ImportError> {
    if depth > 512 {
        return err(format!("tree {ti}: cycle or depth > 512"));
    }
    let id = nodes.len() as u32;
    if idx < 0 {
        let li = (!idx) as usize; // ~leaf
        let v = *leaf_value
            .get(li)
            .ok_or_else(|| ImportError(format!("tree {ti}: leaf {li} out of range")))?;
        let mut values = vec![0.0f32; n_classes];
        values[class] = v as f32;
        nodes.push(Node::Leaf { values });
        return Ok(id);
    }
    let i = idx as usize;
    if i >= split_feature.len() {
        return err(format!("tree {ti}: internal node {i} out of range"));
    }
    let feature = split_feature[i];
    if feature < 0 || feature as usize >= n_features {
        return err(format!("tree {ti}: feature {feature} out of range"));
    }
    let t = threshold[i] as f32;
    if !t.is_finite() {
        return err(format!("tree {ti}: non-finite threshold"));
    }
    nodes.push(Node::Leaf { values: vec![] }); // placeholder
    let left = build(
        left_child[i], nodes, split_feature, threshold, left_child, right_child, leaf_value,
        n_features, n_classes, class, ti, depth + 1,
    )?;
    let right = build(
        right_child[i], nodes, split_feature, threshold, left_child, right_child, leaf_value,
        n_features, n_classes, class, ti, depth + 1,
    )?;
    nodes[id as usize] = Node::Branch { feature: feature as u32, threshold: t, left, right };
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    const BINARY_MODEL: &str = "\
version=v4\n\
num_class=1\n\
max_feature_idx=1\n\
objective=binary\n\
\n\
Tree=0\n\
num_leaves=3\n\
split_feature=0 1\n\
threshold=0.5 -1.25\n\
decision_type=2 2\n\
left_child=1 -1\n\
right_child=-2 -3\n\
leaf_value=0.1 -0.2 0.3\n\
\n\
Tree=1\n\
num_leaves=1\n\
leaf_value=0.05\n\
\n\
end of trees\n";

    #[test]
    fn binary_import_and_semantics() {
        let m = import(BINARY_MODEL).unwrap();
        assert_eq!(m.kind, ModelKind::Gbt);
        assert_eq!(m.n_features, 2);
        assert_eq!(m.n_classes, 2);
        assert_eq!(m.trees.len(), 2);
        let margin = |row: &[f32]| m.trees.iter().map(|t| t.evaluate(row)[1]).sum::<f32>();
        // tree0: x0 <= 0.5 ? (internal 1: x1 <= -1.25 ? leaf0 : leaf1) : leaf2? wait:
        // left_child[0]=1 (internal), right_child[0]=-2 (leaf 1).
        // internal 1: left=-1 (leaf 0 = 0.1), right=-3 (leaf 2 = 0.3).
        assert_eq!(margin(&[0.0, -2.0]), 0.1 + 0.05);
        assert_eq!(margin(&[0.0, 0.0]), 0.3 + 0.05);
        assert_eq!(margin(&[1.0, 0.0]), -0.2 + 0.05);
        // boundary: <= keeps 0.5 on the left subtree
        assert_eq!(margin(&[0.5, 5.0]), 0.3 + 0.05);
    }

    #[test]
    fn multiclass_header() {
        let text = "\
num_class=3\nmax_feature_idx=0\n\n\
Tree=0\nnum_leaves=1\nleaf_value=0.1\n\n\
Tree=1\nnum_leaves=1\nleaf_value=0.2\n\n\
Tree=2\nnum_leaves=1\nleaf_value=0.7\n\nend of trees\n";
        let m = import(text).unwrap();
        assert_eq!(m.n_classes, 3);
        let p = m.predict_proba(&[0.0]);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn integer_only_engine_accepts_imported_model() {
        let m = import(BINARY_MODEL).unwrap();
        let e = crate::inference::GbtIntEngine::compile(&m);
        for row in [[0.0f32, -2.0], [0.5, 5.0], [7.0, 7.0], [-3.0, -3.0]] {
            assert_eq!(e.predict(&row), m.predict(&row));
        }
    }

    #[test]
    fn codegen_pipeline_not_applicable_but_ir_tools_work() {
        // GBT models flow through stats/serialization like RF models.
        let m = import(BINARY_MODEL).unwrap();
        let m2 = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
        let s = crate::ir::stats::stats(&m);
        assert_eq!(s.n_trees, 2);
        assert_eq!(s.n_leaves, 4);
    }

    #[test]
    fn rejects_malformed() {
        assert!(import("").is_err());
        assert!(import("num_class=1\n").is_err()); // no trees
        // ragged arrays
        let ragged = "max_feature_idx=1\n\nTree=0\nnum_leaves=3\nsplit_feature=0\n\
            threshold=0.5 1.0\ndecision_type=2 2\nleft_child=1 -1\nright_child=-2 -3\n\
            leaf_value=0.1 0.2 0.3\n";
        assert!(import(ragged).is_err());
        // unsupported categorical decision type
        let cat = "max_feature_idx=1\n\nTree=0\nnum_leaves=2\nsplit_feature=0\n\
            threshold=0.5\ndecision_type=1\nleft_child=-1\nright_child=-2\nleaf_value=0.1 0.2\n";
        assert!(import(cat).is_err());
        // feature out of range
        let oob = "max_feature_idx=0\n\nTree=0\nnum_leaves=2\nsplit_feature=3\n\
            threshold=0.5\ndecision_type=2\nleft_child=-1\nright_child=-2\nleaf_value=0.1 0.2\n";
        assert!(import(oob).is_err());
    }
}
