//! XGBoost front-end: parse `Booster.get_dump(dump_format="json")`
//! output (a JSON array of per-tree nested objects) into the IR.
//!
//! Dump node shapes:
//! * branch: `{"nodeid":0,"split":"f3","split_condition":1.5,"yes":1,
//!   "no":2,"missing":1,"children":[...]}` — semantics `x < cond → yes`.
//! * leaf: `{"nodeid":5,"leaf":0.1703}` — an additive margin.
//!
//! Conversions applied:
//! * `<` splits become our `<=` convention via [`super::f32_pred`]
//!   (exact: classifies every finite f32 identically);
//! * multiclass boosters emit `n_rounds * n_classes` trees round-robin
//!   over classes; each imported tree's leaf vector holds its margin in
//!   its class column (the `ModelKind::Gbt` convention).
//!
//! `missing` direction is recorded but NaN features are rejected by the
//! engines (the IR has no NaN semantics; documented limitation).

use super::{err, ImportError};
use crate::ir::{Model, ModelKind, Node, Tree, MAX_CLASSES, MAX_FEATURES, MAX_TREES};
use crate::util::Json;

/// Import an XGBoost JSON dump.
///
/// `n_features`/`n_classes` come from the caller (the dump does not
/// carry them); `base_score` is XGBoost's global bias (default 0.5 for
/// logistic objectives — pass the booster's configured value, in margin
/// space).
pub fn import(
    dump_json: &str,
    n_features: usize,
    n_classes: usize,
    base_score: f32,
) -> Result<Model, ImportError> {
    let v = Json::parse(dump_json).map_err(|e| ImportError(format!("bad json: {e}")))?;
    let trees_json = match v.as_arr() {
        Some(a) => a,
        None => return err("expected a JSON array of trees"),
    };
    if trees_json.is_empty() {
        return err("empty tree list");
    }
    if n_classes < 2 {
        return err("n_classes must be >= 2");
    }
    if n_classes > MAX_CLASSES {
        return err(format!("n_classes {n_classes} exceeds limit {MAX_CLASSES}"));
    }
    if n_features > MAX_FEATURES {
        return err(format!("n_features {n_features} exceeds limit {MAX_FEATURES}"));
    }
    if trees_json.len() > MAX_TREES {
        return err(format!("{} trees exceeds limit {MAX_TREES}", trees_json.len()));
    }
    if !base_score.is_finite() {
        return err("non-finite base_score");
    }
    // Binary boosters emit one tree per round (class column 1... by
    // convention we place binary margins in column 1, base in column 1).
    let round_robin = if n_classes > 2 { n_classes } else { 1 };
    if trees_json.len() % round_robin != 0 {
        return err(format!(
            "tree count {} not a multiple of n_classes {}",
            trees_json.len(),
            n_classes
        ));
    }

    let mut trees = Vec::with_capacity(trees_json.len());
    for (ti, tv) in trees_json.iter().enumerate() {
        let class = if round_robin == 1 { 1 } else { ti % n_classes };
        let mut nodes: Vec<Node> = Vec::new();
        build_node(tv, &mut nodes, n_features, n_classes, class, ti, 0)?;
        trees.push(Tree { nodes });
    }

    let mut base = vec![0.0f32; n_classes];
    for (c, b) in base.iter_mut().enumerate() {
        // For binary models only the positive class carries the bias.
        if round_robin > 1 || c == 1 {
            *b = base_score;
        }
    }
    let model = Model { kind: ModelKind::Gbt, n_features, n_classes, trees, base_score: base };
    model.validate().map_err(|e| ImportError(format!("imported model invalid: {e}")))?;
    Ok(model)
}

#[allow(clippy::too_many_arguments)]
fn build_node(
    v: &Json,
    nodes: &mut Vec<Node>,
    n_features: usize,
    n_classes: usize,
    class: usize,
    ti: usize,
    depth: usize,
) -> Result<u32, ImportError> {
    // Recursion bound: mirrors the lightgbm importer's cap so a
    // pathologically deep dump errors instead of exhausting the stack.
    if depth > 512 {
        return err(format!("tree {ti}: depth > 512"));
    }
    let id = nodes.len() as u32;
    if let Some(leaf) = v.get("leaf") {
        let margin = leaf
            .as_f64()
            .ok_or_else(|| ImportError(format!("tree {ti}: bad leaf value")))?;
        let mut values = vec![0.0f32; n_classes];
        values[class] = margin as f32;
        nodes.push(Node::Leaf { values });
        return Ok(id);
    }

    // Branch node.
    let split = v
        .get("split")
        .and_then(Json::as_str)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing 'split'")))?;
    let feature: u32 = split
        .strip_prefix('f')
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| ImportError(format!("tree {ti}: bad split name '{split}'")))?;
    if feature as usize >= n_features {
        return err(format!("tree {ti}: feature {feature} out of range"));
    }
    let cond = v
        .get("split_condition")
        .and_then(Json::as_f64)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing split_condition")))?;
    let cond = cond as f32;
    if !cond.is_finite() {
        return err(format!("tree {ti}: non-finite split_condition"));
    }
    let yes = v
        .get("yes")
        .and_then(Json::as_f64)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing 'yes'")))?;
    let no = v
        .get("no")
        .and_then(Json::as_f64)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing 'no'")))?;
    let children = v
        .get("children")
        .and_then(Json::as_arr)
        .ok_or_else(|| ImportError(format!("tree {ti}: missing children")))?;
    if children.len() != 2 {
        return err(format!("tree {ti}: expected 2 children"));
    }
    let child_id = |want: f64| -> Result<&Json, ImportError> {
        children
            .iter()
            .find(|c| c.get("nodeid").and_then(Json::as_f64) == Some(want))
            .ok_or_else(|| ImportError(format!("tree {ti}: child nodeid {want} not found")))
    };

    nodes.push(Node::Leaf { values: vec![] }); // placeholder
    // xgboost: x < cond → 'yes' branch; ours: x <= pred(cond) → left.
    let left = build_node(child_id(yes)?, nodes, n_features, n_classes, class, ti, depth + 1)?;
    let right = build_node(child_id(no)?, nodes, n_features, n_classes, class, ti, depth + 1)?;
    nodes[id as usize] =
        Node::Branch { feature, threshold: super::f32_pred(cond), left, right };
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A binary booster with 2 rounds: margins move class 1.
    const BINARY_DUMP: &str = r#"[
      {"nodeid":0,"split":"f0","split_condition":0.5,"yes":1,"no":2,"missing":1,
       "children":[{"nodeid":1,"leaf":-0.4},{"nodeid":2,"leaf":0.6}]},
      {"nodeid":0,"split":"f1","split_condition":-1.25,"yes":1,"no":2,"missing":1,
       "children":[{"nodeid":2,"leaf":0.3},{"nodeid":1,"leaf":-0.2}]}
    ]"#;

    #[test]
    fn binary_import_and_semantics() {
        let m = import(BINARY_DUMP, 2, 2, 0.0).unwrap();
        assert_eq!(m.kind, ModelKind::Gbt);
        assert_eq!(m.trees.len(), 2);
        // x0 < 0.5 -> -0.4; x1 < -1.25 -> -0.2 (note shuffled child order).
        // margins: class1 = t0 + t1.
        let margin = |row: &[f32]| {
            m.trees.iter().map(|t| t.evaluate(row)[1]).sum::<f32>()
        };
        assert_eq!(margin(&[0.0, 0.0]), -0.4 + 0.3);
        assert_eq!(margin(&[1.0, -2.0]), 0.6 + -0.2);
        // boundary: xgboost '<' means x = 0.5 goes 'no'.
        assert_eq!(margin(&[0.5, 0.0]), 0.6 + 0.3);
        // just below goes 'yes'
        assert_eq!(margin(&[0.49999, 0.0]), -0.4 + 0.3);
    }

    #[test]
    fn multiclass_round_robin() {
        // 3 classes, one round = 3 trees (single-leaf stumps).
        let dump = r#"[
          {"nodeid":0,"leaf":0.1},
          {"nodeid":0,"leaf":0.2},
          {"nodeid":0,"leaf":0.3}
        ]"#;
        let m = import(dump, 4, 3, 0.5).unwrap();
        assert_eq!(m.trees.len(), 3);
        let p = m.predict_proba(&[0.0; 4]);
        // softmax(0.6, 0.7, 0.8) — monotone in class index
        assert!(p[2] > p[1] && p[1] > p[0]);
        assert_eq!(m.base_score, vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn integer_only_engine_accepts_imported_model() {
        let m = import(BINARY_DUMP, 2, 2, 0.0).unwrap();
        let e = crate::inference::GbtIntEngine::compile(&m);
        for row in [[0.0f32, 0.0], [0.5, -3.0], [2.0, 5.0], [-1.0, -1.25]] {
            assert_eq!(e.predict(&row), m.predict(&row));
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(import("{}", 2, 2, 0.0).is_err()); // not an array
        assert!(import("[]", 2, 2, 0.0).is_err()); // empty
        assert!(import("[{\"nodeid\":0}]", 2, 2, 0.0).is_err()); // neither leaf nor split
        // bad feature name
        let bad = r#"[{"nodeid":0,"split":"x0","split_condition":1,"yes":1,"no":2,
          "children":[{"nodeid":1,"leaf":0},{"nodeid":2,"leaf":0}]}]"#;
        assert!(import(bad, 2, 2, 0.0).is_err());
        // feature out of range
        let oob = r#"[{"nodeid":0,"split":"f9","split_condition":1,"yes":1,"no":2,
          "children":[{"nodeid":1,"leaf":0},{"nodeid":2,"leaf":0}]}]"#;
        assert!(import(oob, 2, 2, 0.0).is_err());
        // wrong multiple for multiclass
        assert!(import("[{\"nodeid\":0,\"leaf\":0.1}]", 2, 3, 0.0).is_err());
    }
}
