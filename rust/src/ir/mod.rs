//! Treelite-like model intermediate representation.
//!
//! Every trainer ([`crate::trees`]) lowers into this IR and every backend
//! (the inference engines, the C code generator, the architecture
//! simulator, the XLA artifact packer) consumes it — mirroring the role
//! Treelite plays in the paper's pipeline (Fig 1): a "standardized
//! intermediary that simplifies subsequent processing and optimization".
//!
//! Trees are stored as flat node arrays with explicit child indices.
//! Branch semantics: `if row[feature] <= threshold { left } else { right }`
//! — the comparison operator used by scikit-learn, XGBoost and LightGBM
//! alike, and the one the paper's Listings show.

pub mod import;
pub mod serial;
pub mod stats;

// ---------------------------------------------------------------------------
// Capacity limits — the admission bounds of every model-loading path.
//
// Untrusted inputs (model files, importer dumps, manifests) declare their
// own sizes; without bounds a corrupt or hostile file can demand
// pathological allocations before structural validation ever runs. The
// first two mirror hard encoding limits of the packed execution layout
// ([`crate::inference::compiled`]: 15-bit feature field, u16 child
// index); the last two are sanity ceilings far above anything the paper
// (or tree learning generally) produces.
// ---------------------------------------------------------------------------

/// Maximum feature columns a model may declare (compiled nodes store the
/// feature in a 15-bit field).
pub const MAX_FEATURES: usize = 32_768;
/// Maximum nodes in a single tree (compiled nodes store child links as
/// u16 indices).
pub const MAX_NODES_PER_TREE: usize = 65_536;
/// Maximum trees in an ensemble.
pub const MAX_TREES: usize = 100_000;
/// Maximum classes a model may declare.
pub const MAX_CLASSES: usize = 4_096;

/// One node of a tree: either an internal split or a leaf.
#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    /// `if row[feature] <= threshold` go to `left`, else `right`.
    Branch {
        /// Feature column the split reads.
        feature: u32,
        /// Split threshold (finite; `<=` goes left).
        threshold: f32,
        /// Index of the left child.
        left: u32,
        /// Index of the right child.
        right: u32,
    },
    /// Leaf payload. For classification forests (`ModelKind::RandomForest`)
    /// this is a per-class probability vector (sums to 1). For boosted
    /// trees (`ModelKind::Gbt`) it is a per-class margin contribution.
    Leaf {
        /// Per-class values (length `n_classes`).
        values: Vec<f32>,
    },
}

/// A single decision tree: `nodes[0]` is the root.
#[derive(Clone, Debug, PartialEq)]
pub struct Tree {
    /// Flat node array; child links are indices into it.
    pub nodes: Vec<Node>,
}

/// What the leaf values mean and how trees are combined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Leaves hold class probabilities; ensemble output is the average
    /// over trees (scikit-learn `RandomForestClassifier` semantics).
    RandomForest,
    /// Leaves hold additive margins; ensemble output is
    /// `base_score + sum(tree outputs)` followed by softmax/sigmoid.
    Gbt,
}

/// A trained tree-ensemble model in the common IR.
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    /// How leaf values combine (probability average vs additive margins).
    pub kind: ModelKind,
    /// Feature columns the model consumes.
    pub n_features: usize,
    /// Classes the model predicts.
    pub n_classes: usize,
    /// The ensemble's trees.
    pub trees: Vec<Tree>,
    /// GBT initial margin per class (zeros for random forests).
    pub base_score: Vec<f32>,
}

/// IR validation failure. Fields locate the offender: `tree` / `node`
/// are indices into [`Model::trees`] and [`Tree::nodes`].
#[derive(Debug, PartialEq)]
#[allow(missing_docs)] // variant docs + the field convention above cover these
pub enum IrError {
    /// A tree has no nodes.
    EmptyTree(usize),
    /// A child index points outside the tree.
    BadChild { tree: usize, node: usize },
    /// A split references a feature the model does not have.
    BadFeature { tree: usize, node: usize, feature: u32 },
    /// A leaf's value vector does not match `n_classes`.
    BadLeafArity { tree: usize, node: usize, got: usize },
    /// A split threshold is NaN or infinite.
    NonFiniteThreshold { tree: usize, node: usize },
    /// An RF leaf's values are not a probability distribution.
    LeafNotDistribution { tree: usize, node: usize, sum: f32 },
    /// A node cannot be reached from the root.
    Unreachable { tree: usize, node: usize },
    /// Child links form a cycle.
    Cycle { tree: usize },
    /// A node is the child of more than one branch (a DAG, not a tree).
    SharedChild { tree: usize, node: usize },
    /// The model has no trees at all (nothing to evaluate; RF averaging
    /// would divide by zero).
    NoTrees,
    /// `n_features` exceeds [`MAX_FEATURES`].
    TooManyFeatures { got: usize },
    /// `n_classes` exceeds [`MAX_CLASSES`] (or is zero).
    BadClassCount { got: usize },
    /// The ensemble has more than [`MAX_TREES`] trees.
    TooManyTrees { got: usize },
    /// A tree has more than [`MAX_NODES_PER_TREE`] nodes.
    TreeTooLarge { tree: usize, got: usize },
    /// `base_score` length does not match `n_classes`.
    BadBaseScoreArity { got: usize },
    /// A `base_score` entry is NaN or infinite.
    NonFiniteBaseScore { index: usize },
    /// A leaf value is NaN or infinite (poisons quantization and every
    /// engine downstream).
    NonFiniteLeafValue { tree: usize, node: usize },
}

impl std::fmt::Display for IrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for IrError {}

impl Tree {
    /// Evaluate the tree on a row, returning the leaf values.
    pub fn evaluate<'a>(&'a self, row: &[f32]) -> &'a [f32] {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Branch { feature, threshold, left, right } => {
                    i = if row[*feature as usize] <= *threshold { *left as usize } else { *right as usize };
                }
                Node::Leaf { values } => return values,
            }
        }
    }

    /// Number of leaf nodes.
    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    /// Maximum root-to-leaf depth (root = depth 0).
    ///
    /// Iterative (explicit-stack post-order): this is called at engine
    /// compile time on trees that may legally be chains of tens of
    /// thousands of nodes, where call-stack recursion would overflow a
    /// worker thread's stack.
    pub fn depth(&self) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut depth = vec![0usize; self.nodes.len()];
        // (node, children_done)
        let mut stack: Vec<(usize, bool)> = vec![(0, false)];
        while let Some((i, children_done)) = stack.pop() {
            match &self.nodes[i] {
                Node::Leaf { .. } => depth[i] = 0,
                Node::Branch { left, right, .. } => {
                    if children_done {
                        depth[i] = 1 + depth[*left as usize].max(depth[*right as usize]);
                    } else {
                        stack.push((i, true));
                        stack.push((*left as usize, false));
                        stack.push((*right as usize, false));
                    }
                }
            }
        }
        depth[0]
    }
}

impl Model {
    /// Predict class probabilities for one row (float reference semantics,
    /// exactly what the paper's baseline generated C computes).
    pub fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        match self.kind {
            ModelKind::RandomForest => {
                let mut acc = vec![0.0f32; self.n_classes];
                for t in &self.trees {
                    let leaf = t.evaluate(row);
                    for (a, &v) in acc.iter_mut().zip(leaf) {
                        *a += v;
                    }
                }
                let inv = 1.0 / self.trees.len() as f32;
                for a in &mut acc {
                    *a *= inv;
                }
                acc
            }
            ModelKind::Gbt => {
                let mut margins = self.base_score.clone();
                for t in &self.trees {
                    let leaf = t.evaluate(row);
                    for (m, &v) in margins.iter_mut().zip(leaf) {
                        *m += v;
                    }
                }
                softmax(&margins)
            }
        }
    }

    /// Predicted class (argmax of probabilities; ties resolve to the
    /// lowest class index, matching the generated C).
    pub fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.predict_proba(row))
    }

    /// Validate structural invariants. Called after training and after
    /// deserialization; the codegen and simulators assume a valid model.
    pub fn validate(&self) -> Result<(), IrError> {
        // Capacity limits first: a corrupt or hostile file fails on its
        // declared sizes before any per-node work happens.
        if self.trees.is_empty() {
            return Err(IrError::NoTrees);
        }
        if self.n_features > MAX_FEATURES {
            return Err(IrError::TooManyFeatures { got: self.n_features });
        }
        if self.n_classes == 0 || self.n_classes > MAX_CLASSES {
            return Err(IrError::BadClassCount { got: self.n_classes });
        }
        if self.trees.len() > MAX_TREES {
            return Err(IrError::TooManyTrees { got: self.trees.len() });
        }
        if self.base_score.len() != self.n_classes {
            return Err(IrError::BadBaseScoreArity { got: self.base_score.len() });
        }
        if let Some(index) = self.base_score.iter().position(|v| !v.is_finite()) {
            return Err(IrError::NonFiniteBaseScore { index });
        }
        for (ti, tree) in self.trees.iter().enumerate() {
            if tree.nodes.is_empty() {
                return Err(IrError::EmptyTree(ti));
            }
            if tree.nodes.len() > MAX_NODES_PER_TREE {
                return Err(IrError::TreeTooLarge { tree: ti, got: tree.nodes.len() });
            }
            let n = tree.nodes.len();
            let mut seen = vec![false; n];
            // Incoming child-edge count per node: a *tree* (what every
            // compiled layout, and the child-adjacent canonicalization in
            // particular, relies on) has exactly one parent per non-root
            // node and none for the root — shared children (DAGs) and
            // back-edges are rejected below.
            let mut refs = vec![0usize; n];
            // Iterative DFS from the root; also detects cycles via a bound
            // on visited edges.
            let mut stack = vec![0usize];
            let mut visited_edges = 0usize;
            while let Some(i) = stack.pop() {
                if seen[i] {
                    continue;
                }
                seen[i] = true;
                match &tree.nodes[i] {
                    Node::Branch { feature, threshold, left, right } => {
                        if *feature as usize >= self.n_features {
                            return Err(IrError::BadFeature { tree: ti, node: i, feature: *feature });
                        }
                        if !threshold.is_finite() {
                            return Err(IrError::NonFiniteThreshold { tree: ti, node: i });
                        }
                        for &c in [left, right].iter() {
                            if *c as usize >= n {
                                return Err(IrError::BadChild { tree: ti, node: i });
                            }
                            refs[*c as usize] += 1;
                            stack.push(*c as usize);
                        }
                        visited_edges += 2;
                        if visited_edges > 2 * n {
                            return Err(IrError::Cycle { tree: ti });
                        }
                    }
                    Node::Leaf { values } => {
                        if values.len() != self.n_classes {
                            return Err(IrError::BadLeafArity { tree: ti, node: i, got: values.len() });
                        }
                        if values.iter().any(|v| !v.is_finite()) {
                            return Err(IrError::NonFiniteLeafValue { tree: ti, node: i });
                        }
                        if self.kind == ModelKind::RandomForest {
                            let sum: f32 = values.iter().sum();
                            if !(0.999..=1.001).contains(&sum) || values.iter().any(|v| *v < 0.0) {
                                return Err(IrError::LeafNotDistribution { tree: ti, node: i, sum });
                            }
                        }
                    }
                }
            }
            if let Some(node) = seen.iter().position(|&s| !s) {
                return Err(IrError::Unreachable { tree: ti, node });
            }
            // Proper-tree shape: nothing may point back at the root (a
            // small cycle the edge bound can miss), and no node may have
            // two parents.
            if refs[0] > 0 {
                return Err(IrError::Cycle { tree: ti });
            }
            if let Some(node) = refs.iter().position(|&r| r > 1) {
                return Err(IrError::SharedChild { tree: ti, node });
            }
        }
        Ok(())
    }

    /// Serialize to the JSON interchange format (see [`serial`]).
    pub fn to_json(&self) -> String {
        serial::to_json(self).to_string()
    }

    /// Deserialize from JSON and validate. Binary `INTB` artifacts are
    /// sniffed and rejected with a pointed error ([`serial::check_not_binary`]).
    pub fn from_json(s: &str) -> Result<Model, Box<dyn std::error::Error>> {
        serial::check_not_binary(s)?;
        let v = crate::util::Json::parse(s)?;
        let m = serial::from_json(&v)?;
        m.validate()?;
        Ok(m)
    }

    /// Total number of nodes across all trees.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.nodes.len()).sum()
    }

    /// Total number of leaves across all trees.
    pub fn n_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves()).sum()
    }

    /// Maximum tree depth in the ensemble.
    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }
}

/// Numerically-stable softmax.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Argmax with lowest-index tie-breaking.
pub fn argmax<T: PartialOrd + Copy>(xs: &[T]) -> u32 {
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-built 2-class stump: x0 <= 0.5 ? [0.9,0.1] : [0.2,0.8]
    pub(crate) fn stump() -> Model {
        Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 2 },
                    Node::Leaf { values: vec![0.9, 0.1] },
                    Node::Leaf { values: vec![0.2, 0.8] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        }
    }

    #[test]
    fn stump_eval() {
        let m = stump();
        assert_eq!(m.predict(&[0.0]), 0);
        assert_eq!(m.predict(&[1.0]), 1);
        // boundary: <= goes left
        assert_eq!(m.predict(&[0.5]), 0);
        assert!(m.validate().is_ok());
    }

    #[test]
    fn ensemble_averages() {
        let mut m = stump();
        m.trees.push(Tree { nodes: vec![Node::Leaf { values: vec![0.5, 0.5] }] });
        let p = m.predict_proba(&[0.0]);
        assert!((p[0] - 0.7).abs() < 1e-6);
        assert!((p[1] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn validate_catches_bad_child() {
        let mut m = stump();
        if let Node::Branch { left, .. } = &mut m.trees[0].nodes[0] {
            *left = 99;
        }
        assert!(matches!(m.validate(), Err(IrError::BadChild { .. })));
    }

    #[test]
    fn validate_catches_bad_feature() {
        let mut m = stump();
        if let Node::Branch { feature, .. } = &mut m.trees[0].nodes[0] {
            *feature = 5;
        }
        assert!(matches!(m.validate(), Err(IrError::BadFeature { .. })));
    }

    #[test]
    fn validate_catches_bad_leaf() {
        let mut m = stump();
        m.trees[0].nodes[1] = Node::Leaf { values: vec![0.9, 0.9] };
        assert!(matches!(m.validate(), Err(IrError::LeafNotDistribution { .. })));
    }

    #[test]
    fn validate_catches_nonfinite_threshold() {
        let mut m = stump();
        if let Node::Branch { threshold, .. } = &mut m.trees[0].nodes[0] {
            *threshold = f32::NAN;
        }
        assert!(matches!(m.validate(), Err(IrError::NonFiniteThreshold { .. })));
    }

    #[test]
    fn validate_catches_unreachable() {
        let mut m = stump();
        m.trees[0].nodes.push(Node::Leaf { values: vec![1.0, 0.0] });
        assert!(matches!(m.validate(), Err(IrError::Unreachable { .. })));
    }

    #[test]
    fn validate_catches_shared_child() {
        // A DAG, not a tree: both branch arms point at the same leaf.
        // Every node is reachable and acyclic, so only the single-parent
        // check can reject it — the compiled child-adjacent layout
        // depends on this being an error.
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 1 },
                    Node::Leaf { values: vec![0.5, 0.5] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        };
        assert_eq!(
            m.validate(),
            Err(IrError::SharedChild { tree: 0, node: 1 })
        );
    }

    #[test]
    fn validate_catches_root_backedge() {
        // left points back at the root: a 2-cycle small enough to slip
        // past the visited-edge bound; the root-has-no-parent check
        // rejects it.
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 0, right: 1 },
                    Node::Leaf { values: vec![0.5, 0.5] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        };
        assert_eq!(m.validate(), Err(IrError::Cycle { tree: 0 }));
    }

    #[test]
    fn validate_catches_arity() {
        let mut m = stump();
        m.trees[0].nodes[1] = Node::Leaf { values: vec![1.0] };
        assert!(matches!(m.validate(), Err(IrError::BadLeafArity { .. })));
    }

    #[test]
    fn validate_enforces_capacity_limits() {
        let mut m = stump();
        m.trees.clear();
        assert_eq!(m.validate(), Err(IrError::NoTrees));

        let mut m = stump();
        m.n_features = MAX_FEATURES + 1;
        assert_eq!(m.validate(), Err(IrError::TooManyFeatures { got: MAX_FEATURES + 1 }));

        let mut m = stump();
        m.n_classes = MAX_CLASSES + 1;
        assert_eq!(m.validate(), Err(IrError::BadClassCount { got: MAX_CLASSES + 1 }));
        m.n_classes = 0;
        assert_eq!(m.validate(), Err(IrError::BadClassCount { got: 0 }));
    }

    #[test]
    fn validate_catches_base_score_corruption() {
        let mut m = stump();
        m.base_score = vec![0.0];
        assert_eq!(m.validate(), Err(IrError::BadBaseScoreArity { got: 1 }));

        let mut m = stump();
        m.base_score[1] = f32::INFINITY;
        assert_eq!(m.validate(), Err(IrError::NonFiniteBaseScore { index: 1 }));
    }

    #[test]
    fn validate_catches_nonfinite_leaf() {
        // GBT kind so the RF distribution check cannot mask the leaf
        // finiteness check.
        let mut m = stump();
        m.kind = ModelKind::Gbt;
        m.trees[0].nodes[2] = Node::Leaf { values: vec![0.2, f32::NAN] };
        assert_eq!(m.validate(), Err(IrError::NonFiniteLeafValue { tree: 0, node: 2 }));
    }

    #[test]
    fn json_roundtrip() {
        let m = stump();
        let j = m.to_json();
        let m2 = Model::from_json(&j).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn json_rejects_invalid() {
        let mut m = stump();
        m.trees[0].nodes[1] = Node::Leaf { values: vec![0.9, 0.9] };
        let j = m.to_json();
        assert!(Model::from_json(&j).is_err());
    }

    #[test]
    fn depth_and_counts() {
        let m = stump();
        assert_eq!(m.trees[0].depth(), 1);
        assert_eq!(m.n_nodes(), 3);
        assert_eq!(m.n_leaves(), 2);
        assert_eq!(m.max_depth(), 1);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        assert_eq!(argmax(&[0.5f32, 0.5, 0.1]), 0);
    }
}
