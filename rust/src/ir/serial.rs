//! JSON (de)serialization of the model IR — the interchange format the
//! framework's stages pass between each other (the analogue of Treelite's
//! model files in the paper's pipeline, Fig 1).
//!
//! Format (compact, columnar per tree to keep files small):
//!
//! ```json
//! {
//!   "format": "intreeger-ir-v1",
//!   "kind": "rf" | "gbt",
//!   "n_features": 7,
//!   "n_classes": 7,
//!   "base_score": [0, ...],
//!   "trees": [
//!     {
//!       "feature":  [0, -1, -1],        // -1 marks a leaf
//!       "threshold":[87.5, 0, 0],
//!       "left":     [1, 0, 0],
//!       "right":    [2, 0, 0],
//!       "leaf":     [[...], [0.9, 0.1], [0.2, 0.8]]  // per-node values
//!     }, ...
//!   ]
//! }
//! ```

use super::{Model, ModelKind, Node, Tree};
use super::{MAX_CLASSES, MAX_FEATURES, MAX_NODES_PER_TREE, MAX_TREES};
use crate::util::json::{arr, f32_arr, num, obj, s, Json};

/// Current format tag.
pub const FORMAT: &str = "intreeger-ir-v1";

/// Reject input that is actually an `INTB` binary model artifact
/// ([`crate::runtime::binfmt`]) handed to the JSON deserializer — the
/// format-confusion case gets a pointed typed error instead of an
/// opaque JSON parse failure.
pub fn check_not_binary(s: &str) -> Result<(), SerialError> {
    if s.as_bytes().starts_with(b"INTB") {
        return err(
            "input is an INTB binary model artifact, not JSON IR; \
             load it through runtime::binfmt (e.g. `serve --bin`)",
        );
    }
    Ok(())
}

/// Serialize a model to a JSON value.
pub fn to_json(model: &Model) -> Json {
    let trees: Vec<Json> = model
        .trees
        .iter()
        .map(|t| {
            let mut feature = Vec::with_capacity(t.nodes.len());
            let mut threshold = Vec::with_capacity(t.nodes.len());
            let mut left = Vec::with_capacity(t.nodes.len());
            let mut right = Vec::with_capacity(t.nodes.len());
            let mut leaf = Vec::with_capacity(t.nodes.len());
            for n in &t.nodes {
                match n {
                    Node::Branch { feature: f, threshold: th, left: l, right: r } => {
                        feature.push(num(*f as f64));
                        threshold.push(num(*th as f64));
                        left.push(num(*l as f64));
                        right.push(num(*r as f64));
                        leaf.push(Json::Arr(vec![]));
                    }
                    Node::Leaf { values } => {
                        feature.push(num(-1.0));
                        threshold.push(num(0.0));
                        left.push(num(0.0));
                        right.push(num(0.0));
                        leaf.push(f32_arr(values));
                    }
                }
            }
            obj(vec![
                ("feature", Json::Arr(feature)),
                ("threshold", Json::Arr(threshold)),
                ("left", Json::Arr(left)),
                ("right", Json::Arr(right)),
                ("leaf", Json::Arr(leaf)),
            ])
        })
        .collect();

    obj(vec![
        ("format", s(FORMAT)),
        ("kind", s(match model.kind {
            ModelKind::RandomForest => "rf",
            ModelKind::Gbt => "gbt",
        })),
        ("n_features", num(model.n_features as f64)),
        ("n_classes", num(model.n_classes as f64)),
        ("base_score", f32_arr(&model.base_score)),
        ("trees", arr(trees)),
    ])
}

/// Deserialization error.
#[derive(Debug)]
pub struct SerialError(
    /// Human-readable cause.
    pub String,
);

impl std::fmt::Display for SerialError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model deserialization error: {}", self.0)
    }
}
impl std::error::Error for SerialError {}

fn err<T>(msg: impl Into<String>) -> Result<T, SerialError> {
    Err(SerialError(msg.into()))
}

fn get_f64s(v: &Json, key: &str) -> Result<Vec<f64>, SerialError> {
    let a = match v.get(key).and_then(Json::as_arr) {
        Some(a) => a,
        None => return err(format!("missing array '{key}'")),
    };
    a.iter()
        .map(|x| x.as_f64().ok_or_else(|| SerialError(format!("non-number in '{key}'"))))
        .collect()
}

/// Deserialize a model from a parsed JSON value. Structural validation
/// (child indices, leaf arity, ...) is the caller's job via
/// [`Model::validate`]; this only checks the format.
pub fn from_json(v: &Json) -> Result<Model, SerialError> {
    match v.get("format").and_then(Json::as_str) {
        Some(f) if f == FORMAT => {}
        Some(f) => return err(format!("unsupported format '{f}'")),
        None => return err("missing 'format'"),
    }
    let kind = match v.get("kind").and_then(Json::as_str) {
        Some("rf") => ModelKind::RandomForest,
        Some("gbt") => ModelKind::Gbt,
        other => return err(format!("bad kind {other:?}")),
    };
    let n_features = v
        .get("n_features")
        .and_then(Json::as_usize)
        .ok_or_else(|| SerialError("bad n_features".into()))?;
    if n_features > MAX_FEATURES {
        return err(format!("n_features {n_features} exceeds limit {MAX_FEATURES}"));
    }
    let n_classes = v
        .get("n_classes")
        .and_then(Json::as_usize)
        .ok_or_else(|| SerialError("bad n_classes".into()))?;
    if n_classes == 0 || n_classes > MAX_CLASSES {
        return err(format!("n_classes {n_classes} outside 1..={MAX_CLASSES}"));
    }
    let base_score: Vec<f32> =
        get_f64s(v, "base_score")?.into_iter().map(|x| x as f32).collect();

    let trees_json = match v.get("trees").and_then(Json::as_arr) {
        Some(a) => a,
        None => return err("missing 'trees'"),
    };
    if trees_json.len() > MAX_TREES {
        return err(format!("{} trees exceeds limit {MAX_TREES}", trees_json.len()));
    }
    let mut trees = Vec::with_capacity(trees_json.len());
    for (ti, tv) in trees_json.iter().enumerate() {
        let feature = get_f64s(tv, "feature")?;
        let threshold = get_f64s(tv, "threshold")?;
        let left = get_f64s(tv, "left")?;
        let right = get_f64s(tv, "right")?;
        let leaf = match tv.get("leaf").and_then(Json::as_arr) {
            Some(a) => a,
            None => return err(format!("tree {ti}: missing 'leaf'")),
        };
        let n = feature.len();
        if threshold.len() != n || left.len() != n || right.len() != n || leaf.len() != n {
            return err(format!("tree {ti}: column length mismatch"));
        }
        if n > MAX_NODES_PER_TREE {
            return err(format!("tree {ti}: {n} nodes exceeds limit {MAX_NODES_PER_TREE}"));
        }
        let mut nodes = Vec::with_capacity(n);
        for i in 0..n {
            if feature[i] < 0.0 {
                let values = leaf[i]
                    .as_arr()
                    .ok_or_else(|| SerialError(format!("tree {ti} node {i}: bad leaf")))?
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .map(|f| f as f32)
                            .ok_or_else(|| SerialError(format!("tree {ti} node {i}: bad leaf value")))
                    })
                    .collect::<Result<Vec<f32>, _>>()?;
                nodes.push(Node::Leaf { values });
            } else {
                // The f64 → f32 narrowing can overflow to infinity (JSON
                // happily encodes 1e300); catch it here with a located
                // message — `validate` would reject it too, but later and
                // namelessly relative to the file.
                let th = threshold[i] as f32;
                if !th.is_finite() {
                    return err(format!("tree {ti} node {i}: non-finite threshold"));
                }
                nodes.push(Node::Branch {
                    feature: feature[i] as u32,
                    threshold: th,
                    left: left[i] as u32,
                    right: right[i] as u32,
                });
            }
        }
        trees.push(Tree { nodes });
    }

    Ok(Model { kind, n_features, n_classes, trees, base_score })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    #[test]
    fn roundtrip_trained_forest() {
        let ds = shuttle_like(800, 21);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 4, max_depth: 5, ..Default::default() },
            9,
        );
        let text = m.to_json();
        let m2 = Model::from_json(&text).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn roundtrip_gbt() {
        let ds = shuttle_like(300, 22);
        let m = crate::trees::train_gbt(
            &ds,
            &crate::trees::GbtParams { n_rounds: 2, max_depth: 3, ..Default::default() },
            1,
        );
        let m2 = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn thresholds_bit_exact() {
        // FlInt correctness depends on thresholds surviving serialization
        // bit-for-bit.
        let ds = shuttle_like(500, 23);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 3, max_depth: 6, ..Default::default() },
            2,
        );
        let m2 = Model::from_json(&m.to_json()).unwrap();
        for (t1, t2) in m.trees.iter().zip(&m2.trees) {
            for (n1, n2) in t1.nodes.iter().zip(&t2.nodes) {
                if let (Node::Branch { threshold: a, .. }, Node::Branch { threshold: b, .. }) =
                    (n1, n2)
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Model::from_json("{\"format\":\"other\"}").is_err());
        assert!(Model::from_json("{}").is_err());
        assert!(Model::from_json("[1,2]").is_err());
        assert!(Model::from_json("not json").is_err());
    }

    #[test]
    fn rejects_column_mismatch() {
        let bad = r#"{"format":"intreeger-ir-v1","kind":"rf","n_features":1,
            "n_classes":2,"base_score":[0,0],
            "trees":[{"feature":[-1],"threshold":[0,0],"left":[0],"right":[0],"leaf":[[1,0]]}]}"#;
        assert!(Model::from_json(bad).is_err());
    }

    #[test]
    fn rejects_oversized_declared_counts() {
        // Hostile headers fail on their declared sizes, before any
        // allocation or per-node work.
        let huge_features = r#"{"format":"intreeger-ir-v1","kind":"rf",
            "n_features":9999999999,"n_classes":2,"base_score":[0,0],
            "trees":[{"feature":[-1],"threshold":[0],"left":[0],"right":[0],"leaf":[[1,0]]}]}"#;
        assert!(Model::from_json(huge_features).is_err());
        let huge_classes = r#"{"format":"intreeger-ir-v1","kind":"rf",
            "n_features":1,"n_classes":9999999,"base_score":[0,0],
            "trees":[{"feature":[-1],"threshold":[0],"left":[0],"right":[0],"leaf":[[1,0]]}]}"#;
        assert!(Model::from_json(huge_classes).is_err());
        let zero_classes = r#"{"format":"intreeger-ir-v1","kind":"rf",
            "n_features":1,"n_classes":0,"base_score":[],
            "trees":[{"feature":[-1],"threshold":[0],"left":[0],"right":[0],"leaf":[[]]}]}"#;
        assert!(Model::from_json(zero_classes).is_err());
    }

    #[test]
    fn rejects_nonfinite_threshold_encodings() {
        // 1e999 parses to f64 infinity; 1e300 is finite in f64 but
        // overflows the f32 narrowing. Both must be typed errors.
        for enc in ["1e999", "1e300", "-1e999"] {
            let bad = format!(
                r#"{{"format":"intreeger-ir-v1","kind":"rf","n_features":1,
                "n_classes":2,"base_score":[0,0],
                "trees":[{{"feature":[0,-1,-1],"threshold":[{enc},0,0],
                "left":[1,0,0],"right":[2,0,0],
                "leaf":[[],[0.9,0.1],[0.2,0.8]]}}]}}"#
            );
            assert!(Model::from_json(&bad).is_err(), "threshold {enc} must be rejected");
        }
    }

    #[test]
    fn rejects_invalid_structure_via_validate() {
        // Well-formed JSON, structurally invalid model (bad child index).
        let bad = r#"{"format":"intreeger-ir-v1","kind":"rf","n_features":1,
            "n_classes":2,"base_score":[0,0],
            "trees":[{"feature":[0],"threshold":[0.5],"left":[7],"right":[7],"leaf":[[]]}]}"#;
        assert!(Model::from_json(bad).is_err());
    }
}
