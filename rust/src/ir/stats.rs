//! Model-level statistics used by the evaluation harnesses: node/leaf
//! counts, depth histograms, the leaf-probability distribution the
//! probability-to-integer conversion (paper §III-A) operates on, and
//! per-tree QuickScorer eligibility (which trees fit a `u64` false-leaf
//! mask and take the bitvector fast path — surfaced by the CLI
//! `inspect` command so the walker fallback is never a mystery).

use super::{Model, Node};
use crate::inference::quickscorer::QS_MAX_LEAVES;

/// Summary statistics of a trained model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelStats {
    /// Trees in the ensemble.
    pub n_trees: usize,
    /// Total nodes across all trees.
    pub n_nodes: usize,
    /// Internal split nodes.
    pub n_branches: usize,
    /// Leaf nodes.
    pub n_leaves: usize,
    /// Maximum root-to-leaf depth in the ensemble.
    pub max_depth: usize,
    /// Mean node depth over all nodes.
    pub mean_depth: f64,
    /// Smallest non-zero leaf probability in the model — drives the
    /// paper's first edge case (probabilities < ~0.001 lose relative
    /// precision vs f32; see §III-A).
    pub min_nonzero_leaf_prob: f32,
    /// Expected number of branch nodes evaluated per inference assuming
    /// uniform leaf reachability (upper-bounded by max depth).
    pub mean_leaf_depth: f64,
    /// Leaf count per tree (QuickScorer eligibility is
    /// `<=` [`QS_MAX_LEAVES`]).
    pub leaf_counts: Vec<usize>,
    /// Trees whose leaves fit one `u64` QuickScorer bitvector.
    pub qs_eligible_trees: usize,
    /// Tree ids that exceed the mask width and take the branchless
    /// walker fallback under the QuickScorer kernel.
    pub qs_ineligible: Vec<usize>,
}

/// Compute summary statistics for a model.
pub fn stats(model: &Model) -> ModelStats {
    let mut n_branches = 0usize;
    let mut n_leaves = 0usize;
    let mut min_p = f32::INFINITY;
    let mut depth_sum = 0usize;
    let mut leaf_depth_sum = 0usize;
    let mut leaf_count = 0usize;
    let mut leaf_counts = Vec::with_capacity(model.trees.len());

    for tree in &model.trees {
        let mut tree_leaves = 0usize;
        // depth of each node via DFS from root
        let mut depth = vec![0usize; tree.nodes.len()];
        let mut stack = vec![0usize];
        let mut seen = vec![false; tree.nodes.len()];
        while let Some(i) = stack.pop() {
            if seen[i] {
                continue;
            }
            seen[i] = true;
            match &tree.nodes[i] {
                Node::Branch { left, right, .. } => {
                    n_branches += 1;
                    for &c in [left, right].iter() {
                        depth[*c as usize] = depth[i] + 1;
                        stack.push(*c as usize);
                    }
                }
                Node::Leaf { values } => {
                    n_leaves += 1;
                    tree_leaves += 1;
                    leaf_depth_sum += depth[i];
                    leaf_count += 1;
                    for &v in values {
                        if v > 0.0 && v < min_p {
                            min_p = v;
                        }
                    }
                }
            }
            depth_sum += depth[i];
        }
        leaf_counts.push(tree_leaves);
    }

    let qs_ineligible: Vec<usize> = leaf_counts
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > QS_MAX_LEAVES)
        .map(|(t, _)| t)
        .collect();
    let n_nodes = n_branches + n_leaves;
    ModelStats {
        n_trees: model.trees.len(),
        n_nodes,
        n_branches,
        n_leaves,
        max_depth: model.max_depth(),
        mean_depth: if n_nodes == 0 { 0.0 } else { depth_sum as f64 / n_nodes as f64 },
        min_nonzero_leaf_prob: if min_p.is_finite() { min_p } else { 0.0 },
        mean_leaf_depth: if leaf_count == 0 { 0.0 } else { leaf_depth_sum as f64 / leaf_count as f64 },
        qs_eligible_trees: leaf_counts.len() - qs_ineligible.len(),
        qs_ineligible,
        leaf_counts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ModelKind, Tree};

    fn stump() -> Model {
        Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 2 },
                    Node::Leaf { values: vec![0.9, 0.1] },
                    Node::Leaf { values: vec![0.25, 0.75] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        }
    }

    #[test]
    fn stump_stats() {
        let s = stats(&stump());
        assert_eq!(s.n_trees, 1);
        assert_eq!(s.n_nodes, 3);
        assert_eq!(s.n_branches, 1);
        assert_eq!(s.n_leaves, 2);
        assert_eq!(s.max_depth, 1);
        assert_eq!(s.min_nonzero_leaf_prob, 0.1);
        assert!((s.mean_leaf_depth - 1.0).abs() < 1e-12);
        assert_eq!(s.leaf_counts, vec![2]);
        assert_eq!(s.qs_eligible_trees, 1);
        assert!(s.qs_ineligible.is_empty());
    }

    #[test]
    fn qs_eligibility_flags_wide_trees() {
        // A right-leaning chain with QS_MAX_LEAVES + 1 leaves (one more
        // than a u64 mask covers) next to the eligible stump.
        let n_branches = QS_MAX_LEAVES;
        let mut nodes = Vec::with_capacity(2 * n_branches + 1);
        for i in 0..n_branches {
            nodes.push(Node::Branch {
                feature: 0,
                threshold: i as f32,
                left: (2 * i + 1) as u32,
                right: (2 * i + 2) as u32,
            });
            nodes.push(Node::Leaf { values: vec![0.5, 0.5] });
        }
        nodes.push(Node::Leaf { values: vec![0.5, 0.5] });
        let mut m = stump();
        m.trees.push(crate::ir::Tree { nodes });
        m.validate().unwrap();
        let s = stats(&m);
        assert_eq!(s.leaf_counts, vec![2, QS_MAX_LEAVES + 1]);
        assert_eq!(s.qs_eligible_trees, 1);
        assert_eq!(s.qs_ineligible, vec![1]);
    }

    #[test]
    fn trained_model_stats_consistent() {
        let ds = crate::data::shuttle_like(2000, 5);
        let model = crate::trees::RandomForest::train(
            &ds,
            &crate::trees::ForestParams { n_trees: 5, max_depth: 6, ..Default::default() },
            42,
        );
        let s = stats(&model);
        assert_eq!(s.n_trees, 5);
        assert_eq!(s.n_nodes, s.n_branches + s.n_leaves);
        // a binary tree has exactly one more leaf than branches
        assert_eq!(s.n_leaves, s.n_branches + s.n_trees);
        assert!(s.max_depth <= 6);
        assert!(s.min_nonzero_leaf_prob > 0.0 && s.min_nonzero_leaf_prob <= 1.0);
        assert_eq!(s.leaf_counts.len(), 5);
        assert_eq!(s.leaf_counts.iter().sum::<usize>(), s.n_leaves);
        // Depth-6 trees have at most 64 leaves: all eligible.
        assert_eq!(s.qs_eligible_trees, 5);
        assert!(s.qs_ineligible.is_empty());
    }
}
