//! Probability-to-integer conversion (§III-A) — the paper's contribution.
//!
//! Leaf class probabilities `p ∈ [0, 1]` are converted **at code
//! generation time** to `u32` fixed point with scaling factor
//! `S = 2^32 / n_trees`:
//!
//! ```text
//! q = floor(p * 2^32 / n)
//! ```
//!
//! Each tree contributes `q < 2^32/n + 1`, so the sum over `n` trees fits
//! a `u32` without overflow, and ensemble accumulation becomes plain
//! integer addition — no FPU anywhere in the inference path. The absolute
//! representation error per accumulated probability is below `n / 2^32`
//! (the paper's §III-A precision analysis), which beats single-precision
//! float (`2^-24`) whenever `n <= 256`.
//!
//! One corner the paper glosses over: when `n` divides `2^32` exactly
//! (n = 1, 2, 4, ...) and a leaf has `p = 1.0`, the per-tree value is
//! exactly `2^32/n` and `n` such trees sum to `2^32` — which wraps a
//! `u32` to 0 and would catastrophically mis-rank that class. We
//! therefore clamp each quantized value to `floor((2^32-1)/n)`, which
//! guarantees `sum <= 2^32-1` unconditionally while changing the paper's
//! arithmetic by at most one ULP of the fixed-point grid (error still
//! within the `n/2^32` bound) — see [`prob_to_fixed`] and the
//! `prop_no_overflow_for_distributions` property test.
//!
//! GBT leaf *margins* are not probabilities; [`margin_scale`] derives a
//! power-of-two fixed-point scale from the model's margin range instead.

use crate::ir::{Model, ModelKind, Node};

/// 2^32 as f64 (exact).
pub const TWO_32: f64 = 4_294_967_296.0;

/// Fixed-point scaling factor for an `n`-tree ensemble: `2^32 / n`.
#[inline]
pub fn scale_factor(n_trees: usize) -> f64 {
    assert!(n_trees > 0);
    TWO_32 / n_trees as f64
}

/// Convert one leaf probability to `u32` fixed point with scale `2^32/n`
/// (floor rounding, as in the paper's worked example: 0.75 with n=10 →
/// 322122547). Values are clamped to `floor((2^32-1)/n)` so that the sum
/// over `n` trees provably fits a `u32` (see module docs).
#[inline]
pub fn prob_to_fixed(p: f32, n_trees: usize) -> u32 {
    debug_assert!((0.0..=1.0).contains(&p), "probability out of range: {p}");
    let cap = (u32::MAX as u64 / n_trees as u64) as u32;
    let q = (p as f64 * scale_factor(n_trees)).floor();
    if q >= cap as f64 {
        cap
    } else {
        q as u32
    }
}

/// Convert an accumulated `u32` fixed-point sum back to an f32 probability
/// (only used for reporting/verification — inference itself never needs
/// this conversion; argmax happens on the integer sums).
#[inline]
pub fn fixed_to_prob(acc: u32) -> f32 {
    (acc as f64 / TWO_32) as f32
}

/// Worst-case absolute error of the accumulated ensemble probability:
/// each of the `n` terms loses < 1/S = n/2^32 in the floor... divided by
/// the implicit ensemble average. Net bound: `n / 2^32` on the final
/// averaged probability (paper §III-A).
#[inline]
pub fn error_bound(n_trees: usize) -> f64 {
    n_trees as f64 / TWO_32
}

/// Largest ensemble size for which the fixed-point representation is at
/// least as accurate as an IEEE-754 single float (paper: `n/2^32 >
/// 1/2^24 ⇔ n > 256`).
pub const MAX_TREES_BEATING_F32: usize = 256;

/// True when the fixed-point error bound is no worse than f32's 2^-24.
#[inline]
pub fn beats_f32(n_trees: usize) -> bool {
    n_trees <= MAX_TREES_BEATING_F32
}

/// Maximum possible accumulated value across `n` trees: each leaf
/// contributes at most `floor((2^32-1)/n)` (the clamp in
/// [`prob_to_fixed`]), so `n` trees sum to at most `2^32 - 1` — the
/// no-overflow guarantee the integer engine's unchecked `u32` additions
/// rely on.
pub fn max_accumulated(n_trees: usize) -> u64 {
    n_trees as u64 * (u32::MAX as u64 / n_trees as u64)
}

/// A quantized leaf: per-class `u32` fixed-point contributions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantLeaf {
    /// Per-class fixed-point values (scale `2^32 / n_trees`).
    pub values: Vec<u32>,
}

/// Quantize every leaf of a random-forest model. Returns, per tree, per
/// leaf-node-index, the `u32` contribution vector. Branch nodes get `None`.
///
/// Panics if the model is not a `RandomForest` (GBT margins use
/// [`margin_scale`] + [`margin_to_fixed`] instead).
pub fn quantize_forest(model: &Model) -> Vec<Vec<Option<QuantLeaf>>> {
    assert_eq!(model.kind, ModelKind::RandomForest, "quantize_forest needs probability leaves");
    let n = model.trees.len();
    model
        .trees
        .iter()
        .map(|t| {
            t.nodes
                .iter()
                .map(|node| match node {
                    Node::Leaf { values } => Some(QuantLeaf {
                        values: values.iter().map(|&p| prob_to_fixed(p, n)).collect(),
                    }),
                    Node::Branch { .. } => None,
                })
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// GBT margin fixed point
// ---------------------------------------------------------------------------

/// Fixed-point parameters for GBT margins: `q = round(m * 2^shift)`,
/// accumulated in `i64`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MarginScale {
    /// Power-of-two exponent: margins are scaled by `2^shift`.
    pub shift: u32,
}

/// Derive a margin scale: choose the largest `shift` such that the
/// worst-case accumulated |margin| (sum of per-tree maxima + base score)
/// stays below `2^62` — leaving headroom so i64 accumulation cannot
/// overflow.
pub fn margin_scale(model: &Model) -> MarginScale {
    assert_eq!(model.kind, ModelKind::Gbt);
    let mut max_abs_sum = model.base_score.iter().fold(0.0f64, |a, &b| a.max(b.abs() as f64));
    for t in &model.trees {
        let tree_max = t
            .nodes
            .iter()
            .filter_map(|n| match n {
                Node::Leaf { values } => {
                    Some(values.iter().fold(0.0f64, |a, &v| a.max(v.abs() as f64)))
                }
                _ => None,
            })
            .fold(0.0f64, f64::max);
        max_abs_sum += tree_max;
    }
    let max_abs_sum = max_abs_sum.max(1e-30);
    // 2^shift * max_abs_sum < 2^62  =>  shift < 62 - log2(max_abs_sum)
    let shift = (61.0 - max_abs_sum.log2()).floor().clamp(0.0, 40.0) as u32;
    MarginScale { shift }
}

/// Quantize one margin value under a scale.
#[inline]
pub fn margin_to_fixed(m: f32, scale: MarginScale) -> i64 {
    (m as f64 * (1u64 << scale.shift) as f64).round() as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Model, ModelKind, Tree};
    use crate::prop_ensure;
    use crate::util::check::check;

    #[test]
    fn paper_worked_example() {
        // RF with 10 trees; leaf (0.75, 0.25) → (322122547, 107374182).
        assert_eq!(prob_to_fixed(0.75, 10), 322_122_547);
        assert_eq!(prob_to_fixed(0.25, 10), 107_374_182);
    }

    #[test]
    fn clamp_corner_case() {
        // n=1, p=1.0: floor(2^32) would overflow u32; clamp to u32::MAX.
        assert_eq!(prob_to_fixed(1.0, 1), u32::MAX);
        assert_eq!(prob_to_fixed(0.0, 1), 0);
    }

    #[test]
    fn error_bound_matches_paper() {
        assert!(beats_f32(256));
        assert!(!beats_f32(257));
        assert!(error_bound(1) <= 1.0 / (1u64 << 32) as f64 + 1e-30);
        // n=100 trees: error ~ 1e-8 (the paper's Fig 2 magnitude).
        let e = error_bound(100);
        assert!(e > 1e-8 && e < 1e-7, "e = {e}");
    }

    #[test]
    fn max_accumulated_fits_u32() {
        for n in [1usize, 2, 3, 4, 7, 8, 10, 50, 64, 100, 128, 256, 257, 1000] {
            assert!(max_accumulated(n) <= u32::MAX as u64, "n = {n}");
        }
    }

    #[test]
    fn power_of_two_saturated_leaves_do_not_wrap() {
        // The edge case the paper misses: n | 2^32 and p = 1.0.
        for n in [1usize, 2, 4, 8, 16, 64, 256] {
            let q = prob_to_fixed(1.0, n) as u64;
            assert!(q * n as u64 <= u32::MAX as u64, "n = {n} wraps");
            // and the error stays within the paper's bound
            let err = (1.0 - (q * n as u64) as f64 / TWO_32).abs();
            assert!(err <= error_bound(n) + 1.0 / TWO_32, "n = {n} err {err}");
        }
    }

    fn tiny_forest(n_trees: usize) -> Model {
        let tree = Tree {
            nodes: vec![
                crate::ir::Node::Branch { feature: 0, threshold: 0.0, left: 1, right: 2 },
                crate::ir::Node::Leaf { values: vec![0.75, 0.25] },
                crate::ir::Node::Leaf { values: vec![0.0, 1.0] },
            ],
        };
        Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![tree; n_trees],
            base_score: vec![0.0, 0.0],
        }
    }

    #[test]
    fn quantize_forest_shapes() {
        let m = tiny_forest(10);
        let q = quantize_forest(&m);
        assert_eq!(q.len(), 10);
        assert!(q[0][0].is_none());
        assert_eq!(q[0][1].as_ref().unwrap().values, vec![322_122_547, 107_374_182]);
    }

    #[test]
    #[should_panic(expected = "probability leaves")]
    fn quantize_rejects_gbt() {
        let mut m = tiny_forest(1);
        m.kind = ModelKind::Gbt;
        quantize_forest(&m);
    }

    #[test]
    fn margin_scale_headroom() {
        let ds = crate::data::shuttle_like(500, 1);
        let m = crate::trees::train_gbt(
            &ds,
            &crate::trees::GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() },
            2,
        );
        let s = margin_scale(&m);
        assert!(s.shift > 10, "shift {}", s.shift);
        // Worst-case accumulated magnitude must stay under 2^62.
        let mut max_abs_sum = m.base_score.iter().fold(0.0f64, |a, &b| a.max(b.abs() as f64));
        for t in &m.trees {
            let tm = t
                .nodes
                .iter()
                .filter_map(|n| match n {
                    crate::ir::Node::Leaf { values } => {
                        Some(values.iter().fold(0.0f64, |a, &v| a.max(v.abs() as f64)))
                    }
                    _ => None,
                })
                .fold(0.0f64, f64::max);
            max_abs_sum += tm;
        }
        assert!(max_abs_sum * ((1u64 << s.shift) as f64) < (1u64 << 62) as f64);
    }

    /// Quantization error of a single probability is < 1/S (floor).
    #[test]
    fn prop_single_prob_error_bound() {
        check(
            "single_prob_error_bound",
            |r| (r.uniform() as f32, 1 + r.below(299)),
            |&(p, n)| {
                let q = prob_to_fixed(p, n);
                let s = scale_factor(n);
                let err = (p as f64 - q as f64 / s).abs();
                prop_ensure!(err <= 1.0 / s + 1e-12, "err {} bound {}", err, 1.0 / s);
                Ok(())
            },
        );
    }

    /// Summing n quantized probabilities from distributions never
    /// overflows u32 (the paper's overflow-prevention claim).
    #[test]
    fn prop_no_overflow_for_distributions() {
        check(
            "no_overflow_for_distributions",
            |r| {
                let n = 1 + r.below(299);
                let k = 1 + r.below(7);
                let raw: Vec<f64> = (0..k).map(|_| r.uniform()).collect();
                let total: f64 = raw.iter().sum::<f64>().max(1e-9);
                let probs: Vec<f32> = raw.iter().map(|&x| (x / total) as f32).collect();
                (n, probs)
            },
            |&(n, ref probs)| {
                for &p in probs {
                    // Worst case: all n trees land on this same leaf value.
                    let q = prob_to_fixed(p.min(1.0), n) as u64;
                    let sum = q * n as u64;
                    prop_ensure!(sum <= u32::MAX as u64, "class sum {} overflows (n={})", sum, n);
                }
                Ok(())
            },
        );
    }

    /// Argmax of fixed-point sums equals argmax of float sums when class
    /// probabilities are separated by more than the error bound.
    #[test]
    fn prop_argmax_parity_when_separated() {
        check(
            "argmax_parity_when_separated",
            |r| (1 + r.below(255), r.uniform()),
            |&(n, a)| {
                let gap = 2.0 * error_bound(n) + 1e-6;
                let p0 = (a * (1.0 - gap)) as f32;
                let p1 = (p0 as f64 + gap) as f32;
                let q0 = (prob_to_fixed(p0, n) as u64) * n as u64;
                let q1 = (prob_to_fixed(p1, n) as u64) * n as u64;
                prop_ensure!((p0 < p1) == (q0 < q1), "ordering flip: n={n} p0={p0} p1={p1}");
                Ok(())
            },
        );
    }
}
