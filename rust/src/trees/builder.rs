//! CART classification-tree builder (Gini impurity, exact sorted-scan
//! split finding), producing [`crate::ir::Tree`] directly.
//!
//! Split semantics match scikit-learn: candidate thresholds are midpoints
//! between consecutive distinct feature values; a split sends
//! `value <= threshold` left. Leaf values are the class distribution of
//! the training rows that reach the leaf — exactly the probabilities the
//! paper's §III-A conversion later turns into `u32` fixed point.

use crate::data::Dataset;
use crate::ir::{Node, Tree};
use crate::util::Rng;

/// Parameters for a single CART tree.
#[derive(Clone, Debug)]
pub struct TreeParams {
    /// Maximum depth (root = 0). The paper's use cases use depths 5–7.
    pub max_depth: usize,
    /// Minimum rows required to attempt a split.
    pub min_samples_split: usize,
    /// Minimum rows in each child.
    pub min_samples_leaf: usize,
    /// Number of features to consider per split; `0` means all
    /// (Random Forests pass sqrt(n_features)).
    pub max_features: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 8, min_samples_split: 2, min_samples_leaf: 1, max_features: 0 }
    }
}

/// Gini impurity of a class-count vector with `total` samples.
#[inline]
fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts.iter().map(|&c| (c as f64 / t) * (c as f64 / t)).sum::<f64>()
}

struct BestSplit {
    feature: usize,
    /// Threshold as the midpoint of adjacent distinct values, snapped to
    /// f32 (the IR stores f32 thresholds, like Treelite).
    threshold: f32,
    /// Weighted-Gini improvement over the parent node.
    gain: f64,
}

/// Find the best (feature, threshold) for rows `idx`, or None if no split
/// improves impurity / satisfies the constraints.
fn best_split(
    ds: &Dataset,
    idx: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> Option<BestSplit> {
    let n = idx.len();
    if n < params.min_samples_split {
        return None;
    }
    let mut parent_counts = vec![0usize; ds.n_classes];
    for &i in idx {
        parent_counts[ds.labels[i] as usize] += 1;
    }
    let parent_gini = gini(&parent_counts, n);
    if parent_gini == 0.0 {
        return None; // pure node
    }

    let k = if params.max_features == 0 { ds.n_features } else { params.max_features.min(ds.n_features) };
    let features = rng.sample_indices(ds.n_features, k);

    let mut best: Option<BestSplit> = None;
    for f in features {
        // Sort row indices by this feature's value.
        scratch.order.clear();
        scratch.order.extend(idx.iter().map(|&i| (ds.row(i)[f], ds.labels[i])));
        scratch
            .order
            .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));

        let mut left_counts = vec![0usize; ds.n_classes];
        let mut right_counts = parent_counts.clone();
        for s in 0..n - 1 {
            let (v, label) = scratch.order[s];
            left_counts[label as usize] += 1;
            right_counts[label as usize] -= 1;
            let next_v = scratch.order[s + 1].0;
            if v == next_v {
                continue; // can't split between equal values
            }
            let n_left = s + 1;
            let n_right = n - n_left;
            if n_left < params.min_samples_leaf || n_right < params.min_samples_leaf {
                continue;
            }
            let w_gini = (n_left as f64 * gini(&left_counts, n_left)
                + n_right as f64 * gini(&right_counts, n_right))
                / n as f64;
            let gain = parent_gini - w_gini;
            if gain > best.as_ref().map_or(1e-12, |b| b.gain) {
                // Midpoint in f64, snapped to f32. Snap may round up to
                // next_v; clamp so `v <= threshold < next_v` stays true
                // (f32 threshold must separate the two f32 values).
                let mut t = ((v as f64 + next_v as f64) * 0.5) as f32;
                if t >= next_v {
                    t = v;
                }
                best = Some(BestSplit { feature: f, threshold: t, gain });
            }
        }
    }
    best
}

/// Reusable sort buffer across nodes.
struct Scratch {
    order: Vec<(f32, u32)>,
}

/// Train a single CART classification tree on rows `idx` of `ds`.
/// Leaf values are class frequencies (a probability distribution).
pub fn train_tree(ds: &Dataset, idx: &[usize], params: &TreeParams, rng: &mut Rng) -> Tree {
    assert!(!idx.is_empty(), "cannot train a tree on zero rows");
    let mut nodes: Vec<Node> = Vec::new();
    let mut scratch = Scratch { order: Vec::with_capacity(idx.len()) };
    build_node(ds, idx, params, rng, &mut nodes, 0, &mut scratch);
    Tree { nodes }
}

fn leaf_from(ds: &Dataset, idx: &[usize]) -> Node {
    let mut counts = vec![0usize; ds.n_classes];
    for &i in idx {
        counts[ds.labels[i] as usize] += 1;
    }
    let total = idx.len() as f32;
    Node::Leaf { values: counts.iter().map(|&c| c as f32 / total).collect() }
}

fn build_node(
    ds: &Dataset,
    idx: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
    nodes: &mut Vec<Node>,
    depth: usize,
    scratch: &mut Scratch,
) -> u32 {
    let id = nodes.len() as u32;
    if depth >= params.max_depth {
        nodes.push(leaf_from(ds, idx));
        return id;
    }
    match best_split(ds, idx, params, rng, scratch) {
        None => {
            nodes.push(leaf_from(ds, idx));
            id
        }
        Some(split) => {
            nodes.push(Node::Leaf { values: vec![] }); // placeholder
            let (mut left_idx, mut right_idx) = (Vec::new(), Vec::new());
            for &i in idx {
                if ds.row(i)[split.feature] <= split.threshold {
                    left_idx.push(i);
                } else {
                    right_idx.push(i);
                }
            }
            debug_assert!(!left_idx.is_empty() && !right_idx.is_empty());
            let left = build_node(ds, &left_idx, params, rng, nodes, depth + 1, scratch);
            let right = build_node(ds, &right_idx, params, rng, nodes, depth + 1, scratch);
            nodes[id as usize] = Node::Branch {
                feature: split.feature as u32,
                threshold: split.threshold,
                left,
                right,
            };
            id
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shuttle_like, Dataset};
    use crate::ir::{Model, ModelKind};

    fn as_model(tree: Tree, ds: &Dataset) -> Model {
        Model {
            kind: ModelKind::RandomForest,
            n_features: ds.n_features,
            n_classes: ds.n_classes,
            trees: vec![tree],
            base_score: vec![0.0; ds.n_classes],
        }
    }

    /// Perfectly separable 1-D data must be fit exactly.
    #[test]
    fn separable_data_fit_exactly() {
        let features: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let labels: Vec<u32> = (0..100).map(|i| if i < 50 { 0 } else { 1 }).collect();
        let ds = Dataset::new(features, labels, 1, 2);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        let tree = train_tree(&ds, &idx, &TreeParams::default(), &mut Rng::new(1));
        let model = as_model(tree, &ds);
        assert!(model.validate().is_ok());
        assert_eq!(crate::trees::accuracy(&model, &ds), 1.0);
        // One split suffices.
        assert_eq!(model.trees[0].nodes.len(), 3);
    }

    #[test]
    fn respects_max_depth() {
        let ds = shuttle_like(2000, 2);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        for depth in [0usize, 1, 3, 5] {
            let tree = train_tree(
                &ds,
                &idx,
                &TreeParams { max_depth: depth, ..Default::default() },
                &mut Rng::new(1),
            );
            assert!(tree.depth() <= depth, "depth {} > {}", tree.depth(), depth);
        }
    }

    #[test]
    fn depth_zero_is_single_leaf_with_prior() {
        let ds = shuttle_like(1000, 3);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        let tree = train_tree(&ds, &idx, &TreeParams { max_depth: 0, ..Default::default() }, &mut Rng::new(1));
        assert_eq!(tree.nodes.len(), 1);
        if let Node::Leaf { values } = &tree.nodes[0] {
            let s: f32 = values.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        } else {
            panic!("expected leaf");
        }
    }

    #[test]
    fn min_samples_leaf_respected() {
        let ds = shuttle_like(500, 4);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        let tree = train_tree(
            &ds,
            &idx,
            &TreeParams { max_depth: 12, min_samples_leaf: 50, ..Default::default() },
            &mut Rng::new(1),
        );
        // With >=50 rows per leaf, at most 500/50 = 10 leaves.
        assert!(tree.n_leaves() <= 10);
    }

    #[test]
    fn better_than_majority_baseline() {
        let ds = shuttle_like(5000, 5);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        let tree = train_tree(&ds, &idx, &TreeParams { max_depth: 8, ..Default::default() }, &mut Rng::new(1));
        let model = as_model(tree, &ds);
        let majority =
            *ds.class_counts().iter().max().unwrap() as f64 / ds.n_rows() as f64;
        let acc = crate::trees::accuracy(&model, &ds);
        assert!(acc > majority + 0.02, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn gini_helper() {
        assert_eq!(gini(&[10, 0], 10), 0.0);
        assert!((gini(&[5, 5], 10) - 0.5).abs() < 1e-12);
        assert_eq!(gini(&[], 0), 0.0);
    }

    #[test]
    fn thresholds_separate_values_as_f32() {
        // Construct values where the f64 midpoint rounds to the upper f32;
        // the builder must clamp so the split still separates them.
        let a = 1.0f32;
        let b = f32::from_bits(a.to_bits() + 1); // next representable
        let features = vec![a, a, b, b];
        let labels = vec![0, 0, 1, 1];
        let ds = Dataset::new(features, labels, 1, 2);
        let idx: Vec<usize> = (0..4).collect();
        let tree = train_tree(&ds, &idx, &TreeParams::default(), &mut Rng::new(1));
        let model = as_model(tree, &ds);
        assert_eq!(crate::trees::accuracy(&model, &ds), 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = shuttle_like(1000, 6);
        let idx: Vec<usize> = (0..ds.n_rows()).collect();
        let t1 = train_tree(&ds, &idx, &TreeParams::default(), &mut Rng::new(77));
        let t2 = train_tree(&ds, &idx, &TreeParams::default(), &mut Rng::new(77));
        assert_eq!(t1, t2);
    }
}
