//! Gradient-boosted trees (softmax log-loss, Newton leaf weights —
//! XGBoost-style second-order boosting).
//!
//! The paper's framework "supports all existing tree-based classification
//! models" via the common IR; GBTs are the second major family (XGBoost /
//! LightGBM front-ends in Fig 1). A GBT leaf holds an additive *margin*
//! rather than a probability, so the integer conversion for GBT models
//! uses a range-derived fixed-point scale (see [`crate::quant`]) instead
//! of the probability scale `2^32/n`.

use crate::data::Dataset;
use crate::ir::{Model, ModelKind, Node, Tree};
use crate::util::Rng;

/// GBT training parameters.
#[derive(Clone, Debug)]
pub struct GbtParams {
    /// Boosting rounds; each round trains `n_classes` trees (one-vs-all).
    pub n_rounds: usize,
    /// Depth limit for every tree.
    pub max_depth: usize,
    /// Shrinkage applied to every leaf weight.
    pub learning_rate: f32,
    /// L2 regularization on leaf weights (XGBoost lambda).
    pub lambda: f64,
    /// Minimum rows each side of a split must keep.
    pub min_samples_leaf: usize,
    /// Row subsample fraction per round (stochastic gradient boosting).
    pub subsample: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_rounds: 10,
            max_depth: 4,
            learning_rate: 0.3,
            lambda: 1.0,
            min_samples_leaf: 1,
            subsample: 1.0,
        }
    }
}

/// Per-row gradient statistics for one class column.
struct GradHess {
    g: Vec<f64>,
    h: Vec<f64>,
}

/// Newton gain for a candidate split (XGBoost eq. 7, no complexity term).
#[inline]
fn newton_score(g: f64, h: f64, lambda: f64) -> f64 {
    g * g / (h + lambda)
}

/// Regression-tree node builder on (g, h) statistics. Leaf values are
/// `-lr * G / (H + lambda)` stored in the class column `class`.
fn build_reg_node(
    ds: &Dataset,
    idx: &[usize],
    gh: &GradHess,
    params: &GbtParams,
    depth: usize,
    class: usize,
    nodes: &mut Vec<Node>,
) -> u32 {
    let id = nodes.len() as u32;
    let (gsum, hsum) = idx.iter().fold((0.0, 0.0), |(g, h), &i| (g + gh.g[i], h + gh.h[i]));

    let make_leaf = |nodes: &mut Vec<Node>| {
        let mut values = vec![0.0f32; ds.n_classes];
        values[class] = (-params.learning_rate as f64 * gsum / (hsum + params.lambda)) as f32;
        nodes.push(Node::Leaf { values });
    };

    if depth >= params.max_depth || idx.len() < 2 * params.min_samples_leaf.max(1) {
        make_leaf(nodes);
        return id;
    }

    // Exact split search over all features.
    let parent_score = newton_score(gsum, hsum, params.lambda);
    let mut best: Option<(usize, f32, f64)> = None; // (feature, threshold, gain)
    let mut order: Vec<(f32, f64, f64)> = Vec::with_capacity(idx.len());
    for f in 0..ds.n_features {
        order.clear();
        order.extend(idx.iter().map(|&i| (ds.row(i)[f], gh.g[i], gh.h[i])));
        order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        let (mut gl, mut hl) = (0.0f64, 0.0f64);
        for s in 0..order.len() - 1 {
            gl += order[s].1;
            hl += order[s].2;
            let (v, next_v) = (order[s].0, order[s + 1].0);
            if v == next_v {
                continue;
            }
            let n_left = s + 1;
            let n_right = order.len() - n_left;
            if n_left < params.min_samples_leaf || n_right < params.min_samples_leaf {
                continue;
            }
            let gain = newton_score(gl, hl, params.lambda)
                + newton_score(gsum - gl, hsum - hl, params.lambda)
                - parent_score;
            if gain > best.map_or(1e-9, |b| b.2) {
                let mut t = ((v as f64 + next_v as f64) * 0.5) as f32;
                if t >= next_v {
                    t = v;
                }
                best = Some((f, t, gain));
            }
        }
    }

    match best {
        None => {
            make_leaf(nodes);
            id
        }
        Some((feature, threshold, _)) => {
            nodes.push(Node::Leaf { values: vec![] }); // placeholder
            let (mut li, mut ri) = (Vec::new(), Vec::new());
            for &i in idx {
                if ds.row(i)[feature] <= threshold {
                    li.push(i);
                } else {
                    ri.push(i);
                }
            }
            let left = build_reg_node(ds, &li, gh, params, depth + 1, class, nodes);
            let right = build_reg_node(ds, &ri, gh, params, depth + 1, class, nodes);
            nodes[id as usize] =
                Node::Branch { feature: feature as u32, threshold, left, right };
            id
        }
    }
}

/// Train a gradient-boosted-trees classifier; deterministic in `seed`.
pub fn train_gbt(ds: &Dataset, params: &GbtParams, seed: u64) -> Model {
    assert!(params.n_rounds > 0);
    assert!(ds.n_rows() > 0);
    let n = ds.n_rows();
    let k = ds.n_classes;
    let mut rng = Rng::new(seed);

    // Base score: log of class priors (standard multiclass init).
    let counts = ds.class_counts();
    let base_score: Vec<f32> = counts
        .iter()
        .map(|&c| (((c.max(1)) as f64) / n as f64).ln() as f32)
        .collect();

    // Current margins per row per class.
    let mut margins: Vec<f64> = Vec::with_capacity(n * k);
    for _ in 0..n {
        margins.extend(base_score.iter().map(|&b| b as f64));
    }

    let mut trees: Vec<Tree> = Vec::with_capacity(params.n_rounds * k);
    for round in 0..params.n_rounds {
        // Row subsample for this round.
        let idx: Vec<usize> = if params.subsample < 1.0 {
            let m = ((n as f64) * params.subsample).round().max(1.0) as usize;
            rng.sample_indices(n, m)
        } else {
            (0..n).collect()
        };

        // Softmax probabilities for all rows (needed for grads).
        let mut probs = vec![0.0f64; n * k];
        for i in 0..n {
            let row = &margins[i * k..(i + 1) * k];
            let m = row.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
            let mut s = 0.0;
            for c in 0..k {
                let e = (row[c] - m).exp();
                probs[i * k + c] = e;
                s += e;
            }
            for c in 0..k {
                probs[i * k + c] /= s;
            }
        }

        for class in 0..k {
            // Softmax log-loss gradients: g = p - y, h = p(1-p).
            let mut gh = GradHess { g: vec![0.0; n], h: vec![0.0; n] };
            for i in 0..n {
                let p = probs[i * k + class];
                let y = if ds.labels[i] as usize == class { 1.0 } else { 0.0 };
                gh.g[i] = p - y;
                gh.h[i] = (p * (1.0 - p)).max(1e-9);
            }
            let mut nodes = Vec::new();
            build_reg_node(ds, &idx, &gh, params, 0, class, &mut nodes);
            let tree = Tree { nodes };
            // Update margins with the new tree's predictions.
            for i in 0..n {
                let leaf = tree.evaluate(ds.row(i));
                margins[i * k + class] += leaf[class] as f64;
            }
            trees.push(tree);
        }
        let _ = round;
    }

    let model = Model { kind: ModelKind::Gbt, n_features: ds.n_features, n_classes: k, trees, base_score };
    debug_assert!(model.validate().is_ok());
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::accuracy;
    use crate::util::Rng;

    #[test]
    fn gbt_trains_and_validates() {
        let ds = shuttle_like(2000, 7);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() }, 1);
        assert!(m.validate().is_ok());
        assert_eq!(m.kind, ModelKind::Gbt);
        assert_eq!(m.trees.len(), 3 * ds.n_classes);
    }

    #[test]
    fn gbt_beats_majority() {
        let ds = shuttle_like(4000, 8);
        let (train, test) = ds.train_test_split(0.25, &mut Rng::new(2));
        let m = train_gbt(&train, &GbtParams { n_rounds: 8, max_depth: 4, ..Default::default() }, 3);
        let majority = *test.class_counts().iter().max().unwrap() as f64 / test.n_rows() as f64;
        let acc = accuracy(&m, &test);
        assert!(acc > majority, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn gbt_probabilities_are_distribution() {
        let ds = shuttle_like(800, 9);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 2, max_depth: 3, ..Default::default() }, 4);
        let p = m.predict_proba(ds.row(0));
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn gbt_more_rounds_reduce_train_error() {
        let ds = shuttle_like(2000, 10);
        let short = train_gbt(&ds, &GbtParams { n_rounds: 1, max_depth: 3, ..Default::default() }, 5);
        let long = train_gbt(&ds, &GbtParams { n_rounds: 10, max_depth: 3, ..Default::default() }, 5);
        assert!(accuracy(&long, &ds) >= accuracy(&short, &ds));
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = shuttle_like(600, 11);
        let p = GbtParams { n_rounds: 2, max_depth: 3, subsample: 0.7, ..Default::default() };
        assert_eq!(train_gbt(&ds, &p, 9), train_gbt(&ds, &p, 9));
    }
}
