//! Random Forest training (bagging + feature subsampling), with
//! scikit-learn `RandomForestClassifier` prediction semantics: each tree
//! votes with a class-probability leaf and the ensemble averages them —
//! the exact structure the paper's probability-to-integer conversion
//! targets (§III-A: "the probabilities from each DT in the ensemble are
//! summed up and divided by the total number of trees").

use super::builder::{train_tree, TreeParams};
use crate::data::Dataset;
use crate::ir::{Model, ModelKind};
use crate::util::Rng;

/// Random-forest training parameters.
#[derive(Clone, Debug)]
pub struct ForestParams {
    /// Number of trees. The paper evaluates up to 100 (and notes that
    /// >256 would break the fixed-point precision argument).
    pub n_trees: usize,
    /// Depth limit for every tree.
    pub max_depth: usize,
    /// Minimum rows a node needs to be split further.
    pub min_samples_split: usize,
    /// Minimum rows each side of a split must keep.
    pub min_samples_leaf: usize,
    /// Features per split; `0` = floor(sqrt(n_features)) (sklearn default).
    pub max_features: usize,
    /// Bootstrap sample size as a fraction of the training set.
    pub bootstrap_frac: f64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 10,
            max_depth: 8,
            min_samples_split: 2,
            min_samples_leaf: 1,
            max_features: 0,
            bootstrap_frac: 1.0,
        }
    }
}

/// Random-forest trainer. (Namespaced struct so callers write
/// `RandomForest::train(...)`; the result is a plain IR [`Model`].)
pub struct RandomForest;

impl RandomForest {
    /// Train a random forest; deterministic in `seed`.
    pub fn train(ds: &Dataset, params: &ForestParams, seed: u64) -> Model {
        assert!(params.n_trees > 0, "n_trees must be positive");
        assert!(ds.n_rows() > 0, "cannot train on an empty dataset");
        let mut rng = Rng::new(seed);
        let max_features = if params.max_features == 0 {
            (ds.n_features as f64).sqrt().floor().max(1.0) as usize
        } else {
            params.max_features
        };
        let tree_params = TreeParams {
            max_depth: params.max_depth,
            min_samples_split: params.min_samples_split,
            min_samples_leaf: params.min_samples_leaf,
            max_features,
        };
        let n_boot = ((ds.n_rows() as f64) * params.bootstrap_frac).round().max(1.0) as usize;

        let mut trees = Vec::with_capacity(params.n_trees);
        for t in 0..params.n_trees {
            let mut tree_rng = rng.fork(t as u64);
            // Bootstrap sample (with replacement).
            let idx: Vec<usize> = (0..n_boot).map(|_| tree_rng.below(ds.n_rows())).collect();
            trees.push(train_tree(ds, &idx, &tree_params, &mut tree_rng));
        }

        let model = Model {
            kind: ModelKind::RandomForest,
            n_features: ds.n_features,
            n_classes: ds.n_classes,
            trees,
            base_score: vec![0.0; ds.n_classes],
        };
        debug_assert!(model.validate().is_ok());
        model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{esa_like, shuttle_like};
    use crate::trees::accuracy;

    #[test]
    fn forest_valid_and_sized() {
        let ds = shuttle_like(2000, 1);
        let m = RandomForest::train(&ds, &ForestParams { n_trees: 7, max_depth: 5, ..Default::default() }, 3);
        assert!(m.validate().is_ok());
        assert_eq!(m.trees.len(), 7);
        assert_eq!(m.kind, ModelKind::RandomForest);
        assert!(m.max_depth() <= 5);
    }

    #[test]
    fn forest_beats_single_tree_on_holdout() {
        let ds = shuttle_like(8000, 2);
        let (train, test) = ds.train_test_split(0.25, &mut Rng::new(9));
        let single = RandomForest::train(&train, &ForestParams { n_trees: 1, max_depth: 6, ..Default::default() }, 5);
        let forest = RandomForest::train(&train, &ForestParams { n_trees: 25, max_depth: 6, ..Default::default() }, 5);
        let acc1 = accuracy(&single, &test);
        let acc25 = accuracy(&forest, &test);
        // Bagging shouldn't be (much) worse; usually better.
        assert!(acc25 + 0.02 >= acc1, "forest {acc25} vs single {acc1}");
        assert!(acc25 > 0.6, "forest accuracy too low: {acc25}");
    }

    #[test]
    fn esa_forest_trains() {
        let ds = esa_like(3000, 3);
        let (train, test) = ds.train_test_split(0.25, &mut Rng::new(1));
        let m = RandomForest::train(&train, &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() }, 1);
        let majority = *test.class_counts().iter().max().unwrap() as f64 / test.n_rows() as f64;
        let acc = accuracy(&m, &test);
        assert!(acc >= majority - 0.05, "acc {acc} majority {majority}");
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = shuttle_like(1000, 4);
        let p = ForestParams { n_trees: 4, max_depth: 4, ..Default::default() };
        assert_eq!(RandomForest::train(&ds, &p, 11), RandomForest::train(&ds, &p, 11));
        assert_ne!(RandomForest::train(&ds, &p, 11), RandomForest::train(&ds, &p, 12));
    }

    #[test]
    #[should_panic(expected = "n_trees")]
    fn zero_trees_panics() {
        let ds = shuttle_like(100, 1);
        RandomForest::train(&ds, &ForestParams { n_trees: 0, ..Default::default() }, 1);
    }

    #[test]
    fn probabilities_average_to_distribution() {
        let ds = shuttle_like(1500, 5);
        let m = RandomForest::train(&ds, &ForestParams { n_trees: 9, max_depth: 5, ..Default::default() }, 2);
        for i in (0..ds.n_rows()).step_by(97) {
            let p = m.predict_proba(ds.row(i));
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }
}
