//! Extremely Randomized Trees (Geurts et al., cited by the paper §II-A):
//! like a Random Forest but splits use *random* thresholds drawn within
//! each candidate feature's value range (no exhaustive scan), and by
//! default no bootstrap. Faster to train, often comparable accuracy —
//! and a third ensemble family exercising the same IR/integer pipeline.

use crate::data::Dataset;
use crate::ir::{Model, ModelKind, Node, Tree};
use crate::util::Rng;

/// ExtraTrees training parameters.
#[derive(Clone, Debug)]
pub struct ExtraParams {
    /// Number of trees.
    pub n_trees: usize,
    /// Depth limit for every tree.
    pub max_depth: usize,
    /// Minimum rows a node needs to be split further.
    pub min_samples_split: usize,
    /// Candidate features per split; 0 = floor(sqrt(n_features)).
    pub max_features: usize,
}

impl Default for ExtraParams {
    fn default() -> Self {
        ExtraParams { n_trees: 10, max_depth: 8, min_samples_split: 2, max_features: 0 }
    }
}

/// Train an ExtraTrees ensemble; deterministic in `seed`.
pub fn train_extra_trees(ds: &Dataset, params: &ExtraParams, seed: u64) -> Model {
    assert!(params.n_trees > 0 && ds.n_rows() > 0);
    let k = if params.max_features == 0 {
        (ds.n_features as f64).sqrt().floor().max(1.0) as usize
    } else {
        params.max_features.min(ds.n_features)
    };
    let mut rng = Rng::new(seed);
    let idx: Vec<usize> = (0..ds.n_rows()).collect();
    let mut trees = Vec::with_capacity(params.n_trees);
    for t in 0..params.n_trees {
        let mut tree_rng = rng.fork(t as u64);
        let mut nodes = Vec::new();
        grow(ds, &idx, params, k, &mut tree_rng, &mut nodes, 0);
        trees.push(Tree { nodes });
    }
    let model = Model {
        kind: ModelKind::RandomForest,
        n_features: ds.n_features,
        n_classes: ds.n_classes,
        trees,
        base_score: vec![0.0; ds.n_classes],
    };
    debug_assert!(model.validate().is_ok());
    model
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts.iter().map(|&c| (c as f64 / t) * (c as f64 / t)).sum::<f64>()
}

fn leaf_from(ds: &Dataset, idx: &[usize]) -> Node {
    let mut counts = vec![0usize; ds.n_classes];
    for &i in idx {
        counts[ds.labels[i] as usize] += 1;
    }
    let total = idx.len() as f32;
    Node::Leaf { values: counts.iter().map(|&c| c as f32 / total).collect() }
}

fn grow(
    ds: &Dataset,
    idx: &[usize],
    params: &ExtraParams,
    k: usize,
    rng: &mut Rng,
    nodes: &mut Vec<Node>,
    depth: usize,
) -> u32 {
    let id = nodes.len() as u32;
    let mut counts = vec![0usize; ds.n_classes];
    for &i in idx {
        counts[ds.labels[i] as usize] += 1;
    }
    let parent_gini = gini(&counts, idx.len());
    if depth >= params.max_depth || idx.len() < params.min_samples_split || parent_gini == 0.0 {
        nodes.push(leaf_from(ds, idx));
        return id;
    }

    // ExtraTrees split: for each of k random features, draw ONE uniform
    // threshold within the node's value range; keep the best by Gini.
    let mut best: Option<(usize, f32, f64)> = None;
    for &f in &rng.sample_indices(ds.n_features, k) {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &i in idx {
            let v = ds.row(i)[f];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if lo >= hi {
            continue; // constant feature in this node
        }
        let t = rng.uniform_in(lo, hi);
        // Guarantee a non-degenerate split: t in [lo, hi) sends lo left.
        let t = if t >= hi { lo } else { t };
        let mut lc = vec![0usize; ds.n_classes];
        let mut nl = 0usize;
        for &i in idx {
            if ds.row(i)[f] <= t {
                lc[ds.labels[i] as usize] += 1;
                nl += 1;
            }
        }
        if nl == 0 || nl == idx.len() {
            continue;
        }
        let rc: Vec<usize> = counts.iter().zip(&lc).map(|(a, b)| a - b).collect();
        let w = (nl as f64 * gini(&lc, nl)
            + (idx.len() - nl) as f64 * gini(&rc, idx.len() - nl))
            / idx.len() as f64;
        let gain = parent_gini - w;
        if gain > best.map_or(f64::MIN, |b| b.2) {
            best = Some((f, t, gain));
        }
    }

    match best {
        None => {
            nodes.push(leaf_from(ds, idx));
            id
        }
        Some((f, t, _)) => {
            nodes.push(Node::Leaf { values: vec![] });
            let (mut li, mut ri) = (Vec::new(), Vec::new());
            for &i in idx {
                if ds.row(i)[f] <= t {
                    li.push(i);
                } else {
                    ri.push(i);
                }
            }
            let left = grow(ds, &li, params, k, rng, nodes, depth + 1);
            let right = grow(ds, &ri, params, k, rng, nodes, depth + 1);
            nodes[id as usize] = Node::Branch { feature: f as u32, threshold: t, left, right };
            id
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::inference::{Engine, FloatEngine, IntEngine};
    use crate::trees::accuracy;
    use crate::util::Rng;

    #[test]
    fn trains_and_validates() {
        let ds = shuttle_like(2000, 120);
        let m = train_extra_trees(&ds, &ExtraParams { n_trees: 8, max_depth: 6, ..Default::default() }, 1);
        assert!(m.validate().is_ok());
        assert_eq!(m.trees.len(), 8);
        assert!(m.max_depth() <= 6);
    }

    #[test]
    fn beats_majority_on_holdout() {
        let ds = shuttle_like(6000, 121);
        let (train, test) = ds.train_test_split(0.25, &mut Rng::new(2));
        let m = train_extra_trees(&train, &ExtraParams { n_trees: 20, max_depth: 8, ..Default::default() }, 3);
        let majority = *test.class_counts().iter().max().unwrap() as f64 / test.n_rows() as f64;
        let acc = accuracy(&m, &test);
        // Random-threshold splits are weaker per tree; require at least
        // matching the majority baseline and clearing a high floor.
        assert!(acc >= majority, "acc {acc} vs majority {majority}");
        assert!(acc > 0.9, "acc {acc}");
    }

    #[test]
    fn integer_pipeline_parity_holds() {
        // The paper's core claim extends to ExtraTrees unchanged: the
        // integer-only engine predicts identically to float.
        let ds = shuttle_like(1500, 122);
        let m = train_extra_trees(&ds, &ExtraParams { n_trees: 10, max_depth: 6, ..Default::default() }, 4);
        let fe = FloatEngine::compile(&m);
        let ie = IntEngine::compile(&m);
        for i in 0..ds.n_rows() {
            assert_eq!(fe.predict(ds.row(i)), ie.predict(ds.row(i)), "row {i}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = shuttle_like(800, 123);
        let p = ExtraParams { n_trees: 3, max_depth: 4, ..Default::default() };
        assert_eq!(train_extra_trees(&ds, &p, 9), train_extra_trees(&ds, &p, 9));
        assert_ne!(train_extra_trees(&ds, &p, 9), train_extra_trees(&ds, &p, 10));
    }

    #[test]
    fn faster_than_exhaustive_rf() {
        use std::time::Instant;
        let ds = shuttle_like(8000, 124);
        let t0 = Instant::now();
        let _ = train_extra_trees(&ds, &ExtraParams { n_trees: 10, max_depth: 7, ..Default::default() }, 1);
        let t_extra = t0.elapsed();
        let t0 = Instant::now();
        let _ = crate::trees::RandomForest::train(
            &ds,
            &crate::trees::ForestParams { n_trees: 10, max_depth: 7, ..Default::default() },
            1,
        );
        let t_rf = t0.elapsed();
        // Random thresholds skip the O(n log n) sort per node; allow slack
        // for noise but ExtraTrees should not be slower.
        assert!(t_extra <= t_rf * 2, "extra {t_extra:?} rf {t_rf:?}");
    }
}
