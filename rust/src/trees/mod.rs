//! Training substrate: CART decision trees, Random Forests and gradient
//! boosted trees, implemented from scratch.
//!
//! The paper treats training as a pluggable black box (scikit-learn,
//! XGBoost, LightGBM) that produces float split thresholds and float leaf
//! probabilities; InTreeger's transforms apply downstream of training.
//! This module is the self-contained equivalent so the end-to-end pipeline
//! (dataset in → integer-only C out) has no external dependencies.
//!
//! * [`builder`] — single CART classification tree (Gini impurity).
//! * [`forest`] — bootstrap-aggregated Random Forest
//!   (scikit-learn `RandomForestClassifier` semantics: per-tree class
//!   probability leaves, ensemble = average of tree probabilities).
//! * [`gbt`] — gradient boosted trees (softmax log-loss, Newton leaf
//!   weights — XGBoost-style, exercising the `ModelKind::Gbt` IR path).
//! * [`extra`] — Extremely Randomized Trees (random-threshold splits).
//!
//! Models from external frameworks (XGBoost / LightGBM dumps) enter the
//! same IR through [`crate::ir::import`].

pub mod builder;
pub mod extra;
pub mod forest;
pub mod gbt;

pub use builder::{train_tree, TreeParams};
pub use extra::{train_extra_trees, ExtraParams};
pub use forest::{ForestParams, RandomForest};
pub use gbt::{train_gbt, GbtParams};

use crate::data::Dataset;
use crate::ir::Model;

/// Fraction of rows a model classifies correctly on a dataset.
pub fn accuracy(model: &Model, ds: &Dataset) -> f64 {
    if ds.n_rows() == 0 {
        return 0.0;
    }
    let correct = (0..ds.n_rows())
        .filter(|&i| model.predict(ds.row(i)) == ds.labels[i])
        .count();
    correct as f64 / ds.n_rows() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;

    #[test]
    fn accuracy_of_perfect_and_empty() {
        let ds = shuttle_like(200, 1);
        let model = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
            7,
        );
        let acc = accuracy(&model, &ds);
        assert!((0.0..=1.0).contains(&acc));
        let empty = crate::data::Dataset::new(vec![], vec![], ds.n_features, ds.n_classes);
        assert_eq!(accuracy(&model, &empty), 0.0);
    }
}
