//! QuickScorer-style bitvector forest evaluation — the third traversal
//! strategy next to the branchy and branchless tile walkers.
//!
//! Following the QuickScorer family (Lucchese et al.; evaluated on ARM in
//! Koschel et al., *Fast Inference of Tree Ensembles on ARM Devices*),
//! this pass removes node traversal entirely. At compile time every
//! eligible tree (≤ [`QS_MAX_LEAVES`] leaves, so one `u64` bitvector
//! covers it) is lowered to:
//!
//! * an **in-order leaf numbering** — leaf `b` of a tree is bit `b` of the
//!   tree's bitvector, and `leaf_payloads` maps bits back to rows of the
//!   engines' leaf tables;
//! * one **condition** per branch node: `(threshold word, local tree,
//!   u64 false-leaf mask)`. The mask clears exactly the bits of the
//!   branch's *left* subtree — the leaves that become unreachable when
//!   the `<=`-goes-left split is **false** (the row goes right).
//!
//! Conditions are then bucketed **per feature and sorted ascending by
//! threshold**. Evaluating a row is two linear scans per feature: because
//! the IR split is `x <= t` goes left, the false conditions (`x > t`) are
//! exactly a *prefix* of the sorted stream, so the scan ANDs masks until
//! the first true condition and stops. After all features, each tree's
//! exit leaf is the **lowest set bit** of its bitvector:
//!
//! * the true exit leaf is never cleared (a false branch with the exit
//!   leaf in its left subtree would have to be an ancestor the walk went
//!   *left* at — contradiction), and
//! * every leaf left of it is cleared by the lowest common ancestor with
//!   the exit leaf, which the walk took rightward (condition false).
//!
//! Everything is u32/u64 integer arithmetic: with ordered-u32 thresholds
//! (the source paper's FlInt domain) the whole forest evaluation is
//! integer-only end to end, with **zero** precision loss — the scan
//! performs the exact same `x > t` comparisons as the walkers, so the
//! exit leaves are identical bit for bit, and the driver accumulates
//! leaf payloads per row in ascending tree order (the scalar sequence),
//! preserving the crate's batch-parity invariant for float sums too.
//!
//! ## Cache blocking (BlockQS)
//!
//! Trees are partitioned into blocks of [`QS_BLOCK_TREES`]; the driver
//! iterates row tiles × blocks so a block's condition streams and the
//! tile's bitvectors stay cache-resident while every row of the tile
//! scans them.
//!
//! ## Eligibility and fallback
//!
//! Trees with more than [`QS_MAX_LEAVES`] leaves do not fit a `u64` mask
//! and **fall back per-tree to the branchless lockstep walker** inside
//! the same driver (accumulation order is unchanged). The fallback is
//! logged at plan-build time — never silent — and surfaced by
//! `ir::stats` and the CLI `inspect` command.

use super::batch::{row_base_lanes, walk_tile_predicated, Domain, PackedTrees, TILE_ROWS};
use super::parallel;
use super::simd::SimdBackend;
use crate::flint::ordered_u32;
use crate::ir::{Model, Node, Tree};

/// Widest tree a `u64` leaf bitvector can cover.
pub const QS_MAX_LEAVES: usize = 64;

/// Trees per cache block of the blocked driver: 64 bitvectors per row are
/// 512 bytes, so a full [`TILE_ROWS`] tile's live state stays within L1
/// while the block's condition streams stream through it.
pub const QS_BLOCK_TREES: usize = 64;

/// One cache block of the compiled plan: up to [`QS_BLOCK_TREES`] trees'
/// conditions, bucketed per feature (`feature_offsets`) and sorted
/// ascending by threshold within each bucket. The threshold is stored in
/// both 32-bit encodings (ordered-u32 and raw f32 bits) so one plan
/// serves both comparison domains; the sort order is shared because
/// [`ordered_u32`] is monotone in the float value.
#[derive(Clone, Debug)]
pub struct QsBlock {
    /// Trees in this block.
    pub n_trees: usize,
    /// Global tree id per local tree index.
    pub tree_ids: Vec<u32>,
    /// Initial bitvector per local tree: one bit per leaf, all set.
    pub init: Vec<u64>,
    /// Condition-stream bucket boundaries; length `n_features + 1`.
    pub feature_offsets: Vec<u32>,
    /// Ordered-u32 threshold words (FlInt / InTreeger / GBT domain).
    pub thresh_ord: Vec<u32>,
    /// Raw f32-bit threshold words (float-baseline domain).
    pub thresh_f32: Vec<u32>,
    /// Local tree index of each condition.
    pub tree_of: Vec<u16>,
    /// False-leaf mask of each condition (clears the left subtree).
    pub masks: Vec<u64>,
    /// Per local tree, start of its bit→payload row in `leaf_payloads`;
    /// length `n_trees + 1`.
    pub leaf_offsets: Vec<u32>,
    /// Leaf-table payload row per (local tree, in-order leaf bit).
    pub leaf_payloads: Vec<u32>,
}

/// A forest compiled for QuickScorer evaluation: cache blocks of eligible
/// trees plus the (loudly logged) walker-fallback tree set.
#[derive(Clone, Debug)]
pub struct QsPlan {
    /// Total trees in the model (eligible + fallback).
    pub n_trees: usize,
    /// Feature columns of the model.
    pub n_features: usize,
    /// Cache blocks of eligible trees (see [`QS_BLOCK_TREES`]).
    pub blocks: Vec<QsBlock>,
    /// Global ids of trees with more than [`QS_MAX_LEAVES`] leaves; the
    /// driver walks these with the branchless lockstep kernel.
    pub fallback: Vec<u32>,
}

impl QsPlan {
    /// Number of trees evaluated by bitvector (not the walker fallback).
    pub fn n_eligible(&self) -> usize {
        self.n_trees - self.fallback.len()
    }

    /// Compile a plan with the default cache-block width.
    pub fn build(model: &Model) -> QsPlan {
        Self::build_with(model, QS_BLOCK_TREES)
    }

    /// Compile a plan with an explicit trees-per-block width (the C
    /// emitter uses one block; tests shrink it to force block seams).
    ///
    /// Leaf payload indices count leaves in IR node order across the
    /// whole model — exactly the assignment `CompiledForest::compile`
    /// and `GbtIntEngine::compile` use for their leaf tables, so the
    /// plan indexes either engine's tables directly.
    pub fn build_with(model: &Model, block_trees: usize) -> QsPlan {
        assert!(block_trees >= 1);
        let n_trees = model.trees.len();
        let mut fallback: Vec<u32> = Vec::new();
        let mut eligible: Vec<u32> = Vec::new();
        for (t, tree) in model.trees.iter().enumerate() {
            if tree.n_leaves() <= QS_MAX_LEAVES {
                eligible.push(t as u32);
            } else {
                fallback.push(t as u32);
            }
        }
        if !fallback.is_empty() {
            // Loud by design: a model silently missing the fast path is a
            // deployment surprise; `inspect` shows the same information.
            eprintln!(
                "quickscorer: {}/{} trees ineligible (> {QS_MAX_LEAVES} leaves), \
                 falling back to the branchless walker (tree ids {:?})",
                fallback.len(),
                n_trees,
                fallback
            );
        }
        // Leaf payload row per tree, in IR node order (global counter).
        let mut payload_base = vec![0u32; n_trees];
        let mut counter = 0u32;
        for (t, tree) in model.trees.iter().enumerate() {
            payload_base[t] = counter;
            counter += tree.n_leaves() as u32;
        }

        let mut blocks = Vec::new();
        for chunk in eligible.chunks(block_trees) {
            blocks.push(build_block(model, chunk, &payload_base));
        }
        QsPlan { n_trees, n_features: model.n_features, blocks, fallback }
    }
}

/// One condition during block construction (pre-sort).
struct Cond {
    feature: u32,
    word: u32,
    bits: u32,
    local: u16,
    mask: u64,
}

fn build_block(model: &Model, tree_ids: &[u32], payload_base: &[u32]) -> QsBlock {
    // `Cond::local` is u16; the default block width is 64, but the C
    // emitter builds one whole-forest block, so keep the bound explicit.
    assert!(tree_ids.len() <= u16::MAX as usize + 1, "quickscorer block too wide");
    let mut conds: Vec<Cond> = Vec::new();
    let mut init = Vec::with_capacity(tree_ids.len());
    let mut leaf_offsets = Vec::with_capacity(tree_ids.len() + 1);
    let mut leaf_payloads: Vec<u32> = Vec::new();
    for (local, &tid) in tree_ids.iter().enumerate() {
        let tree = &model.trees[tid as usize];
        let (ranges, inorder) = leaf_ranges(tree);
        let n_leaves = inorder.len();
        debug_assert!((1..=QS_MAX_LEAVES).contains(&n_leaves));
        init.push(if n_leaves == QS_MAX_LEAVES { u64::MAX } else { (1u64 << n_leaves) - 1 });
        leaf_offsets.push(leaf_payloads.len() as u32);
        // bit b → payload row: payload indices count leaves in IR node
        // order within the tree, offset by the model-wide base.
        let mut payload_of_node = vec![0u32; tree.nodes.len()];
        let mut k = 0u32;
        for (i, node) in tree.nodes.iter().enumerate() {
            if matches!(node, Node::Leaf { .. }) {
                payload_of_node[i] = payload_base[tid as usize] + k;
                k += 1;
            }
        }
        leaf_payloads.extend(inorder.iter().map(|&i| payload_of_node[i]));
        for node in &tree.nodes {
            if let Node::Branch { feature, threshold, left, right: _ } = node {
                let (lo, hi) = ranges[*left as usize];
                let width = (hi - lo) as u64;
                // A branch's left subtree holds at most n_leaves - 1 <= 63
                // leaves (the right subtree has at least one), so the
                // shift cannot overflow.
                debug_assert!(width < 64);
                let mask = !(((1u64 << width) - 1) << lo);
                conds.push(Cond {
                    feature: *feature,
                    word: ordered_u32(*threshold),
                    bits: threshold.to_bits(),
                    local: local as u16,
                    mask,
                });
            }
        }
    }
    leaf_offsets.push(leaf_payloads.len() as u32);
    // Bucket per feature, ascending threshold inside each bucket. The
    // ordered-u32 word is monotone in the float value, so one sort key
    // serves both comparison domains (ties need no ordering: equal words
    // are all-false or all-true together for any row).
    conds.sort_by_key(|c| (c.feature, c.word));
    let mut feature_offsets = vec![0u32; model.n_features + 1];
    for c in &conds {
        feature_offsets[c.feature as usize + 1] += 1;
    }
    for f in 0..model.n_features {
        feature_offsets[f + 1] += feature_offsets[f];
    }
    QsBlock {
        n_trees: tree_ids.len(),
        tree_ids: tree_ids.to_vec(),
        init,
        feature_offsets,
        thresh_ord: conds.iter().map(|c| c.word).collect(),
        thresh_f32: conds.iter().map(|c| c.bits).collect(),
        tree_of: conds.iter().map(|c| c.local).collect(),
        masks: conds.iter().map(|c| c.mask).collect(),
        leaf_offsets,
        leaf_payloads,
    }
}

/// In-order (left-to-right) leaf numbering of one tree: returns per-node
/// leaf-index ranges `[lo, hi)` plus the leaf node ids in bit order.
/// Iterative, like every other tree pass in the crate.
fn leaf_ranges(tree: &Tree) -> (Vec<(u32, u32)>, Vec<usize>) {
    let n = tree.nodes.len();
    let mut ranges = vec![(0u32, 0u32); n];
    let mut inorder: Vec<usize> = Vec::new();
    // (node, children_done) post-order with left pushed last (visited
    // first), so leaves are numbered left to right.
    let mut stack: Vec<(usize, bool)> = vec![(0, false)];
    while let Some((i, children_done)) = stack.pop() {
        match &tree.nodes[i] {
            Node::Leaf { .. } => {
                let b = inorder.len() as u32;
                ranges[i] = (b, b + 1);
                inorder.push(i);
            }
            Node::Branch { left, right, .. } => {
                if children_done {
                    ranges[i] = (ranges[*left as usize].0, ranges[*right as usize].1);
                } else {
                    stack.push((i, true));
                    stack.push((*right as usize, false));
                    stack.push((*left as usize, false));
                }
            }
        }
    }
    (ranges, inorder)
}

/// Scan one row against one block's condition streams, ANDing false-leaf
/// masks into `bv` (pre-initialized from `block.init`). `words` selects
/// the threshold encoding of the caller's domain.
///
/// Ascending thresholds make the false conditions (`go right`) a
/// *prefix* of each feature's stream; the scan computes the prefix
/// length — scalar early-exit compare, or the SIMD 8-/4-wide compare of
/// [`super::simd`] per `backend` — then ANDs exactly that many masks.
/// The masks ANDed (and their order) are identical across backends, so
/// the resulting bitvectors are bit-equal by construction.
#[inline]
fn eval_block<D: Domain>(
    block: &QsBlock,
    words: &[u32],
    row: &[D::Elem],
    backend: SimdBackend,
    bv: &mut [u64],
) {
    let offs = &block.feature_offsets;
    for (f, &x) in row.iter().enumerate() {
        let (s, e) = (offs[f] as usize, offs[f + 1] as usize);
        let stream = &words[s..e];
        let prefix = match backend {
            SimdBackend::Scalar => {
                stream.iter().take_while(|&&w| D::go_right(x, w)).count()
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: every non-scalar backend passes the
            // `is_available()` assert in `accumulate_batch` (the single
            // funnel into this driver) — AVX2 was detected at runtime.
            // The scan reads only within the `stream` slice.
            SimdBackend::Avx2 => unsafe { D::qs_prefix_avx2(x, stream) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: as above — NEON was detected before selection.
            SimdBackend::Neon => unsafe { D::qs_prefix_neon(x, stream) },
            other => unreachable!(
                "backend {} cannot execute on this architecture",
                other.name()
            ),
        };
        for i in s..s + prefix {
            bv[block.tree_of[i] as usize] &= block.masks[i];
        }
    }
}

/// QuickScorer batch driver: row tiles × tree blocks, walker fallback for
/// ineligible trees, then per-row accumulation in **ascending tree
/// order** — the scalar engines' exact sequence, so float sums see the
/// same rounding order and results stay bit-identical to the walkers.
///
/// `threads > 1` runs two phases on the work-stealing pool
/// ([`super::parallel`]): independent (block × row-range) and
/// (fallback-walk × row-range) tasks fill a batch-wide exit-payload
/// matrix — leaf *indices* only, no accumulation arithmetic, so the fill
/// order is irrelevant — then, after the pool joins, each row's payloads
/// fold into `acc` in ascending tree order. The reduction sequence is
/// fixed and task-index independent, so f32/u32/i64 outputs are
/// bit-identical at any thread count.
#[allow(clippy::too_many_arguments)] // internal monomorphized driver, mirrors accumulate_batch
pub(crate) fn accumulate_qs<D: Domain, T>(
    plan: &QsPlan,
    trees: &PackedTrees,
    rows: &[D::Elem],
    n_rows: usize,
    n_classes: usize,
    leaf_table: &[T],
    backend: SimdBackend,
    threads: usize,
    acc: &mut [T],
) where
    T: Copy + std::ops::AddAssign<T> + Send + Sync,
{
    assert_eq!(acc.len(), n_rows * n_classes);
    assert!(n_rows * trees.stride <= rows.len());
    debug_assert_eq!(plan.n_trees, trees.tree_offsets.len() - 1);
    debug_assert_eq!(plan.n_features, trees.stride);
    let n_trees = plan.n_trees;
    let stride = trees.stride;
    if threads <= 1 {
        let max_block = plan.blocks.iter().map(|b| b.n_trees).max().unwrap_or(0);
        let mut bv = vec![0u64; max_block];
        // Exit payload per (row-in-tile, tree): filled out of order
        // (blocks, then fallback trees), consumed in tree order.
        let mut payloads = vec![0u32; TILE_ROWS * n_trees];
        let mut leaves = [0u32; TILE_ROWS];
        let mut tile_start = 0;
        while tile_start < n_rows {
            let tile_rows = TILE_ROWS.min(n_rows - tile_start);
            for block in &plan.blocks {
                let words = D::qs_words(block);
                for r in 0..tile_rows {
                    let base = (tile_start + r) * stride;
                    let row = &rows[base..base + stride];
                    let bv = &mut bv[..block.n_trees];
                    bv.copy_from_slice(&block.init);
                    eval_block::<D>(block, words, row, backend, bv);
                    for (lt, &tid) in block.tree_ids.iter().enumerate() {
                        let leaf = bv[lt].trailing_zeros() as usize;
                        let lo = block.leaf_offsets[lt] as usize;
                        payloads[r * n_trees + tid as usize] = block.leaf_payloads[lo + leaf];
                    }
                }
            }
            // Tree-independent per-lane offsets for the fallback walks,
            // computed once per tile.
            let row_base = (!plan.fallback.is_empty())
                .then(|| row_base_lanes(trees.stride, tile_start, tile_rows));
            for &t in &plan.fallback {
                let t = t as usize;
                walk_tile_predicated::<D>(
                    trees,
                    t,
                    rows,
                    tile_start,
                    tile_rows,
                    row_base.as_ref().expect("computed when fallback is non-empty"),
                    backend,
                    &mut leaves,
                );
                for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                    payloads[r * n_trees + t] = p;
                }
            }
            for r in 0..tile_rows {
                let row_acc =
                    &mut acc[(tile_start + r) * n_classes..(tile_start + r + 1) * n_classes];
                for &p in &payloads[r * n_trees..r * n_trees + n_trees] {
                    let leaf = &leaf_table[p as usize * n_classes..(p as usize + 1) * n_classes];
                    for (a, &v) in row_acc.iter_mut().zip(leaf) {
                        *a += v;
                    }
                }
            }
            tile_start += tile_rows;
        }
        return;
    }
    // Multi-core path. The payload matrix covers the whole batch (the
    // single-thread path reuses a TILE_ROWS-deep one) so block tasks and
    // fallback tasks can run in any order on any worker: each (row,
    // tree) cell has exactly one writer — rows partition across chunks,
    // trees across units.
    let chunks = parallel::tile_chunks(n_rows, TILE_ROWS, threads);
    let mut payloads = vec![0u32; n_rows * n_trees];
    // Phase-1 units: every condition-stream block, plus one walker unit
    // covering all fallback trees when present.
    let n_units = plan.blocks.len() + usize::from(!plan.fallback.is_empty());
    {
        let slab = parallel::SharedSlab::new(&mut payloads);
        parallel::run_tasks(threads, chunks.len() * n_units, |task| {
            let (lo, hi) = chunks[task / n_units];
            let unit = task % n_units;
            if let Some(block) = plan.blocks.get(unit) {
                let words = D::qs_words(block);
                let mut bv = vec![0u64; block.n_trees];
                for row_i in lo..hi {
                    let base = row_i * stride;
                    let row = &rows[base..base + stride];
                    bv.copy_from_slice(&block.init);
                    eval_block::<D>(block, words, row, backend, &mut bv);
                    for (lt, &tid) in block.tree_ids.iter().enumerate() {
                        let leaf = bv[lt].trailing_zeros() as usize;
                        let off = block.leaf_offsets[lt] as usize;
                        // SAFETY: cell (row_i, tid) belongs to exactly
                        // this (chunk, block) task — disjoint writes.
                        unsafe {
                            slab.write(
                                row_i * n_trees + tid as usize,
                                block.leaf_payloads[off + leaf],
                            );
                        }
                    }
                }
            } else {
                // The fallback walker unit of this row range.
                let mut leaves = [0u32; TILE_ROWS];
                let mut tile_start = lo;
                while tile_start < hi {
                    let tile_rows = TILE_ROWS.min(hi - tile_start);
                    let row_base = row_base_lanes(stride, tile_start, tile_rows);
                    for &t in &plan.fallback {
                        let t = t as usize;
                        walk_tile_predicated::<D>(
                            trees, t, rows, tile_start, tile_rows, &row_base, backend,
                            &mut leaves,
                        );
                        for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                            // SAFETY: fallback tree ids are written only
                            // by this unit; rows only by this chunk.
                            unsafe { slab.write((tile_start + r) * n_trees + t, p) };
                        }
                    }
                    tile_start += tile_rows;
                }
            }
        });
    }
    // Phase 2 — the pool join above is the barrier that makes every
    // payload visible. Fold per row in ascending tree order: a fixed
    // reduction sequence, independent of which worker filled what.
    let payloads = &payloads;
    let slab = parallel::SharedSlab::new(acc);
    parallel::run_tasks(threads, chunks.len(), |i| {
        let (lo, hi) = chunks[i];
        // SAFETY: disjoint row ranges of `acc` across tasks.
        let chunk_acc = unsafe { slab.slice_mut(lo * n_classes, (hi - lo) * n_classes) };
        for row_i in lo..hi {
            let row_acc =
                &mut chunk_acc[(row_i - lo) * n_classes..(row_i - lo + 1) * n_classes];
            for &p in &payloads[row_i * n_trees..(row_i + 1) * n_trees] {
                let leaf = &leaf_table[p as usize * n_classes..(p as usize + 1) * n_classes];
                for (a, &v) in row_acc.iter_mut().zip(leaf) {
                    *a += v;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::inference::batch::{int_fixed_batch_with, OrdDomain, TraversalKernel};
    use crate::inference::CompiledForest;
    use crate::ir::ModelKind;
    use crate::trees::{ForestParams, RandomForest};
    use crate::util::check::balanced_tree;
    use crate::util::Rng;

    fn stump_model() -> Model {
        Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![Tree {
                nodes: vec![
                    Node::Branch { feature: 0, threshold: 0.5, left: 1, right: 2 },
                    Node::Leaf { values: vec![0.9, 0.1] },
                    Node::Leaf { values: vec![0.2, 0.8] },
                ],
            }],
            base_score: vec![0.0, 0.0],
        }
    }

    #[test]
    fn stump_plan_golden() {
        let plan = QsPlan::build(&stump_model());
        assert_eq!(plan.n_trees, 1);
        assert!(plan.fallback.is_empty());
        assert_eq!(plan.n_eligible(), 1);
        assert_eq!(plan.blocks.len(), 1);
        let b = &plan.blocks[0];
        assert_eq!(b.n_trees, 1);
        assert_eq!(b.tree_ids, vec![0]);
        assert_eq!(b.init, vec![0b11]);
        assert_eq!(b.feature_offsets, vec![0, 1]);
        assert_eq!(b.thresh_ord, vec![ordered_u32(0.5)]);
        assert_eq!(b.thresh_f32, vec![0.5f32.to_bits()]);
        assert_eq!(b.tree_of, vec![0]);
        // The root's left subtree is bit 0: mask clears exactly that bit.
        assert_eq!(b.masks, vec![!1u64]);
        assert_eq!(b.leaf_offsets, vec![0, 2]);
        assert_eq!(b.leaf_payloads, vec![0, 1]);
    }

    #[test]
    fn streams_sorted_and_masks_cover_leaves() {
        let ds = shuttle_like(1500, 41);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 9, max_depth: 6, ..Default::default() },
            41,
        );
        let plan = QsPlan::build(&m);
        assert!(plan.fallback.is_empty(), "depth-6 trees are always eligible");
        let n_conds: usize = plan.blocks.iter().map(|b| b.masks.len()).sum();
        let n_branches: usize = m.trees.iter().map(|t| t.nodes.len() - t.n_leaves()).sum();
        assert_eq!(n_conds, n_branches, "one condition per branch");
        for b in &plan.blocks {
            assert_eq!(*b.feature_offsets.last().unwrap() as usize, b.thresh_ord.len());
            for f in 0..m.n_features {
                let (s, e) = (b.feature_offsets[f] as usize, b.feature_offsets[f + 1] as usize);
                for i in s..e.saturating_sub(1) {
                    assert!(b.thresh_ord[i] <= b.thresh_ord[i + 1], "stream not sorted");
                }
            }
            for (lt, &tid) in b.tree_ids.iter().enumerate() {
                let n_leaves = m.trees[tid as usize].n_leaves();
                let lo = b.leaf_offsets[lt] as usize;
                let hi = b.leaf_offsets[lt + 1] as usize;
                assert_eq!(hi - lo, n_leaves, "one payload per leaf");
                assert_eq!(b.init[lt].count_ones() as usize, n_leaves);
            }
        }
    }

    #[test]
    fn qs_matches_walkers_bit_for_bit() {
        let ds = shuttle_like(1500, 42);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 9, max_depth: 6, ..Default::default() },
            42,
        );
        let f = CompiledForest::compile(&m);
        for n in [1usize, 7, 8, 9, 200] {
            let flat = &ds.features[..n * ds.n_features];
            let qs = int_fixed_batch_with(&f, flat, TraversalKernel::QuickScorer);
            let walker = int_fixed_batch_with(&f, flat, TraversalKernel::Branchless);
            assert_eq!(qs, walker, "n={n}");
        }
    }

    #[test]
    fn small_blocks_seam_parity() {
        // Force multiple cache blocks and check the driver stitches them
        // (and their tree-id mapping) correctly against the branchy path.
        let ds = shuttle_like(1200, 43);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 11, max_depth: 5, ..Default::default() },
            43,
        );
        let f = CompiledForest::compile(&m);
        let plan = QsPlan::build_with(&m, 3);
        assert_eq!(plan.blocks.len(), 4, "11 trees at 3 per block");
        let n = 37usize;
        let flat = &ds.features[..n * ds.n_features];
        let rows_ord: Vec<u32> = flat.iter().map(|&x| ordered_u32(x)).collect();
        let want = int_fixed_batch_with(&f, flat, TraversalKernel::Branchy);
        for &backend in SimdBackend::available() {
            // threads > 1 exercises the two-phase payload-matrix path
            // (block × row-range tasks + the ordered fold).
            for threads in [1usize, 3] {
                let mut got = vec![0u32; n * f.n_classes];
                accumulate_qs::<OrdDomain, u32>(
                    &plan,
                    &f.packed_ord(),
                    &rows_ord,
                    n,
                    f.n_classes,
                    &f.leaf_u32,
                    backend,
                    threads,
                    &mut got,
                );
                assert_eq!(got, want, "{} {}t", backend.name(), threads);
            }
        }
    }

    #[test]
    fn eligibility_boundary_63_64_65() {
        let mut rng = Rng::new(0x95);
        let nf = 4usize;
        let nc = 3usize;
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: nf,
            n_classes: nc,
            trees: vec![
                balanced_tree(&mut rng, 63, nf, nc),
                balanced_tree(&mut rng, 64, nf, nc),
                balanced_tree(&mut rng, 65, nf, nc),
            ],
            base_score: vec![0.0; nc],
        };
        m.validate().expect("hand-built model must validate");
        let plan = QsPlan::build(&m);
        assert_eq!(plan.fallback, vec![2], "only the 65-leaf tree falls back");
        assert_eq!(plan.n_eligible(), 2);
        let b = &plan.blocks[0];
        assert_eq!(b.tree_ids, vec![0, 1]);
        assert_eq!(b.init[0], (1u64 << 63) - 1);
        assert_eq!(b.init[1], u64::MAX, "64-leaf tree uses the full mask");
        // Hybrid evaluation (bitvectors + walker fallback) still matches
        // the pure walker path bit for bit, including a ragged tail.
        let f = CompiledForest::compile(&m);
        let mut rows = Vec::new();
        for i in 0..21 {
            for j in 0..nf {
                rows.push(rng.uniform_in(-60.0, 60.0) + (i + j) as f32 * 0.01);
            }
        }
        let qs = int_fixed_batch_with(&f, &rows, TraversalKernel::QuickScorer);
        let walker = int_fixed_batch_with(&f, &rows, TraversalKernel::Branchless);
        assert_eq!(qs, walker);
    }

    #[test]
    fn single_leaf_trees_have_no_conditions() {
        let mut rng = Rng::new(5);
        let nc = 2usize;
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 2,
            n_classes: nc,
            trees: (0..3)
                .map(|_| {
                    let raw: Vec<f32> = (0..nc).map(|_| rng.uniform_in(0.1, 1.0)).collect();
                    let sum: f32 = raw.iter().sum();
                    Tree {
                        nodes: vec![Node::Leaf {
                            values: raw.iter().map(|&x| x / sum).collect(),
                        }],
                    }
                })
                .collect(),
            base_score: vec![0.0; nc],
        };
        m.validate().unwrap();
        let plan = QsPlan::build(&m);
        let b = &plan.blocks[0];
        assert!(b.masks.is_empty());
        assert_eq!(b.init, vec![1, 1, 1]);
        let f = CompiledForest::compile(&m);
        let rows = [0.3f32, -1.0, 2.0, 7.5];
        assert_eq!(
            int_fixed_batch_with(&f, &rows, TraversalKernel::QuickScorer),
            int_fixed_batch_with(&f, &rows, TraversalKernel::Branchy),
        );
    }
}
