//! Integer-only inference for gradient-boosted trees.
//!
//! GBT leaves hold additive *margins*, not probabilities, so the paper's
//! `2^32/n` probability scale does not apply. Instead a power-of-two
//! fixed-point scale is derived from the model's worst-case accumulated
//! margin ([`crate::quant::margin_scale`]) and leaves are quantized to
//! `i64`. Because softmax is monotone per-class rank, `argmax` over
//! accumulated margins equals `argmax` over probabilities — classification
//! needs no float ops (probability *reporting* still computes a softmax).
//!
//! The traversal machinery is the same packed 8-byte child-adjacent
//! encoding and generic tile walkers as the RF engines
//! ([`super::compiled::Node8`] / [`super::batch`]): the GBT forest is
//! canonicalized to BFS child-adjacent order at compile time, leaves
//! self-loop with their payload index in the threshold word, and the
//! batch path picks the branchy or the predicated branchless kernel via
//! [`TraversalKernel`].

use super::batch::{
    accumulate_batch, with_ordered_batch, with_ordered_row, OrdDomain, PackedTrees,
    TraversalKernel,
};
use super::compiled::{pack_tree, soa_planes, Node8, NodeOrder, LEAF, MAX_FEATURES, MAX_TREE_NODES};
use super::parallel;
use super::quickscorer::QsPlan;
use super::simd::SimdBackend;
use crate::flint::ordered_u32;
use crate::ir::{argmax, softmax, Model, ModelKind, Node};
use crate::quant::{margin_scale, margin_to_fixed, MarginScale};

/// GBT forest compiled to the packed child-adjacent layout with integer
/// margin leaves.
pub struct GbtIntEngine {
    n_classes: usize,
    n_features: usize,
    scale: MarginScale,
    tree_offsets: Vec<u32>,
    /// Fixed trip count of the branchless kernel, per tree.
    tree_depths: Vec<u32>,
    /// Packed 8-byte nodes, ordered-u32 thresholds (leaf payload in `tw`).
    nodes: Vec<Node8>,
    /// SIMD gather plane mirroring `nodes[i].tw` (see
    /// `CompiledForest::soa_tw_ord`).
    soa_tw: Vec<u32>,
    /// SIMD gather plane packing `nodes[i].ff | nodes[i].left << 16`.
    soa_ffl: Vec<u32>,
    /// Quantized margins, `n_leaves * n_classes`.
    leaf_q: Vec<i64>,
    /// Quantized base score per class.
    base_q: Vec<i64>,
    /// QuickScorer condition-stream plan (shared builder with the RF
    /// engines — GBT leaf payload indices follow the same IR order).
    qs: QsPlan,
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
}

impl GbtIntEngine {
    /// Compile a GBT model into the packed integer-margin layout.
    pub fn compile(model: &Model) -> GbtIntEngine {
        assert_eq!(model.kind, ModelKind::Gbt, "GbtIntEngine requires a GBT model");
        model.validate().expect("model must be valid");
        assert!(
            model.n_features <= MAX_FEATURES,
            "packed node encoding supports at most {MAX_FEATURES} features, model has {}",
            model.n_features
        );
        let scale = margin_scale(model);
        let mut e = GbtIntEngine {
            n_classes: model.n_classes,
            n_features: model.n_features,
            scale,
            tree_offsets: Vec::with_capacity(model.trees.len() + 1),
            tree_depths: model.trees.iter().map(|t| t.depth() as u32).collect(),
            nodes: Vec::new(),
            soa_tw: Vec::new(),
            soa_ffl: Vec::new(),
            leaf_q: Vec::new(),
            base_q: model.base_score.iter().map(|&b| margin_to_fixed(b, scale)).collect(),
            qs: QsPlan::build(model),
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        };
        // Per-tree scratch SoA in IR order, packed to the BFS
        // child-adjacent form (same canonical encoding as
        // `CompiledForest`, shared via `pack_tree`).
        let mut feature: Vec<u32> = Vec::new();
        let mut thresh: Vec<u32> = Vec::new();
        let mut left: Vec<u32> = Vec::new();
        let mut right: Vec<u32> = Vec::new();
        for tree in &model.trees {
            assert!(
                tree.nodes.len() <= MAX_TREE_NODES,
                "packed node encoding supports at most {MAX_TREE_NODES} nodes per tree, tree has {}",
                tree.nodes.len()
            );
            e.tree_offsets.push(e.nodes.len() as u32);
            feature.clear();
            thresh.clear();
            left.clear();
            right.clear();
            for node in &tree.nodes {
                match node {
                    Node::Branch { feature: f, threshold, left: l, right: r } => {
                        feature.push(*f);
                        thresh.push(ordered_u32(*threshold));
                        left.push(*l);
                        right.push(*r);
                    }
                    Node::Leaf { values } => {
                        let payload = (e.leaf_q.len() / model.n_classes) as u32;
                        feature.push(LEAF);
                        thresh.push(0);
                        left.push(payload);
                        right.push(0);
                        e.leaf_q.extend(values.iter().map(|&v| margin_to_fixed(v, scale)));
                    }
                }
            }
            e.nodes.extend(pack_tree(&feature, &thresh, &left, &right, NodeOrder::Breadth));
        }
        e.tree_offsets.push(e.nodes.len() as u32);
        // SIMD gather planes, mirrored from the packed nodes through the
        // same encoder as the RF compiler.
        let (tw, ffl) = soa_planes(&e.nodes);
        e.soa_tw = tw;
        e.soa_ffl = ffl;
        e
    }

    /// Borrow every compiled plane (the binary serializer's view — the
    /// writer memcpy's these slices section by section).
    pub(crate) fn parts(&self) -> GbtPartsRef<'_> {
        GbtPartsRef {
            n_features: self.n_features,
            n_classes: self.n_classes,
            scale: self.scale,
            tree_offsets: &self.tree_offsets,
            tree_depths: &self.tree_depths,
            nodes: &self.nodes,
            soa_tw: &self.soa_tw,
            soa_ffl: &self.soa_ffl,
            leaf_q: &self.leaf_q,
            base_q: &self.base_q,
            qs: &self.qs,
        }
    }

    /// Rebuild an engine from pre-compiled planes (the binary loader's
    /// constructor — the caller has already validated every structural
    /// invariant the kernels rely on). Execution knobs take the same
    /// defaults as [`Self::compile`].
    pub(crate) fn from_parts(p: GbtEngineParts) -> GbtIntEngine {
        GbtIntEngine {
            n_classes: p.n_classes,
            n_features: p.n_features,
            scale: p.scale,
            tree_offsets: p.tree_offsets,
            tree_depths: p.tree_depths,
            nodes: p.nodes,
            soa_tw: p.soa_tw,
            soa_ffl: p.soa_ffl,
            leaf_q: p.leaf_q,
            base_q: p.base_q,
            qs: p.qs,
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// The margin fixed-point scale derived at compile time.
    pub fn scale(&self) -> MarginScale {
        self.scale
    }

    /// Feature columns a row must have.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Classes the model predicts.
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Tile-walk kernel the batched methods use (pure performance knob).
    pub fn kernel(&self) -> TraversalKernel {
        self.kernel
    }

    /// Select the tile-walk kernel for subsequent batched calls.
    pub fn set_kernel(&mut self, kernel: TraversalKernel) {
        self.kernel = kernel;
    }

    /// SIMD execution backend the batched methods use (pure performance
    /// knob; defaults to [`SimdBackend::resolve`]).
    pub fn backend(&self) -> SimdBackend {
        self.backend
    }

    /// Select the SIMD backend for subsequent batched calls. Panics when
    /// `backend` is not executable on this host.
    pub fn set_backend(&mut self, backend: SimdBackend) {
        assert!(backend.is_available(), "backend {} not available on this host", backend.name());
        self.backend = backend;
    }

    /// Intra-batch thread count the batched methods use (pure
    /// performance knob; bit-identical results at every count).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Select the intra-batch thread count for subsequent batched calls
    /// (clamped loudly into `1..=`[`parallel::detected`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = parallel::clamp(threads);
    }

    fn packed(&self) -> PackedTrees<'_> {
        PackedTrees {
            nodes: &self.nodes,
            tw_plane: &self.soa_tw,
            ffl_plane: &self.soa_ffl,
            tree_offsets: &self.tree_offsets,
            tree_depths: &self.tree_depths,
            stride: self.n_features,
        }
    }

    /// Integer-only accumulated margins.
    pub fn predict_fixed(&self, row: &[f32]) -> Vec<i64> {
        assert!(row.len() >= self.n_features);
        with_ordered_row(row, |row_ord| {
            let mut acc = self.base_q.clone();
            for t in 0..self.tree_offsets.len() - 1 {
                let base = self.tree_offsets[t] as usize;
                let mut i = base;
                let payload = loop {
                    let n = self.nodes[i];
                    if n.is_leaf() {
                        break n.tw as usize;
                    }
                    let go_right = row_ord[n.feature_index()] > n.tw;
                    i = base + n.left as usize + go_right as usize;
                };
                let p = payload * self.n_classes;
                for (a, &v) in acc.iter_mut().zip(&self.leaf_q[p..p + self.n_classes]) {
                    *a += v;
                }
            }
            acc
        })
    }

    /// Integer-only classification.
    pub fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.predict_fixed(row))
    }

    /// Batched integer-only accumulated margins, one vector per row of a
    /// flat row-major batch.
    ///
    /// Same execution style as the RF engines: the whole batch is
    /// order-transformed once, then tiles of [`super::batch::TILE_ROWS`]
    /// rows walk each tree through the shared generic kernel (branchy or predicated
    /// branchless per [`Self::kernel`]). Accumulation per row stays in
    /// ascending tree order starting from the base score, so results are
    /// bit-identical to [`Self::predict_fixed`] (i64 adds are exact).
    pub fn predict_fixed_batch(&self, rows: &[f32]) -> Vec<Vec<i64>> {
        let nf = self.n_features;
        assert!(nf > 0);
        assert!(
            rows.len() % nf == 0,
            "batch length {} is not a multiple of n_features {}",
            rows.len(),
            nf
        );
        let n_rows = rows.len() / nf;
        let c = self.n_classes;
        with_ordered_batch(rows, |rows_ord| {
            let mut acc: Vec<i64> = Vec::with_capacity(n_rows * c);
            for _ in 0..n_rows {
                acc.extend_from_slice(&self.base_q);
            }
            // The row-range task split adds each task's trees onto its
            // rows' pre-seeded base scores directly, so the base is
            // applied exactly once at any thread count.
            accumulate_batch::<OrdDomain, i64>(
                &self.packed(),
                Some(&self.qs),
                rows_ord,
                n_rows,
                c,
                &self.leaf_q,
                self.kernel,
                self.backend,
                self.threads,
                &mut acc,
            );
            acc.chunks_exact(c).map(|row| row.to_vec()).collect()
        })
    }

    /// Batched integer-only classification (argmax of
    /// [`Self::predict_fixed_batch`]).
    pub fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        self.predict_fixed_batch(rows).iter().map(|m| argmax(m)).collect()
    }

    /// Probability reporting (float softmax — not on the integer hot path).
    pub fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        let inv = 1.0 / (1u64 << self.scale.shift) as f64;
        let margins: Vec<f32> =
            self.predict_fixed(row).iter().map(|&q| (q as f64 * inv) as f32).collect();
        softmax(&margins)
    }
}

/// Borrowed view of every compiled GBT plane, consumed by the binary
/// serializer ([`crate::runtime::binfmt::write_gbt`]).
pub(crate) struct GbtPartsRef<'a> {
    pub n_features: usize,
    pub n_classes: usize,
    pub scale: MarginScale,
    pub tree_offsets: &'a [u32],
    pub tree_depths: &'a [u32],
    pub nodes: &'a [Node8],
    pub soa_tw: &'a [u32],
    pub soa_ffl: &'a [u32],
    pub leaf_q: &'a [i64],
    pub base_q: &'a [i64],
    pub qs: &'a QsPlan,
}

/// Owned pre-compiled GBT planes, consumed by
/// [`GbtIntEngine::from_parts`] (the binary loader's constructor).
pub(crate) struct GbtEngineParts {
    pub n_features: usize,
    pub n_classes: usize,
    pub scale: MarginScale,
    pub tree_offsets: Vec<u32>,
    pub tree_depths: Vec<u32>,
    pub nodes: Vec<Node8>,
    pub soa_tw: Vec<u32>,
    pub soa_ffl: Vec<u32>,
    pub leaf_q: Vec<i64>,
    pub base_q: Vec<i64>,
    pub qs: QsPlan,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{train_gbt, GbtParams};

    #[test]
    fn gbt_int_matches_float_argmax() {
        let ds = shuttle_like(1500, 12);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 5, max_depth: 4, ..Default::default() }, 3);
        let e = GbtIntEngine::compile(&m);
        let mut mismatches = 0usize;
        for i in 0..ds.n_rows() {
            if e.predict(ds.row(i)) != m.predict(ds.row(i)) {
                mismatches += 1;
            }
        }
        // Margin quantization at shift >= ~40 bits: mismatches require a
        // margin tie below 2^-40 — effectively impossible.
        assert_eq!(mismatches, 0);
    }

    #[test]
    fn gbt_int_probas_close() {
        let ds = shuttle_like(600, 13);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() }, 4);
        let e = GbtIntEngine::compile(&m);
        for i in (0..ds.n_rows()).step_by(37) {
            let a = m.predict_proba(ds.row(i));
            let b = e.predict_proba(ds.row(i));
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn batched_margins_bit_identical_to_scalar_all_kernels_and_backends() {
        let ds = shuttle_like(800, 15);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 4, max_depth: 4, ..Default::default() }, 5);
        let mut e = GbtIntEngine::compile(&m);
        for kernel in TraversalKernel::all() {
            e.set_kernel(kernel);
            for &backend in SimdBackend::available() {
                e.set_backend(backend);
                // threads > 1 checks the scheduler keeps the pre-seeded
                // base score applied exactly once per row.
                for threads in [1usize, 3] {
                    e.set_threads(threads);
                    for n in [1usize, 7, 8, 9, 100] {
                        let flat = &ds.features[..n * ds.n_features];
                        let batched = e.predict_fixed_batch(flat);
                        let classes = e.predict_batch(flat);
                        for i in 0..n {
                            let tag =
                                format!("{}/{}/{}t", kernel.name(), backend.name(), threads);
                            assert_eq!(
                                batched[i],
                                e.predict_fixed(ds.row(i)),
                                "{tag} margins row {i} (n={n})"
                            );
                            assert_eq!(
                                classes[i],
                                e.predict(ds.row(i)),
                                "{tag} class row {i} (n={n})"
                            );
                        }
                    }
                }
                e.set_threads(1);
            }
        }
    }

    #[test]
    fn packed_nodes_are_child_adjacent() {
        let ds = shuttle_like(400, 16);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 4, ..Default::default() }, 6);
        let e = GbtIntEngine::compile(&m);
        for t in 0..e.tree_offsets.len() - 1 {
            let lo = e.tree_offsets[t] as usize;
            let hi = e.tree_offsets[t + 1] as usize;
            for i in lo..hi {
                let n = e.nodes[i];
                if n.is_leaf() {
                    assert_eq!(n.left as usize, i - lo, "leaf self-loop");
                } else {
                    assert!((n.left as usize) + 1 < hi - lo, "children inside tree");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "GBT model")]
    fn rejects_rf() {
        let ds = shuttle_like(200, 14);
        let m = crate::trees::RandomForest::train(
            &ds,
            &crate::trees::ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
            1,
        );
        GbtIntEngine::compile(&m);
    }
}
