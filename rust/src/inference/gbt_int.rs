//! Integer-only inference for gradient-boosted trees.
//!
//! GBT leaves hold additive *margins*, not probabilities, so the paper's
//! `2^32/n` probability scale does not apply. Instead a power-of-two
//! fixed-point scale is derived from the model's worst-case accumulated
//! margin ([`crate::quant::margin_scale`]) and leaves are quantized to
//! `i64`. Because softmax is monotone per-class rank, `argmax` over
//! accumulated margins equals `argmax` over probabilities — classification
//! needs no float ops (probability *reporting* still computes a softmax).

use super::batch::TILE_ROWS;
use super::compiled::LEAF;
use crate::flint::ordered_u32;
use crate::ir::{argmax, softmax, Model, ModelKind, Node};
use crate::quant::{margin_scale, margin_to_fixed, MarginScale};

/// GBT forest compiled to flat arrays with integer margin leaves.
pub struct GbtIntEngine {
    n_classes: usize,
    n_features: usize,
    scale: MarginScale,
    tree_offsets: Vec<u32>,
    feature: Vec<u32>,
    thresh_ord: Vec<u32>,
    left: Vec<u32>,
    right: Vec<u32>,
    /// Quantized margins, `n_leaves * n_classes`.
    leaf_q: Vec<i64>,
    /// Quantized base score per class.
    base_q: Vec<i64>,
}

impl GbtIntEngine {
    pub fn compile(model: &Model) -> GbtIntEngine {
        assert_eq!(model.kind, ModelKind::Gbt, "GbtIntEngine requires a GBT model");
        model.validate().expect("model must be valid");
        let scale = margin_scale(model);
        let mut e = GbtIntEngine {
            n_classes: model.n_classes,
            n_features: model.n_features,
            scale,
            tree_offsets: Vec::with_capacity(model.trees.len() + 1),
            feature: Vec::new(),
            thresh_ord: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
            leaf_q: Vec::new(),
            base_q: model.base_score.iter().map(|&b| margin_to_fixed(b, scale)).collect(),
        };
        for tree in &model.trees {
            e.tree_offsets.push(e.feature.len() as u32);
            for node in &tree.nodes {
                match node {
                    Node::Branch { feature, threshold, left, right } => {
                        e.feature.push(*feature);
                        e.thresh_ord.push(ordered_u32(*threshold));
                        e.left.push(*left);
                        e.right.push(*right);
                    }
                    Node::Leaf { values } => {
                        let payload = (e.leaf_q.len() / model.n_classes) as u32;
                        e.feature.push(LEAF);
                        e.thresh_ord.push(0);
                        e.left.push(payload);
                        e.right.push(0);
                        e.leaf_q.extend(values.iter().map(|&v| margin_to_fixed(v, scale)));
                    }
                }
            }
        }
        e.tree_offsets.push(e.feature.len() as u32);
        e
    }

    pub fn scale(&self) -> MarginScale {
        self.scale
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Integer-only accumulated margins.
    pub fn predict_fixed(&self, row: &[f32]) -> Vec<i64> {
        let mut row_ord = vec![0u32; row.len()];
        for (b, &x) in row_ord.iter_mut().zip(row) {
            *b = ordered_u32(x);
        }
        let mut acc = self.base_q.clone();
        for t in 0..self.tree_offsets.len() - 1 {
            let base = self.tree_offsets[t] as usize;
            let mut i = base;
            loop {
                let f = self.feature[i];
                if f == LEAF {
                    let p = self.left[i] as usize * self.n_classes;
                    for (a, &v) in acc.iter_mut().zip(&self.leaf_q[p..p + self.n_classes]) {
                        *a += v;
                    }
                    break;
                }
                let go_left = row_ord[f as usize] <= self.thresh_ord[i];
                i = base + if go_left { self.left[i] } else { self.right[i] } as usize;
            }
        }
        acc
    }

    /// Integer-only classification.
    pub fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.predict_fixed(row))
    }

    /// Batched integer-only accumulated margins, one vector per row of a
    /// flat row-major batch.
    ///
    /// Same tiled execution style as [`crate::inference::batch`]: the
    /// whole batch is order-transformed once (into that module's shared
    /// thread-local scratch), then [`TILE_ROWS`] rows walk each tree in
    /// lockstep. The walk itself is re-implemented here rather than
    /// reusing `batch::walk_tile_ord` because GBT traversal stays on the
    /// SoA columns (no AoS node array) and accumulates at the leaf
    /// in-loop. Accumulation per row stays in ascending tree order
    /// starting from the base score, so results are bit-identical to
    /// [`Self::predict_fixed`] (i64 adds are exact).
    pub fn predict_fixed_batch(&self, rows: &[f32]) -> Vec<Vec<i64>> {
        let nf = self.n_features;
        assert!(
            rows.len() % nf == 0,
            "batch length {} is not a multiple of n_features {}",
            rows.len(),
            nf
        );
        let n_rows = rows.len() / nf;
        let c = self.n_classes;
        crate::inference::batch::with_ordered_batch(rows, |rows_ord| {
            let mut acc: Vec<i64> = Vec::with_capacity(n_rows * c);
            for _ in 0..n_rows {
                acc.extend_from_slice(&self.base_q);
            }
            let n_trees = self.tree_offsets.len() - 1;
            let mut tile_start = 0;
            while tile_start < n_rows {
                let tile_rows = TILE_ROWS.min(n_rows - tile_start);
                for t in 0..n_trees {
                    let base = self.tree_offsets[t] as usize;
                    let mut idx = [base; TILE_ROWS];
                    let mut done = [false; TILE_ROWS];
                    let mut remaining = tile_rows;
                    while remaining > 0 {
                        for r in 0..tile_rows {
                            if done[r] {
                                continue;
                            }
                            let i = idx[r];
                            let f = self.feature[i];
                            if f == LEAF {
                                let p = self.left[i] as usize * c;
                                let row_acc =
                                    &mut acc[(tile_start + r) * c..(tile_start + r + 1) * c];
                                for (a, &v) in row_acc.iter_mut().zip(&self.leaf_q[p..p + c]) {
                                    *a += v;
                                }
                                done[r] = true;
                                remaining -= 1;
                            } else {
                                let x = rows_ord[(tile_start + r) * nf + f as usize];
                                idx[r] = base
                                    + if x <= self.thresh_ord[i] {
                                        self.left[i]
                                    } else {
                                        self.right[i]
                                    } as usize;
                            }
                        }
                    }
                }
                tile_start += tile_rows;
            }
            acc.chunks_exact(c).map(|row| row.to_vec()).collect()
        })
    }

    /// Batched integer-only classification (argmax of
    /// [`Self::predict_fixed_batch`]).
    pub fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        self.predict_fixed_batch(rows).iter().map(|m| argmax(m)).collect()
    }

    /// Probability reporting (float softmax — not on the integer hot path).
    pub fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        let inv = 1.0 / (1u64 << self.scale.shift) as f64;
        let margins: Vec<f32> =
            self.predict_fixed(row).iter().map(|&q| (q as f64 * inv) as f32).collect();
        softmax(&margins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{train_gbt, GbtParams};

    #[test]
    fn gbt_int_matches_float_argmax() {
        let ds = shuttle_like(1500, 12);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 5, max_depth: 4, ..Default::default() }, 3);
        let e = GbtIntEngine::compile(&m);
        let mut mismatches = 0usize;
        for i in 0..ds.n_rows() {
            if e.predict(ds.row(i)) != m.predict(ds.row(i)) {
                mismatches += 1;
            }
        }
        // Margin quantization at shift >= ~40 bits: mismatches require a
        // margin tie below 2^-40 — effectively impossible.
        assert_eq!(mismatches, 0);
    }

    #[test]
    fn gbt_int_probas_close() {
        let ds = shuttle_like(600, 13);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() }, 4);
        let e = GbtIntEngine::compile(&m);
        for i in (0..ds.n_rows()).step_by(37) {
            let a = m.predict_proba(ds.row(i));
            let b = e.predict_proba(ds.row(i));
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn batched_margins_bit_identical_to_scalar() {
        let ds = shuttle_like(800, 15);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 4, max_depth: 4, ..Default::default() }, 5);
        let e = GbtIntEngine::compile(&m);
        for n in [1usize, 7, 8, 9, 100] {
            let flat = &ds.features[..n * ds.n_features];
            let batched = e.predict_fixed_batch(flat);
            let classes = e.predict_batch(flat);
            for i in 0..n {
                assert_eq!(batched[i], e.predict_fixed(ds.row(i)), "margins row {i} (n={n})");
                assert_eq!(classes[i], e.predict(ds.row(i)), "class row {i} (n={n})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "GBT model")]
    fn rejects_rf() {
        let ds = shuttle_like(200, 14);
        let m = crate::trees::RandomForest::train(
            &ds,
            &crate::trees::ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
            1,
        );
        GbtIntEngine::compile(&m);
    }
}
