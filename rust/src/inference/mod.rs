//! Executable inference engines with semantics identical to the generated
//! C code — the crate's reference implementations of the paper's three
//! compared variants (§IV):
//!
//! * [`FloatEngine`] — the "naive" baseline: float threshold compares,
//!   float probability accumulation (paper Listing 4).
//! * [`FlIntEngine`] — FlInt thresholds (integer compares) but float
//!   probability accumulation (paper Listing 1 / §II-D).
//! * [`IntEngine`] — InTreeger: integer compares **and** `u32` fixed-point
//!   probability accumulation (paper Listing 2/3) — no float operation
//!   anywhere on the inference path.
//!
//! These engines are used for (a) accuracy/parity experiments (Fig 2,
//! §IV-B), (b) *measured* x86 performance (the paper's Fig 3 x86 column is
//! reproduced both by these engines under criterion and by gcc-compiled
//! generated C), and (c) as oracles for the codegen, simulator and XLA
//! paths.
//!
//! [`batch`] adds the batch-first execution core: a tiled traversal
//! kernel that walks [`TILE_ROWS`] rows per tree in lockstep over a
//! batch pre-transformed to ordered-u32 space once — bit-identical to
//! the per-row engines and ≥2x faster at serving batch sizes (see
//! `cargo bench --bench batch_throughput`). [`NodeOrder`] selects the
//! compiled node layout (both canonicalized to the child-adjacent
//! 8-byte [`compiled::Node8`] encoding), and [`TraversalKernel`] selects
//! the branchy early-exit walk, the predicated branchless fixed-trip
//! walk, or the [`quickscorer`] bitvector evaluation (feature-sorted
//! condition streams + `u64` false-leaf masks, no node walks at all).
//! Orthogonally, [`SimdBackend`] selects the execution backend of the
//! branchless walk and the QuickScorer scan: portable scalar code or
//! runtime-detected AVX2 / NEON intrinsics ([`simd`]) — and the
//! intra-batch thread count ([`parallel`]) splits one batch across a
//! work-stealing pool of cores with deterministic, fixed-order
//! reductions. Every kernel × backend × thread-count combination is
//! bit-identical; they are pure performance knobs.

pub mod batch;
pub mod compiled;
pub mod engines;
pub mod gbt_int;
pub mod parallel;
pub mod quickscorer;
pub mod simd;

pub use batch::{TraversalKernel, TILE_ROWS};
pub use compiled::{CompiledForest, Node8, NodeOrder, LEAF};
pub use parallel::THREADS_ENV;
pub use quickscorer::{QsPlan, QS_MAX_LEAVES};
pub use simd::{SimdBackend, BACKEND_ENV};
pub use engines::{
    compile_variant, compile_variant_full, compile_variant_with, Engine, FlIntEngine, FloatEngine,
    IntEngine, Variant,
};
pub use gbt_int::GbtIntEngine;

use crate::data::Dataset;

/// Predict classes for every row of a dataset (via the tiled batch
/// kernel — element-wise identical to calling `predict` per row).
pub fn predict_all<E: Engine + ?Sized>(engine: &E, ds: &Dataset) -> Vec<u32> {
    engine.predict_batch(&ds.features)
}

/// Classification accuracy of an engine over a dataset.
pub fn engine_accuracy<E: Engine + ?Sized>(engine: &E, ds: &Dataset) -> f64 {
    if ds.n_rows() == 0 {
        return 0.0;
    }
    let hits = (0..ds.n_rows()).filter(|&i| engine.predict(ds.row(i)) == ds.labels[i]).count();
    hits as f64 / ds.n_rows() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    #[test]
    fn predict_all_matches_model() {
        let ds = shuttle_like(300, 1);
        let model =
            RandomForest::train(&ds, &ForestParams { n_trees: 5, max_depth: 4, ..Default::default() }, 1);
        let engine = FloatEngine::compile(&model);
        let preds = predict_all(&engine, &ds);
        for i in 0..ds.n_rows() {
            assert_eq!(preds[i], model.predict(ds.row(i)));
        }
        let acc = engine_accuracy(&engine, &ds);
        assert!((0.0..=1.0).contains(&acc));
    }
}
