//! The three inference engines the paper compares (float / FlInt /
//! InTreeger), sharing the [`CompiledForest`] layout.

use super::compiled::CompiledForest;
use crate::flint::ordered_u32;
use crate::ir::{argmax, Model};
use crate::quant::fixed_to_prob;

/// Which of the paper's three implementations an engine realizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Float compares + float accumulation (paper "naive", Listing 4).
    Float,
    /// Integer compares + float accumulation (paper "FlInt").
    FlInt,
    /// Integer compares + u32 fixed-point accumulation (paper "InTreeger").
    IntTreeger,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Float => "float",
            Variant::FlInt => "flint",
            Variant::IntTreeger => "intreeger",
        }
    }

    pub fn all() -> [Variant; 3] {
        [Variant::Float, Variant::FlInt, Variant::IntTreeger]
    }
}

/// Common engine interface.
///
/// Precondition: feature rows contain only **finite** values. NaN is
/// rejected at the data boundary ([`crate::data::Dataset::new`]) because
/// the float and integer variants would route negative-NaN bit patterns
/// differently (IEEE sends NaN right, the ordered-u32 domain would send
/// sign-bit NaN left) — guarding here instead would tax the hot loop.
pub trait Engine: Send + Sync {
    /// Predicted per-class probabilities (the integer engine converts its
    /// fixed-point sums only for this reporting API; `predict` stays
    /// integer end-to-end).
    fn predict_proba(&self, row: &[f32]) -> Vec<f32>;
    /// Predicted class (argmax, lowest index wins ties).
    fn predict(&self, row: &[f32]) -> u32;
    fn variant(&self) -> Variant;
    fn n_classes(&self) -> usize;
}

// ---------------------------------------------------------------------------

/// Baseline engine: float compares, float accumulation.
pub struct FloatEngine {
    forest: CompiledForest,
}

impl FloatEngine {
    pub fn compile(model: &Model) -> FloatEngine {
        FloatEngine { forest: CompiledForest::compile(model) }
    }

    pub fn forest(&self) -> &CompiledForest {
        &self.forest
    }

    /// Accumulated (averaged) float probabilities — reference semantics of
    /// the paper's float C code.
    pub fn accumulate(&self, row: &[f32]) -> Vec<f32> {
        let f = &self.forest;
        let mut acc = vec![0.0f32; f.n_classes];
        for t in 0..f.n_trees {
            let p = f.walk_f32(t, row) as usize;
            let leaf = &f.leaf_f32[p * f.n_classes..(p + 1) * f.n_classes];
            for (a, &v) in acc.iter_mut().zip(leaf) {
                *a += v;
            }
        }
        let inv = 1.0 / f.n_trees as f32;
        for a in &mut acc {
            *a *= inv;
        }
        acc
    }
}

impl Engine for FloatEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.accumulate(row)
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.accumulate(row))
    }
    fn variant(&self) -> Variant {
        Variant::Float
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
}

// ---------------------------------------------------------------------------

/// FlInt engine: integer threshold compares, float accumulation.
pub struct FlIntEngine {
    forest: CompiledForest,
}

impl FlIntEngine {
    pub fn compile(model: &Model) -> FlIntEngine {
        FlIntEngine { forest: CompiledForest::compile(model) }
    }

    fn accumulate(&self, row: &[f32]) -> Vec<f32> {
        let f = &self.forest;
        // One order-preserving transform per feature per inference —
        // integer ops only (shift/xor), matching the generated C.
        let mut buf = [std::mem::MaybeUninit::uninit(); 128];
        let row_ord = transform_row(row, &mut buf);
        let mut acc = vec![0.0f32; f.n_classes];
        for t in 0..f.n_trees {
            let p = f.walk_ord(t, row_ord) as usize;
            let leaf = &f.leaf_f32[p * f.n_classes..(p + 1) * f.n_classes];
            for (a, &v) in acc.iter_mut().zip(leaf) {
                *a += v;
            }
        }
        let inv = 1.0 / f.n_trees as f32;
        for a in &mut acc {
            *a *= inv;
        }
        acc
    }
}

impl Engine for FlIntEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.accumulate(row)
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.accumulate(row))
    }
    fn variant(&self) -> Variant {
        Variant::FlInt
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
}

// ---------------------------------------------------------------------------

/// InTreeger engine: fully integer inference — FlInt compares plus `u32`
/// fixed-point probability accumulation. After compilation, `predict` and
/// `predict_fixed` perform no floating-point arithmetic at all.
pub struct IntEngine {
    forest: CompiledForest,
}

impl IntEngine {
    pub fn compile(model: &Model) -> IntEngine {
        IntEngine { forest: CompiledForest::compile(model) }
    }

    pub fn forest(&self) -> &CompiledForest {
        &self.forest
    }

    /// Fixed-point accumulated class scores (scale `2^32/n_trees`,
    /// averaged by construction). This is the integer-only hot path.
    pub fn predict_fixed(&self, row: &[f32]) -> Vec<u32> {
        let f = &self.forest;
        let mut buf = [std::mem::MaybeUninit::uninit(); 128];
        let row_ord = transform_row(row, &mut buf);
        let mut acc = vec![0u32; f.n_classes];
        for t in 0..f.n_trees {
            let p = f.walk_ord(t, row_ord) as usize;
            let leaf = &f.leaf_u32[p * f.n_classes..(p + 1) * f.n_classes];
            for (a, &v) in acc.iter_mut().zip(leaf) {
                // Plain wrapping-free u32 addition: quant::max_accumulated
                // proves the sum cannot exceed u32::MAX.
                *a += v;
            }
        }
        acc
    }
}

impl Engine for IntEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.predict_fixed(row).iter().map(|&q| fixed_to_prob(q)).collect()
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.predict_fixed(row))
    }
    fn variant(&self) -> Variant {
        Variant::IntTreeger
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
}

/// Transform a feature row into ordered-u32 space using an uninitialized
/// stack buffer (rows up to 128 features — covers both paper datasets).
/// §Perf: avoids a 512-byte memset per inference that showed up on the
/// 87-feature ESA profile.
#[inline]
fn transform_row<'a>(row: &[f32], buf: &'a mut [std::mem::MaybeUninit<u32>; 128]) -> &'a [u32] {
    assert!(row.len() <= 128, "feature count > 128 unsupported in scalar engines");
    for (b, &x) in buf[..row.len()].iter_mut().zip(row) {
        b.write(ordered_u32(x));
    }
    // SAFETY: exactly the first `row.len()` elements were initialized above.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const u32, row.len()) }
}

/// Compile the requested variant behind the common trait.
pub fn compile_variant(model: &Model, v: Variant) -> Box<dyn Engine> {
    match v {
        Variant::Float => Box::new(FloatEngine::compile(model)),
        Variant::FlInt => Box::new(FlIntEngine::compile(model)),
        Variant::IntTreeger => Box::new(IntEngine::compile(model)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{esa_like, shuttle_like};
    use crate::prop_ensure;
    use crate::quant::error_bound;
    use crate::trees::{ForestParams, RandomForest};
    use crate::util::check::for_all;

    fn setup(n_trees: usize, seed: u64) -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(2000, seed);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees, max_depth: 6, ..Default::default() },
            seed,
        );
        (ds, m)
    }

    /// Paper §IV-B: predictions of float and integer models are identical
    /// on every sample. This is experiment E2's unit-scale version.
    #[test]
    fn float_flint_int_predictions_identical() {
        for seed in [1u64, 2, 3] {
            let (ds, m) = setup(10, seed);
            let fe = FloatEngine::compile(&m);
            let fl = FlIntEngine::compile(&m);
            let ie = IntEngine::compile(&m);
            for i in 0..ds.n_rows() {
                let row = ds.row(i);
                let a = fe.predict(row);
                let b = fl.predict(row);
                let c = ie.predict(row);
                assert_eq!(a, b, "flint mismatch row {i}");
                assert_eq!(a, c, "int mismatch row {i}");
            }
        }
    }

    /// Fig 2: probability deltas bounded by n/2^32 (plus float-sum noise).
    #[test]
    fn probability_deltas_within_bound() {
        let (ds, m) = setup(50, 4);
        let fe = FloatEngine::compile(&m);
        let ie = IntEngine::compile(&m);
        let mut max_diff = 0.0f64;
        for i in 0..500 {
            let row = ds.row(i);
            let pf = fe.predict_proba(row);
            let pi = ie.predict_proba(row);
            for (a, b) in pf.iter().zip(&pi) {
                max_diff = max_diff.max((*a as f64 - *b as f64).abs());
            }
        }
        // Bound: fixed-point error n/2^32 + float accumulation error of the
        // float engine itself (~n_trees * eps). Order 1e-8 for 50 trees.
        let bound = error_bound(50) + 50.0 * f32::EPSILON as f64;
        assert!(max_diff <= bound, "max_diff {max_diff} > bound {bound}");
        assert!(max_diff > 0.0, "suspicious: zero probability delta");
    }

    #[test]
    fn flint_equals_float_probas_exactly() {
        // FlInt changes only the comparison mechanism — same leaves, same
        // float accumulation ⇒ bit-identical probabilities.
        let (ds, m) = setup(10, 5);
        let fe = FloatEngine::compile(&m);
        let fl = FlIntEngine::compile(&m);
        for i in 0..300 {
            assert_eq!(fe.predict_proba(ds.row(i)), fl.predict_proba(ds.row(i)));
        }
    }

    #[test]
    fn int_engine_is_integer_only() {
        // predict_fixed output must reconstruct the float average within
        // the fixed-point bound, starting from pure-u32 accumulation.
        let (ds, m) = setup(20, 6);
        let fe = FloatEngine::compile(&m);
        let ie = IntEngine::compile(&m);
        for i in 0..200 {
            let fixed = ie.predict_fixed(ds.row(i));
            let float = fe.predict_proba(ds.row(i));
            for (q, p) in fixed.iter().zip(&float) {
                let back = *q as f64 / crate::quant::TWO_32;
                assert!((back - *p as f64).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn esa_wide_rows_supported() {
        let ds = esa_like(500, 7);
        let m = RandomForest::train(&ds, &ForestParams { n_trees: 5, max_depth: 5, ..Default::default() }, 7);
        let ie = IntEngine::compile(&m);
        let fe = FloatEngine::compile(&m);
        for i in 0..ds.n_rows() {
            assert_eq!(ie.predict(ds.row(i)), fe.predict(ds.row(i)));
        }
    }

    #[test]
    fn variant_helpers() {
        assert_eq!(Variant::all().len(), 3);
        assert_eq!(Variant::Float.name(), "float");
        let (_, m) = setup(2, 8);
        for v in Variant::all() {
            let e = compile_variant(&m, v);
            assert_eq!(e.variant(), v);
            assert_eq!(e.n_classes(), 7);
        }
    }

    /// Parity between all three engines on random forests and random
    /// feature vectors (including out-of-distribution and negative
    /// values) — the paper's "no loss of accuracy" claim as a property.
    #[test]
    fn prop_engines_agree_on_random_inputs() {
        for_all(
            "engines_agree_on_random_inputs",
            16,
            0xEA5E,
            |r| {
                let seed = r.next_u64() % 50;
                let n_trees = 1 + r.below(23);
                let n_rows = 1 + r.below(11);
                let rows: Vec<Vec<f32>> = (0..n_rows)
                    .map(|_| (0..7).map(|_| r.uniform_in(-150.0, 200.0)).collect())
                    .collect();
                (seed, n_trees, rows)
            },
            |&(seed, n_trees, ref rows)| {
                let ds = shuttle_like(400, seed);
                let m = RandomForest::train(
                    &ds,
                    &ForestParams { n_trees, max_depth: 5, ..Default::default() },
                    seed,
                );
                let fe = FloatEngine::compile(&m);
                let fl = FlIntEngine::compile(&m);
                let ie = IntEngine::compile(&m);
                for row in rows {
                    let a = fe.predict(row);
                    prop_ensure!(a == fl.predict(row), "flint disagrees (seed {seed})");
                    prop_ensure!(a == ie.predict(row), "int disagrees (seed {seed})");
                }
                Ok(())
            },
        );
    }
}
