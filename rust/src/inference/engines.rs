//! The three inference engines the paper compares (float / FlInt /
//! InTreeger), sharing the [`CompiledForest`] layout.
//!
//! Every engine exposes two execution styles:
//!
//! * **per-row** (`predict` / `predict_proba` / `predict_fixed`) — the
//!   lowest-latency path, semantically identical to the generated C;
//! * **batched** (`predict_batch` / `predict_proba_batch` /
//!   `predict_fixed_batch`) — the [`super::batch`] tiled kernel: the
//!   whole batch is transformed into ordered-u32 space once and tiles of
//!   [`super::batch::TILE_ROWS`] rows walk each tree in lockstep.
//!
//! The batched results are **bit-identical** to the per-row results for
//! every variant (see the parity invariant in [`super::batch`] and the
//! `tests/batch_parity.rs` suite). Each engine additionally carries a
//! [`TraversalKernel`] selecting the branchy tile walk, the predicated
//! branchless tile walk, or the QuickScorer bitvector evaluation
//! ([`super::quickscorer`]) — also a pure performance knob (the serving
//! coordinator auto-calibrates it per model at startup).

use super::batch::{self, TraversalKernel};
use super::compiled::{CompiledForest, NodeOrder};
use super::parallel;
use super::simd::SimdBackend;
use crate::ir::{argmax, Model};
use crate::quant::fixed_to_prob;

/// Which of the paper's three implementations an engine realizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Float compares + float accumulation (paper "naive", Listing 4).
    Float,
    /// Integer compares + float accumulation (paper "FlInt").
    FlInt,
    /// Integer compares + u32 fixed-point accumulation (paper "InTreeger").
    IntTreeger,
}

impl Variant {
    /// CLI / report name of the variant.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Float => "float",
            Variant::FlInt => "flint",
            Variant::IntTreeger => "intreeger",
        }
    }

    /// All three variants, in the paper's comparison order.
    pub fn all() -> [Variant; 3] {
        [Variant::Float, Variant::FlInt, Variant::IntTreeger]
    }

    /// Parse a CLI variant name (inverse of [`Self::name`]; the CLI
    /// additionally accepts `int` as an alias for `intreeger`).
    pub fn from_name(name: &str) -> Option<Variant> {
        Variant::all().into_iter().find(|v| v.name() == name)
    }
}

/// Common engine interface.
///
/// Precondition: feature rows contain only **finite** values. NaN is
/// rejected at the data boundary ([`crate::data::Dataset::new`]) because
/// the float and integer variants would route negative-NaN bit patterns
/// differently (IEEE sends NaN right, the ordered-u32 domain would send
/// sign-bit NaN left) — guarding here instead would tax the hot loop.
///
/// Batched methods take a flat row-major buffer whose length must be a
/// multiple of [`Engine::n_features`]; they are element-wise identical
/// to calling the per-row methods on each row.
pub trait Engine: Send + Sync {
    /// Predicted per-class probabilities (the integer engine converts its
    /// fixed-point sums only for this reporting API; `predict` stays
    /// integer end-to-end).
    fn predict_proba(&self, row: &[f32]) -> Vec<f32>;
    /// Predicted class (argmax, lowest index wins ties).
    fn predict(&self, row: &[f32]) -> u32;
    /// Predicted class per row of a flat row-major batch. Default: the
    /// per-row path; engines override with the tiled batch kernel.
    fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        assert!(
            rows.len() % self.n_features() == 0,
            "batch length {} is not a multiple of n_features {}",
            rows.len(),
            self.n_features()
        );
        rows.chunks_exact(self.n_features()).map(|r| self.predict(r)).collect()
    }
    /// Per-class probabilities per row of a flat row-major batch.
    fn predict_proba_batch(&self, rows: &[f32]) -> Vec<Vec<f32>> {
        assert!(
            rows.len() % self.n_features() == 0,
            "batch length {} is not a multiple of n_features {}",
            rows.len(),
            self.n_features()
        );
        rows.chunks_exact(self.n_features()).map(|r| self.predict_proba(r)).collect()
    }
    /// Per-class probabilities per row written into a caller-provided
    /// flat `n_rows * n_classes` buffer — the allocation-free sibling of
    /// [`Engine::predict_proba_batch`] that the serving layer reuses
    /// across batches (`out` is fully overwritten). Default: the
    /// per-row path; engines override with the flat batch kernel.
    fn predict_proba_batch_into(&self, rows: &[f32], out: &mut [f32]) {
        let nf = self.n_features();
        let c = self.n_classes();
        assert!(
            rows.len() % nf == 0,
            "batch length {} is not a multiple of n_features {nf}",
            rows.len()
        );
        assert_eq!(out.len(), rows.len() / nf * c, "output buffer must be n_rows * n_classes");
        for (row, slot) in rows.chunks_exact(nf).zip(out.chunks_exact_mut(c)) {
            slot.copy_from_slice(&self.predict_proba(row));
        }
    }
    /// Fixed-point accumulators per row, when the variant has an
    /// integer-only representation (`None` for the float-accumulating
    /// variants).
    fn predict_fixed_batch(&self, rows: &[f32]) -> Option<Vec<Vec<u32>>> {
        let _ = rows;
        None
    }
    /// Which of the paper's variants this engine realizes.
    fn variant(&self) -> Variant;
    /// Classes the engine predicts.
    fn n_classes(&self) -> usize;
    /// Feature columns a row must have.
    fn n_features(&self) -> usize;
    /// Tile-walk kernel the batched methods use (bit-identical results
    /// either way; a pure performance knob).
    fn kernel(&self) -> TraversalKernel;
    /// Select the tile-walk kernel for subsequent batched calls.
    fn set_kernel(&mut self, kernel: TraversalKernel);
    /// SIMD execution backend the batched methods use (bit-identical
    /// results on every backend; a pure performance knob). Defaults to
    /// [`SimdBackend::resolve`] at compile time (env override or best
    /// detected).
    fn backend(&self) -> SimdBackend;
    /// Select the SIMD backend for subsequent batched calls.
    ///
    /// Panics when `backend` is not executable on this host
    /// ([`SimdBackend::is_available`]) — the intrinsic paths must stay
    /// unreachable without the matching CPU feature.
    fn set_backend(&mut self, backend: SimdBackend);
    /// Intra-batch thread count the batched methods use (bit-identical
    /// results at every count; a pure performance knob). Defaults to
    /// [`parallel::resolve`] at compile time (env override or 1).
    fn threads(&self) -> usize;
    /// Select the intra-batch thread count for subsequent batched calls.
    /// Requests above the detected logical core count are clamped loudly
    /// ([`parallel::clamp`]); zero is raised to 1.
    fn set_threads(&mut self, threads: usize);
}

// ---------------------------------------------------------------------------

/// Baseline engine: float compares, float accumulation.
pub struct FloatEngine {
    forest: CompiledForest,
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
}

impl FloatEngine {
    /// Compile a model with the default (depth-first) node layout.
    pub fn compile(model: &Model) -> FloatEngine {
        Self::compile_with(model, NodeOrder::Depth)
    }

    /// Compile with an explicit node layout (see [`NodeOrder`]).
    pub fn compile_with(model: &Model, order: NodeOrder) -> FloatEngine {
        FloatEngine {
            forest: CompiledForest::compile_with(model, order),
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// Wrap an already-compiled forest (e.g. one materialized from the
    /// binary format, [`crate::runtime::binfmt`]) with default execution
    /// knobs.
    pub fn from_forest(forest: CompiledForest) -> FloatEngine {
        FloatEngine {
            forest,
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// The compiled forest backing this engine.
    pub fn forest(&self) -> &CompiledForest {
        &self.forest
    }

    /// Accumulated (averaged) float probabilities — reference semantics of
    /// the paper's float C code.
    pub fn accumulate(&self, row: &[f32]) -> Vec<f32> {
        let f = &self.forest;
        let mut acc = vec![0.0f32; f.n_classes];
        for t in 0..f.n_trees {
            let p = f.walk_f32(t, row) as usize;
            let leaf = &f.leaf_f32[p * f.n_classes..(p + 1) * f.n_classes];
            for (a, &v) in acc.iter_mut().zip(leaf) {
                *a += v;
            }
        }
        let inv = 1.0 / f.n_trees as f32;
        for a in &mut acc {
            *a *= inv;
        }
        acc
    }
}

impl Engine for FloatEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.accumulate(row)
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.accumulate(row))
    }
    fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        batch::argmax_rows(
            &batch::float_proba_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }
    fn predict_proba_batch(&self, rows: &[f32]) -> Vec<Vec<f32>> {
        // Thin per-row reshaping over the flat allocation-free path.
        batch::split_rows(
            batch::float_proba_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }
    fn predict_proba_batch_into(&self, rows: &[f32], out: &mut [f32]) {
        batch::float_proba_batch_into(
            &self.forest, rows, self.kernel, self.backend, self.threads, out,
        );
    }
    fn variant(&self) -> Variant {
        Variant::Float
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
    fn n_features(&self) -> usize {
        self.forest.n_features
    }
    fn kernel(&self) -> TraversalKernel {
        self.kernel
    }
    fn set_kernel(&mut self, kernel: TraversalKernel) {
        self.kernel = kernel;
    }
    fn backend(&self) -> SimdBackend {
        self.backend
    }
    fn set_backend(&mut self, backend: SimdBackend) {
        assert!(backend.is_available(), "backend {} not available on this host", backend.name());
        self.backend = backend;
    }
    fn threads(&self) -> usize {
        self.threads
    }
    fn set_threads(&mut self, threads: usize) {
        self.threads = parallel::clamp(threads);
    }
}

// ---------------------------------------------------------------------------

/// FlInt engine: integer threshold compares, float accumulation.
pub struct FlIntEngine {
    forest: CompiledForest,
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
}

impl FlIntEngine {
    /// Compile a model with the default (depth-first) node layout.
    pub fn compile(model: &Model) -> FlIntEngine {
        Self::compile_with(model, NodeOrder::Depth)
    }

    /// Compile with an explicit node layout (see [`NodeOrder`]).
    pub fn compile_with(model: &Model, order: NodeOrder) -> FlIntEngine {
        FlIntEngine {
            forest: CompiledForest::compile_with(model, order),
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// Wrap an already-compiled forest (e.g. one materialized from the
    /// binary format, [`crate::runtime::binfmt`]) with default execution
    /// knobs.
    pub fn from_forest(forest: CompiledForest) -> FlIntEngine {
        FlIntEngine {
            forest,
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// The compiled forest backing this engine.
    pub fn forest(&self) -> &CompiledForest {
        &self.forest
    }

    fn accumulate(&self, row: &[f32]) -> Vec<f32> {
        let f = &self.forest;
        // One order-preserving transform per feature per inference —
        // integer ops only (shift/xor), matching the generated C. The
        // transform writes into reusable thread-local scratch, so rows of
        // any width are supported without per-call allocation.
        batch::with_ordered_row(row, |row_ord| {
            let mut acc = vec![0.0f32; f.n_classes];
            for t in 0..f.n_trees {
                let p = f.walk_ord(t, row_ord) as usize;
                let leaf = &f.leaf_f32[p * f.n_classes..(p + 1) * f.n_classes];
                for (a, &v) in acc.iter_mut().zip(leaf) {
                    *a += v;
                }
            }
            let inv = 1.0 / f.n_trees as f32;
            for a in &mut acc {
                *a *= inv;
            }
            acc
        })
    }
}

impl Engine for FlIntEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.accumulate(row)
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.accumulate(row))
    }
    fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        batch::argmax_rows(
            &batch::flint_proba_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }
    fn predict_proba_batch(&self, rows: &[f32]) -> Vec<Vec<f32>> {
        // Thin per-row reshaping over the flat allocation-free path.
        batch::split_rows(
            batch::flint_proba_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }
    fn predict_proba_batch_into(&self, rows: &[f32], out: &mut [f32]) {
        batch::flint_proba_batch_into(
            &self.forest, rows, self.kernel, self.backend, self.threads, out,
        );
    }
    fn variant(&self) -> Variant {
        Variant::FlInt
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
    fn n_features(&self) -> usize {
        self.forest.n_features
    }
    fn kernel(&self) -> TraversalKernel {
        self.kernel
    }
    fn set_kernel(&mut self, kernel: TraversalKernel) {
        self.kernel = kernel;
    }
    fn backend(&self) -> SimdBackend {
        self.backend
    }
    fn set_backend(&mut self, backend: SimdBackend) {
        assert!(backend.is_available(), "backend {} not available on this host", backend.name());
        self.backend = backend;
    }
    fn threads(&self) -> usize {
        self.threads
    }
    fn set_threads(&mut self, threads: usize) {
        self.threads = parallel::clamp(threads);
    }
}

// ---------------------------------------------------------------------------

/// InTreeger engine: fully integer inference — FlInt compares plus `u32`
/// fixed-point probability accumulation. After compilation, `predict` and
/// `predict_fixed` perform no floating-point arithmetic at all.
pub struct IntEngine {
    forest: CompiledForest,
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
}

impl IntEngine {
    /// Compile a model with the default (depth-first) node layout.
    pub fn compile(model: &Model) -> IntEngine {
        Self::compile_with(model, NodeOrder::Depth)
    }

    /// Compile with an explicit node layout (see [`NodeOrder`]).
    pub fn compile_with(model: &Model, order: NodeOrder) -> IntEngine {
        IntEngine {
            forest: CompiledForest::compile_with(model, order),
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// Wrap an already-compiled forest (e.g. one materialized from the
    /// binary format, [`crate::runtime::binfmt`]) with default execution
    /// knobs.
    pub fn from_forest(forest: CompiledForest) -> IntEngine {
        IntEngine {
            forest,
            kernel: TraversalKernel::default(),
            backend: SimdBackend::resolve(),
            threads: parallel::resolve(),
        }
    }

    /// The compiled forest backing this engine.
    pub fn forest(&self) -> &CompiledForest {
        &self.forest
    }

    /// Fixed-point accumulated class scores (scale `2^32/n_trees`,
    /// averaged by construction). This is the integer-only hot path.
    pub fn predict_fixed(&self, row: &[f32]) -> Vec<u32> {
        let f = &self.forest;
        batch::with_ordered_row(row, |row_ord| {
            let mut acc = vec![0u32; f.n_classes];
            for t in 0..f.n_trees {
                let p = f.walk_ord(t, row_ord) as usize;
                let leaf = &f.leaf_u32[p * f.n_classes..(p + 1) * f.n_classes];
                for (a, &v) in acc.iter_mut().zip(leaf) {
                    // Plain wrapping-free u32 addition: quant::max_accumulated
                    // proves the sum cannot exceed u32::MAX.
                    *a += v;
                }
            }
            acc
        })
    }

    /// Batched fixed-point accumulators, one vector per row — the
    /// client-facing shape (bit-identical to [`Self::predict_fixed`]
    /// per row). A thin reshaping wrapper over
    /// [`Self::predict_fixed_batch_into`], which the coordinator's
    /// scalar route uses directly with a reused flat buffer.
    pub fn predict_fixed_batch(&self, rows: &[f32]) -> Vec<Vec<u32>> {
        batch::split_rows(
            batch::int_fixed_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }

    /// Batched fixed-point accumulators written into a caller-provided
    /// flat `n_rows * n_classes` buffer — the allocation-free serving
    /// hot path (`out` is fully overwritten; bit-identical to
    /// [`Self::predict_fixed`] per row).
    pub fn predict_fixed_batch_into(&self, rows: &[f32], out: &mut [u32]) {
        batch::int_fixed_batch_into(&self.forest, rows, self.kernel, self.backend, self.threads, out);
    }
}

impl Engine for IntEngine {
    fn predict_proba(&self, row: &[f32]) -> Vec<f32> {
        self.predict_fixed(row).iter().map(|&q| fixed_to_prob(q)).collect()
    }
    fn predict(&self, row: &[f32]) -> u32 {
        argmax(&self.predict_fixed(row))
    }
    fn predict_batch(&self, rows: &[f32]) -> Vec<u32> {
        batch::argmax_rows(
            &batch::int_fixed_batch_exec(
                &self.forest, rows, self.kernel, self.backend, self.threads,
            ),
            self.forest.n_classes,
        )
    }
    fn predict_proba_batch(&self, rows: &[f32]) -> Vec<Vec<f32>> {
        batch::int_fixed_batch_exec(&self.forest, rows, self.kernel, self.backend, self.threads)
            .chunks_exact(self.forest.n_classes)
            .map(|fixed| fixed.iter().map(|&q| fixed_to_prob(q)).collect())
            .collect()
    }
    fn predict_proba_batch_into(&self, rows: &[f32], out: &mut [f32]) {
        // Integer accumulation first, then one fixed→prob conversion
        // per cell into the caller's buffer.
        let fixed =
            batch::int_fixed_batch_exec(&self.forest, rows, self.kernel, self.backend, self.threads);
        assert_eq!(out.len(), fixed.len(), "output buffer must be n_rows * n_classes");
        for (slot, &q) in out.iter_mut().zip(&fixed) {
            *slot = fixed_to_prob(q);
        }
    }
    fn predict_fixed_batch(&self, rows: &[f32]) -> Option<Vec<Vec<u32>>> {
        // Delegates to the inherent batched path (same name, inherent
        // method wins resolution on the concrete type).
        Some(IntEngine::predict_fixed_batch(self, rows))
    }
    fn variant(&self) -> Variant {
        Variant::IntTreeger
    }
    fn n_classes(&self) -> usize {
        self.forest.n_classes
    }
    fn n_features(&self) -> usize {
        self.forest.n_features
    }
    fn kernel(&self) -> TraversalKernel {
        self.kernel
    }
    fn set_kernel(&mut self, kernel: TraversalKernel) {
        self.kernel = kernel;
    }
    fn backend(&self) -> SimdBackend {
        self.backend
    }
    fn set_backend(&mut self, backend: SimdBackend) {
        assert!(backend.is_available(), "backend {} not available on this host", backend.name());
        self.backend = backend;
    }
    fn threads(&self) -> usize {
        self.threads
    }
    fn set_threads(&mut self, threads: usize) {
        self.threads = parallel::clamp(threads);
    }
}

/// Compile the requested variant behind the common trait.
pub fn compile_variant(model: &Model, v: Variant) -> Box<dyn Engine> {
    compile_variant_with(model, v, NodeOrder::Depth)
}

/// Compile the requested variant with an explicit node layout.
pub fn compile_variant_with(model: &Model, v: Variant, order: NodeOrder) -> Box<dyn Engine> {
    match v {
        Variant::Float => Box::new(FloatEngine::compile_with(model, order)),
        Variant::FlInt => Box::new(FlIntEngine::compile_with(model, order)),
        Variant::IntTreeger => Box::new(IntEngine::compile_with(model, order)),
    }
}

/// Compile the requested variant with an explicit node layout and
/// tile-walk kernel.
pub fn compile_variant_full(
    model: &Model,
    v: Variant,
    order: NodeOrder,
    kernel: TraversalKernel,
) -> Box<dyn Engine> {
    let mut e = compile_variant_with(model, v, order);
    e.set_kernel(kernel);
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{esa_like, shuttle_like, SynthSpec};
    use crate::prop_ensure;
    use crate::quant::error_bound;
    use crate::trees::{ForestParams, RandomForest};
    use crate::util::check::for_all;

    fn setup(n_trees: usize, seed: u64) -> (crate::data::Dataset, Model) {
        let ds = shuttle_like(2000, seed);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees, max_depth: 6, ..Default::default() },
            seed,
        );
        (ds, m)
    }

    /// Paper §IV-B: predictions of float and integer models are identical
    /// on every sample. This is experiment E2's unit-scale version.
    #[test]
    fn float_flint_int_predictions_identical() {
        for seed in [1u64, 2, 3] {
            let (ds, m) = setup(10, seed);
            let fe = FloatEngine::compile(&m);
            let fl = FlIntEngine::compile(&m);
            let ie = IntEngine::compile(&m);
            for i in 0..ds.n_rows() {
                let row = ds.row(i);
                let a = fe.predict(row);
                let b = fl.predict(row);
                let c = ie.predict(row);
                assert_eq!(a, b, "flint mismatch row {i}");
                assert_eq!(a, c, "int mismatch row {i}");
            }
        }
    }

    /// Fig 2: probability deltas bounded by n/2^32 (plus float-sum noise).
    #[test]
    fn probability_deltas_within_bound() {
        let (ds, m) = setup(50, 4);
        let fe = FloatEngine::compile(&m);
        let ie = IntEngine::compile(&m);
        let mut max_diff = 0.0f64;
        for i in 0..500 {
            let row = ds.row(i);
            let pf = fe.predict_proba(row);
            let pi = ie.predict_proba(row);
            for (a, b) in pf.iter().zip(&pi) {
                max_diff = max_diff.max((*a as f64 - *b as f64).abs());
            }
        }
        // Bound: fixed-point error n/2^32 + float accumulation error of the
        // float engine itself (~n_trees * eps). Order 1e-8 for 50 trees.
        let bound = error_bound(50) + 50.0 * f32::EPSILON as f64;
        assert!(max_diff <= bound, "max_diff {max_diff} > bound {bound}");
        assert!(max_diff > 0.0, "suspicious: zero probability delta");
    }

    #[test]
    fn flint_equals_float_probas_exactly() {
        // FlInt changes only the comparison mechanism — same leaves, same
        // float accumulation ⇒ bit-identical probabilities.
        let (ds, m) = setup(10, 5);
        let fe = FloatEngine::compile(&m);
        let fl = FlIntEngine::compile(&m);
        for i in 0..300 {
            assert_eq!(fe.predict_proba(ds.row(i)), fl.predict_proba(ds.row(i)));
        }
    }

    #[test]
    fn int_engine_is_integer_only() {
        // predict_fixed output must reconstruct the float average within
        // the fixed-point bound, starting from pure-u32 accumulation.
        let (ds, m) = setup(20, 6);
        let fe = FloatEngine::compile(&m);
        let ie = IntEngine::compile(&m);
        for i in 0..200 {
            let fixed = ie.predict_fixed(ds.row(i));
            let float = fe.predict_proba(ds.row(i));
            for (q, p) in fixed.iter().zip(&float) {
                let back = *q as f64 / crate::quant::TWO_32;
                assert!((back - *p as f64).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn esa_wide_rows_supported() {
        let ds = esa_like(500, 7);
        let m = RandomForest::train(&ds, &ForestParams { n_trees: 5, max_depth: 5, ..Default::default() }, 7);
        let ie = IntEngine::compile(&m);
        let fe = FloatEngine::compile(&m);
        for i in 0..ds.n_rows() {
            assert_eq!(ie.predict(ds.row(i)), fe.predict(ds.row(i)));
        }
    }

    /// Regression: the seed's scalar engines panicked above 128 features
    /// (fixed-size stack buffer). The thread-local scratch removes the
    /// limit — a 200-feature model must work across all three variants,
    /// per-row and batched.
    #[test]
    fn very_wide_rows_supported_all_variants() {
        let spec = SynthSpec {
            n_rows: 300,
            n_features: 200,
            n_classes: 3,
            teacher_depth: 6,
            label_noise: 0.05,
            class_prior: vec![0.5, 0.3, 0.2],
            range: (-10.0, 10.0),
        };
        let ds = crate::data::synth::generate(&spec, 41);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() },
            41,
        );
        let engines = Variant::all().map(|v| compile_variant(&m, v));
        let reference = &engines[0];
        let flat = &ds.features[..64 * ds.n_features];
        for e in &engines {
            assert_eq!(e.n_features(), 200);
            let batched = e.predict_batch(flat);
            for i in 0..64 {
                let scalar = e.predict(ds.row(i));
                assert_eq!(batched[i], scalar, "{} batch/scalar row {i}", e.variant().name());
                assert_eq!(scalar, reference.predict(ds.row(i)), "{} vs float", e.variant().name());
            }
        }
    }

    /// The kernel and the SIMD backend are pure performance knobs:
    /// switching either changes no output bit, on any variant —
    /// including the QuickScorer bitvector kernel.
    #[test]
    fn kernel_and_backend_are_pure_performance_knobs() {
        let (ds, m) = setup(8, 9);
        let flat = &ds.features[..100 * ds.n_features];
        for v in Variant::all() {
            let mut e = compile_variant(&m, v);
            assert_eq!(e.kernel(), TraversalKernel::Branchless, "default kernel");
            assert!(e.backend().is_available(), "default backend must be executable");
            let branchless_probas = e.predict_proba_batch(flat);
            let branchless_classes = e.predict_batch(flat);
            for kernel in TraversalKernel::all() {
                e.set_kernel(kernel);
                assert_eq!(e.kernel(), kernel);
                for &backend in SimdBackend::available() {
                    e.set_backend(backend);
                    assert_eq!(e.backend(), backend);
                    for threads in [1usize, 2] {
                        e.set_threads(threads);
                        let tag = format!(
                            "{}/{}/{}/{}t",
                            v.name(),
                            kernel.name(),
                            backend.name(),
                            threads
                        );
                        assert_eq!(e.predict_proba_batch(flat), branchless_probas, "{tag}");
                        assert_eq!(e.predict_batch(flat), branchless_classes, "{tag}");
                    }
                    e.set_threads(1);
                }
                let via_full = compile_variant_full(&m, v, NodeOrder::Breadth, kernel);
                assert_eq!(via_full.kernel(), kernel);
                assert_eq!(via_full.predict_batch(flat), branchless_classes, "{}", v.name());
            }
        }
    }

    /// The flat `_into` variants are bit-identical to the allocating
    /// shapes on every engine — the serving layer swaps between them
    /// freely (satellite of the zero-copy front-end work).
    #[test]
    fn flat_into_matches_allocating_shapes() {
        let (ds, m) = setup(8, 12);
        let n_rows = 60usize;
        let flat = &ds.features[..n_rows * ds.n_features];
        for v in Variant::all() {
            let e = compile_variant(&m, v);
            let c = e.n_classes();
            let mut out = vec![0.0f32; n_rows * c];
            // Dirty the buffer: `_into` must fully overwrite it.
            out.fill(f32::NAN);
            e.predict_proba_batch_into(flat, &mut out);
            let nested = e.predict_proba_batch(flat);
            for (i, row) in nested.iter().enumerate() {
                assert_eq!(
                    &out[i * c..(i + 1) * c],
                    row.as_slice(),
                    "{} row {i}",
                    v.name()
                );
            }
        }
        let ie = IntEngine::compile(&m);
        let c = ie.forest().n_classes;
        let mut fixed_out = vec![u32::MAX; n_rows * c];
        ie.predict_fixed_batch_into(flat, &mut fixed_out);
        let nested = ie.predict_fixed_batch(flat);
        for (i, row) in nested.iter().enumerate() {
            assert_eq!(&fixed_out[i * c..(i + 1) * c], row.as_slice(), "fixed row {i}");
        }
    }

    /// `set_threads` clamps into `1..=detected` (loudly, never a panic —
    /// unlike an unavailable backend, an over-subscribed pool is merely
    /// pointless, not unsound).
    #[test]
    fn thread_requests_clamped_to_detected_cores() {
        let (_, m) = setup(2, 11);
        let mut e = compile_variant(&m, Variant::IntTreeger);
        assert!(e.threads() >= 1, "compile-time default is at least 1");
        e.set_threads(0);
        assert_eq!(e.threads(), 1, "zero raised to one");
        e.set_threads(usize::MAX);
        assert_eq!(
            e.threads(),
            crate::inference::parallel::detected(),
            "over-subscription clamps to the detected core count"
        );
    }

    /// Forcing a backend the host cannot execute must panic in
    /// `set_backend` — the intrinsic blocks stay unreachable without
    /// the CPU feature.
    #[test]
    fn unavailable_backend_rejected() {
        let unavailable = SimdBackend::all()
            .into_iter()
            .find(|b| !b.is_available());
        let Some(bad) = unavailable else {
            return; // host implausibly supports every backend
        };
        let (_, m) = setup(2, 10);
        let mut e = compile_variant(&m, Variant::IntTreeger);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.set_backend(bad)));
        assert!(r.is_err(), "set_backend({}) must panic", bad.name());
    }

    #[test]
    fn variant_helpers() {
        assert_eq!(Variant::all().len(), 3);
        assert_eq!(Variant::Float.name(), "float");
        let (_, m) = setup(2, 8);
        for v in Variant::all() {
            let e = compile_variant(&m, v);
            assert_eq!(e.variant(), v);
            assert_eq!(e.n_classes(), 7);
            assert_eq!(e.n_features(), 7);
        }
    }

    /// Parity between all three engines on random forests and random
    /// feature vectors (including out-of-distribution and negative
    /// values) — the paper's "no loss of accuracy" claim as a property.
    #[test]
    fn prop_engines_agree_on_random_inputs() {
        for_all(
            "engines_agree_on_random_inputs",
            16,
            0xEA5E,
            |r| {
                let seed = r.next_u64() % 50;
                let n_trees = 1 + r.below(23);
                let n_rows = 1 + r.below(11);
                let rows: Vec<Vec<f32>> = (0..n_rows)
                    .map(|_| (0..7).map(|_| r.uniform_in(-150.0, 200.0)).collect())
                    .collect();
                (seed, n_trees, rows)
            },
            |&(seed, n_trees, ref rows)| {
                let ds = shuttle_like(400, seed);
                let m = RandomForest::train(
                    &ds,
                    &ForestParams { n_trees, max_depth: 5, ..Default::default() },
                    seed,
                );
                let fe = FloatEngine::compile(&m);
                let fl = FlIntEngine::compile(&m);
                let ie = IntEngine::compile(&m);
                for row in rows {
                    let a = fe.predict(row);
                    prop_ensure!(a == fl.predict(row), "flint disagrees (seed {seed})");
                    prop_ensure!(a == ie.predict(row), "int disagrees (seed {seed})");
                }
                Ok(())
            },
        );
    }
}
