//! Intra-batch multi-core scheduling for the batch kernels — the
//! work-stealing tile scheduler behind `accumulate_batch` /
//! `accumulate_qs`.
//!
//! After the single-thread levers (tiling → branchless → QuickScorer →
//! AVX2/NEON), the remaining headroom on a serving host is plain cores.
//! The coordinator's worker pool already overlaps *independent* batches;
//! this module overlaps work **inside** one batch: the drivers split a
//! batch into tasks, a small dependency-free thread pool executes them,
//! and the results are written / reduced so every output bit is identical
//! to the single-thread engines.
//!
//! ## Task shapes
//!
//! * **Walker kernels** (branchy / branchless): tasks are contiguous
//!   **row-tile ranges** ([`super::batch::TILE_ROWS`]-aligned, a few
//!   tiles each). Every task walks *all* trees over its rows in
//!   ascending tree order and owns a disjoint slice of the accumulator,
//!   so the per-row accumulation sequence — the thing float parity
//!   depends on — is exactly the scalar sequence, and no reduction is
//!   needed at all.
//! * **QuickScorer**: tasks are **condition-stream block × row-range**
//!   pairs (reusing the plan's [`super::quickscorer::QS_BLOCK_TREES`]
//!   cache blocking), plus one fallback-walk task per row range. Each
//!   task fills its disjoint cells of a per-batch **exit-payload
//!   matrix** (`row × tree`, the per-task partial state); a second pass
//!   then folds the payloads into the accumulator **per row in ascending
//!   tree order** — a fixed, task-index-independent reduction order, so
//!   f32/u32/i64 sums see the same operand sequence as a single thread
//!   regardless of which worker finished first.
//!
//! The node arrays, SoA planes, condition streams and leaf tables are
//! shared read-only across workers; the only shared-mutable state is the
//! disjointly-partitioned output (see [`SharedSlab`]).
//!
//! ## Why work-stealing rather than a static split
//!
//! Task costs are uneven by construction: QuickScorer plans mix cheap
//! bitvector blocks with expensive per-tree walker fallbacks (trees over
//! `QS_MAX_LEAVES` leaves), the branchy walker's cost tracks the
//! data-dependent average leaf depth, and a ragged final tile is cheaper
//! than a full one. A static one-range-per-worker split would finish at
//! the pace of the unluckiest worker; here every worker drains its own
//! shard of the task list and then **steals** from the other shards
//! ([`Injector`] — a sharded atomic-cursor injector over `std::sync`,
//! no external crates), so stragglers shed load automatically.
//!
//! ## Selection
//!
//! Thread count is a pure performance knob, resolved like the SIMD
//! backend: [`resolve`] honors the [`THREADS_ENV`] environment variable
//! (CLI: `--threads`), loudly clamping to the detected logical core
//! count; engines default to **1** (single-thread, the calibration
//! baseline) and the serving coordinator's auto-calibration sweeps
//! kernel × backend × [`sweep`] thread counts to find the saturation
//! point for the loaded model on the current host.
//!
//! ## Placement
//!
//! Opt-in via [`PIN_ENV`] (`INTREEGER_PIN=1`): [`pin_plan`] parses the
//! shared-last-level-cache groups the kernel exposes in sysfs and
//! assigns worker threads to **distinct physical cores inside one LLC
//! group**, so a shard's working set (node arrays, SoA planes, the
//! request-slab rows it reads) stays resident in a single cache domain
//! instead of bouncing between them, and SMT siblings never fight over
//! one core's ports. Both the coordinator's shard threads and this
//! scheduler's pool workers apply the plan. Pinning degrades to a
//! **loud no-op** wherever the topology is unreadable or
//! `sched_setaffinity(2)` is refused (containers with restricted
//! cpusets) — placement is a performance lever, never a correctness
//! dependency.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Environment variable forcing the intra-batch thread count (a positive
/// integer; the CLI `--threads` flag sets it process-wide). Values above
/// the detected logical core count are clamped loudly; invalid values
/// fall back loudly to 1.
pub const THREADS_ENV: &str = "INTREEGER_THREADS";

/// Logical cores detected on this host (cached; at least 1).
pub fn detected() -> usize {
    static DETECTED: OnceLock<usize> = OnceLock::new();
    *DETECTED.get_or_init(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

/// One `(logical cpu, physical id, core id)` triple per `/proc/cpuinfo`
/// processor stanza, sorted by logical cpu. `None` when the file is
/// unreadable or no stanza carries all three ids (restricted
/// containers).
#[cfg(target_os = "linux")]
fn cpu_topology() -> Option<Vec<(usize, u32, u32)>> {
    parse_cpuinfo(&std::fs::read_to_string("/proc/cpuinfo").ok()?)
}

/// The `/proc/cpuinfo` stanza parse behind [`cpu_topology`], split out
/// so tests can feed synthetic topologies.
#[cfg(target_os = "linux")]
fn parse_cpuinfo(text: &str) -> Option<Vec<(usize, u32, u32)>> {
    let mut triples: Vec<(usize, u32, u32)> = Vec::new();
    let (mut cpu, mut phys, mut core) = (None, None, None);
    for line in text.lines() {
        let mut it = line.splitn(2, ':');
        let key = it.next().unwrap_or("").trim();
        let val = it.next().unwrap_or("").trim();
        match key {
            "processor" => cpu = val.parse::<usize>().ok(),
            "physical id" => phys = val.parse::<u32>().ok(),
            "core id" => core = val.parse::<u32>().ok(),
            // Blank line terminates one processor stanza.
            "" => {
                if let (Some(l), Some(p), Some(c)) = (cpu, phys, core) {
                    triples.push((l, p, c));
                }
                cpu = None;
                phys = None;
                core = None;
            }
            _ => {}
        }
    }
    if let (Some(l), Some(p), Some(c)) = (cpu, phys, core) {
        triples.push((l, p, c));
    }
    triples.sort_unstable();
    (!triples.is_empty()).then_some(triples)
}

/// Physical cores on this host, when the platform exposes them
/// (`/proc/cpuinfo` on Linux: distinct `(physical id, core id)` pairs).
/// `None` where unknown — reported by `inspect` next to [`detected`] so
/// SMT-inflated scaling expectations are visible.
pub fn physical_cores() -> Option<usize> {
    #[cfg(target_os = "linux")]
    {
        let topo = cpu_topology()?;
        let pairs: std::collections::HashSet<(u32, u32)> =
            topo.iter().map(|&(_, p, c)| (p, c)).collect();
        Some(pairs.len())
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Clamp a requested thread count into `1..=`[`detected`], loudly when
/// the request exceeds the host (mirrors the SIMD backend's refused-
/// loudly contract: an over-subscribed pool would only add scheduling
/// noise, never throughput).
pub fn clamp(n: usize) -> usize {
    let n = n.max(1);
    let d = detected();
    if n > d {
        eprintln!(
            "intreeger: {n} threads requested but only {d} logical cores detected; \
             clamping to {d}"
        );
        d
    } else {
        n
    }
}

/// Resolve the thread count engines default to: the [`THREADS_ENV`]
/// override when set (parsed and clamped loudly), otherwise **1**.
/// Single-thread is the deliberate default — it is the bit-exactness
/// baseline the parity suite compares against and keeps the perf
/// trajectory of the bench ledger comparable across PRs; multi-core
/// execution is opted into per process (env / `--threads`) or picked by
/// the serving auto-calibration.
pub fn resolve() -> usize {
    match std::env::var(THREADS_ENV) {
        Ok(raw) => match raw.trim().parse::<usize>() {
            Ok(n) if n >= 1 => clamp(n),
            _ => {
                eprintln!(
                    "intreeger: invalid {THREADS_ENV}='{raw}' (use a positive integer); \
                     using 1 thread"
                );
                1
            }
        },
        Err(_) => 1,
    }
}

/// The core count calibration should saturate at, with the basis of the
/// number: `(physical, "physical")` when [`physical_cores`] parses a
/// topology, `(logical, "logical")` otherwise. SMT siblings share
/// execution ports and the L1/L2 the tile kernels live in, so sweeping
/// past the physical count mostly times scheduler noise — calibration
/// prefers the physical ceiling and `inspect` / the calibration log
/// line record which basis was used. The count is clamped to
/// [`detected`] (a topology claiming more cores than the logical count
/// — containers with restricted cpusets — must not over-subscribe).
pub fn preferred() -> (usize, &'static str) {
    match physical_cores() {
        Some(p) if p >= 1 => (p.min(detected()), "physical"),
        _ => (detected(), "logical"),
    }
}

/// The thread counts a calibration sweep should time: just the forced
/// one when [`THREADS_ENV`] is set (the override pins the choice),
/// otherwise 1, the powers of two below the [`preferred`] core count,
/// and the preferred count itself — e.g. `[1, 2, 4, 6]` on a 6-core
/// host, `[1, 2, 4]` on 4-physical/8-logical SMT.
pub fn sweep() -> Vec<usize> {
    if std::env::var(THREADS_ENV).is_ok() {
        return vec![resolve()];
    }
    let (d, _) = preferred();
    let mut v = vec![1usize];
    let mut t = 2;
    while t < d {
        v.push(t);
        t *= 2;
    }
    if d > 1 {
        v.push(d);
    }
    v
}

// ---------------------------------------------------------------------------
// The scheduler: sharded work-stealing injector + scoped worker pool.

/// Oversubscription factor of the row-range task split: a few tasks per
/// worker so stealing can rebalance uneven costs (ragged tails, QS
/// fallback trees) without shrinking tasks to cache-hostile slivers.
const TASKS_PER_THREAD: usize = 4;

/// A fixed task list `0..n_tasks` sharded into one contiguous range per
/// worker, each with an atomic claim cursor. A worker drains its home
/// shard front-to-back (cache-friendly: neighboring tasks touch
/// neighboring rows), then steals from the other shards — the
/// dependency-free `std::sync` stand-in for per-worker Chase-Lev
/// deques, sufficient because tasks are claimed exactly once and never
/// re-pushed.
pub(crate) struct Injector {
    shards: Vec<Shard>,
}

struct Shard {
    /// Next unclaimed task of this shard; `fetch_add` claims it (values
    /// at/above `end` mean the shard is drained).
    next: AtomicUsize,
    /// One past the last task of this shard.
    end: usize,
}

impl Injector {
    /// Split `0..n_tasks` into `n_shards` contiguous ranges (the leading
    /// shards are one task longer when the split is uneven).
    pub(crate) fn new(n_tasks: usize, n_shards: usize) -> Injector {
        let n_shards = n_shards.max(1);
        let per = n_tasks / n_shards;
        let extra = n_tasks % n_shards;
        let mut shards = Vec::with_capacity(n_shards);
        let mut lo = 0;
        for s in 0..n_shards {
            let len = per + usize::from(s < extra);
            shards.push(Shard { next: AtomicUsize::new(lo), end: lo + len });
            lo += len;
        }
        debug_assert_eq!(lo, n_tasks);
        Injector { shards }
    }

    /// Claim the next task: the home shard first, then steal round-robin
    /// from the others. `None` once every shard is drained.
    pub(crate) fn claim(&self, home: usize) -> Option<usize> {
        let n = self.shards.len();
        for k in 0..n {
            let shard = &self.shards[(home + k) % n];
            // Relaxed is enough: the claim itself is the only shared
            // state, and the scope join at the end of `run_tasks` is the
            // synchronization point for the task *outputs*.
            let i = shard.next.fetch_add(1, Ordering::Relaxed);
            if i < shard.end {
                return Some(i);
            }
        }
        None
    }
}

/// Run `f(task)` for every task in `0..n_tasks` on up to `threads`
/// workers (scoped threads over a work-stealing [`Injector`]; the
/// calling thread is worker 0). `threads <= 1` — or a single task —
/// runs inline with zero scheduling overhead. Returns only after every
/// task completed, so task outputs are visible to the caller.
pub(crate) fn run_tasks<F>(threads: usize, n_tasks: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n_tasks);
    if threads <= 1 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let injector = Injector::new(n_tasks, threads);
    let injector = &injector;
    let f = &f;
    // Spawned pool workers re-pin per the active plan: on Linux a
    // scoped thread inherits its parent's affinity mask, so a pool
    // spawned from a pinned coordinator shard would otherwise stack
    // every worker on the shard's single CPU. Worker 0 is the calling
    // thread and keeps its placement (it may *be* a pinned shard).
    let plan = active_pin_plan(threads);
    let plan = plan.as_ref();
    std::thread::scope(|scope| {
        for w in 1..threads {
            scope.spawn(move || {
                if let Some(p) = plan {
                    p.pin(w);
                }
                while let Some(i) = injector.claim(w) {
                    f(i);
                }
            });
        }
        while let Some(i) = injector.claim(0) {
            f(i);
        }
    });
}

/// Split `n_rows` into contiguous `tile`-aligned row ranges `(lo, hi)`,
/// about [`TASKS_PER_THREAD`] per worker. Range boundaries land on tile
/// boundaries so the drivers' ragged-tail handling (duplicate-last-lane)
/// fires only on the true final tile of the batch — chunking must not
/// change which comparisons run, only who runs them.
pub(crate) fn tile_chunks(n_rows: usize, tile: usize, threads: usize) -> Vec<(usize, usize)> {
    debug_assert!(tile >= 1);
    let n_tiles = n_rows.div_ceil(tile);
    let n_chunks = n_tiles.min(threads.max(1) * TASKS_PER_THREAD).max(1);
    let tiles_per = n_tiles.div_ceil(n_chunks);
    let mut out = Vec::with_capacity(n_chunks);
    let mut lo_tile = 0;
    while lo_tile < n_tiles {
        let hi_tile = (lo_tile + tiles_per).min(n_tiles);
        out.push((lo_tile * tile, (hi_tile * tile).min(n_rows)));
        lo_tile = hi_tile;
    }
    out
}

/// A mutable output slab shared across scheduler tasks through raw
/// pointers, because safe `&mut` hand-out does not survive dynamic task
/// claiming. Soundness is the *callers'* obligation: concurrent tasks
/// must touch disjoint element ranges (the drivers partition by row
/// range, or by `(row, tree)` cell), so no element is ever written by
/// two tasks and no `&mut` reference overlaps another.
pub(crate) struct SharedSlab<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: the slab only moves a raw pointer between threads; access
// discipline (disjointness) is enforced by the unsafe contract of
// `slice_mut` / `write` at the call sites.
unsafe impl<T: Send> Send for SharedSlab<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlab<'_, T> {}

impl<'a, T> SharedSlab<'a, T> {
    /// Wrap an exclusive slice for the duration of a task run. The
    /// borrow keeps the underlying storage alive and un-aliased for the
    /// slab's lifetime.
    pub(crate) fn new(slice: &'a mut [T]) -> SharedSlab<'a, T> {
        SharedSlab { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    /// A mutable sub-slice `[start, start + len)`.
    ///
    /// # Safety
    ///
    /// No concurrently live `slice_mut`/`write` of this slab may overlap
    /// the range — callers must partition the slab into disjoint ranges
    /// across tasks.
    #[allow(clippy::mut_from_ref)] // the shared-&self-to-&mut escape is this type's entire purpose
    pub(crate) unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// Write one element.
    ///
    /// # Safety
    ///
    /// No concurrently live `slice_mut` may cover `idx`, and no other
    /// task may `write` the same `idx` — element-disjoint writes only.
    pub(crate) unsafe fn write(&self, idx: usize, value: T) {
        debug_assert!(idx < self.len);
        self.ptr.add(idx).write(value);
    }
}

// ---------------------------------------------------------------------------
// Cache-topology-aware thread placement (opt-in via INTREEGER_PIN).

/// Environment variable enabling cache-topology-aware thread pinning
/// (`1` / `on`). Off by default: pinning wins on a dedicated serving
/// host, but on a shared machine the kernel scheduler should stay free
/// to migrate around noisy neighbors — so placement is a deliberate
/// per-process opt-in, not a flag.
pub const PIN_ENV: &str = "INTREEGER_PIN";

/// True when [`PIN_ENV`] opts this process into thread pinning.
pub fn pin_enabled() -> bool {
    matches!(std::env::var(PIN_ENV).as_deref().map(str::trim), Ok("1") | Ok("on"))
}

/// Parse a kernel cpulist string (`"0-3,8-11"` — the sysfs
/// `shared_cpu_list` format) into sorted, deduplicated logical CPU
/// ids. `None` on an empty or malformed list (a reversed range counts
/// as malformed).
pub fn parse_cpu_list(s: &str) -> Option<Vec<usize>> {
    let mut cpus = Vec::new();
    for token in s.trim().split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        match token.split_once('-') {
            Some((lo, hi)) => {
                let lo = lo.trim().parse::<usize>().ok()?;
                let hi = hi.trim().parse::<usize>().ok()?;
                if hi < lo {
                    return None;
                }
                cpus.extend(lo..=hi);
            }
            None => cpus.push(token.parse::<usize>().ok()?),
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    (!cpus.is_empty()).then_some(cpus)
}

/// The last-level-cache sharing groups sysfs exposes
/// (`/sys/devices/system/cpu/cpu*/cache/index3/shared_cpu_list`): each
/// group is the sorted set of logical CPUs sharing one LLC, groups
/// ordered by their first CPU. `None` where sysfs (or an L3 index) is
/// unavailable — placement then falls back to the physical-core basis.
pub fn llc_groups() -> Option<Vec<Vec<usize>>> {
    #[cfg(target_os = "linux")]
    {
        let mut lists = std::collections::BTreeSet::new();
        for entry in std::fs::read_dir("/sys/devices/system/cpu").ok()?.flatten() {
            let name = entry.file_name();
            let name = name.to_str().unwrap_or("");
            let Some(digits) = name.strip_prefix("cpu") else { continue };
            if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
                continue;
            }
            let path = entry.path().join("cache/index3/shared_cpu_list");
            if let Ok(text) = std::fs::read_to_string(path) {
                lists.insert(text.trim().to_string());
            }
        }
        let mut groups: Vec<Vec<usize>> = lists.iter().filter_map(|s| parse_cpu_list(s)).collect();
        groups.sort_by_key(|g| g[0]);
        groups.dedup();
        (!groups.is_empty()).then_some(groups)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// A thread→CPU placement: `cpus[slot]` is the logical CPU for worker
/// `slot`, and `basis` records how the targets were derived.
#[derive(Debug, Clone)]
pub struct PinPlan {
    /// Logical CPU id per worker slot, in slot order (the target list
    /// wraps when more slots were requested than distinct cores exist).
    pub cpus: Vec<usize>,
    /// Derivation basis: `"llc"` (one CPU per distinct physical core
    /// inside the largest LLC group) or `"physical"` (one per distinct
    /// physical core; no LLC information was available).
    pub basis: &'static str,
}

impl PinPlan {
    /// Pin the calling thread to slot `slot`'s CPU; returns whether the
    /// pin took (see [`pin_current_thread`] for the degrade contract).
    pub fn pin(&self, slot: usize) -> bool {
        pin_current_thread(self.cpus[slot % self.cpus.len()])
    }
}

/// The deduplicated pin targets of this host — one logical CPU per
/// distinct physical core inside the largest LLC group — computed once
/// per process: the sysfs and `/proc/cpuinfo` reads must never land on
/// the per-batch path.
fn pin_targets() -> Option<&'static (Vec<usize>, &'static str)> {
    static TARGETS: OnceLock<Option<(Vec<usize>, &'static str)>> = OnceLock::new();
    TARGETS
        .get_or_init(|| {
            #[cfg(target_os = "linux")]
            {
                let topo = cpu_topology().unwrap_or_default();
                let one_per_core = |allow: Option<&[usize]>| -> Vec<usize> {
                    let mut seen = std::collections::HashSet::new();
                    let mut cpus = Vec::new();
                    for &(l, p, c) in &topo {
                        if allow.is_some_and(|a| !a.contains(&l)) {
                            continue;
                        }
                        if seen.insert((p, c)) {
                            cpus.push(l);
                        }
                    }
                    cpus
                };
                if let Some(group) =
                    llc_groups().and_then(|gs| gs.into_iter().max_by_key(|g| g.len()))
                {
                    let cpus = one_per_core(Some(&group));
                    // A restricted /proc/cpuinfo (no core ids) still
                    // leaves the LLC group itself as pin targets.
                    let cpus = if cpus.is_empty() { group } else { cpus };
                    return Some((cpus, "llc"));
                }
                let cpus = one_per_core(None);
                (!cpus.is_empty()).then_some((cpus, "physical"))
            }
            #[cfg(not(target_os = "linux"))]
            {
                None
            }
        })
        .as_ref()
}

/// The placement for `slots` worker threads, independent of the
/// [`PIN_ENV`] gate (so `inspect` can always display what *would* be
/// pinned): worker `i` gets the `i`-th pin target, wrapping when
/// `slots` exceeds the distinct-core count. `None` when the host
/// exposes no usable topology, or `slots` is 0.
pub fn pin_plan(slots: usize) -> Option<PinPlan> {
    if slots == 0 {
        return None;
    }
    let targets = pin_targets()?;
    let assignment = (0..slots).map(|i| targets.0[i % targets.0.len()]).collect();
    Some(PinPlan { cpus: assignment, basis: targets.1 })
}

/// The pin plan the serving path actually applies: `None` unless
/// [`PIN_ENV`] opts in *and* the host topology is usable — the
/// enabled-but-unusable case complains once per process and serving
/// proceeds unpinned (the loud-no-op contract).
pub fn active_pin_plan(slots: usize) -> Option<PinPlan> {
    if !pin_enabled() {
        return None;
    }
    match pin_plan(slots) {
        Some(p) => Some(p),
        None => {
            static WARNED: OnceLock<()> = OnceLock::new();
            WARNED.get_or_init(|| {
                eprintln!(
                    "intreeger: {PIN_ENV} is set but no usable CPU topology was found; \
                     running unpinned"
                );
            });
            None
        }
    }
}

/// Pin the calling thread to one logical CPU via `sched_setaffinity(2)`
/// (a one-symbol FFI declaration over the libc std already links — no
/// crate). Returns `false` — loudly, once per process — where the
/// platform has no affinity syscall or the kernel refuses the mask
/// (restricted cpuset, seccomp): the thread keeps running unpinned,
/// a performance fallback, never an error.
pub fn pin_current_thread(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        extern "C" {
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        // 16 × u64 = 1024 CPUs — the size of glibc's default cpu_set_t.
        let mut mask = [0u64; 16];
        if cpu >= mask.len() * 64 {
            pin_warn_once(cpu);
            return false;
        }
        mask[cpu / 64] = 1u64 << (cpu % 64);
        // SAFETY: pid 0 addresses the calling thread; the mask is a
        // valid initialized cpu_set_t-sized buffer owned by this frame.
        let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
        if rc != 0 {
            pin_warn_once(cpu);
            return false;
        }
        true
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// One warning per process for refused pins: a fleet of shards all
/// hitting the same restricted cpuset must not spam a line per thread.
#[cfg(target_os = "linux")]
fn pin_warn_once(cpu: usize) {
    static WARNED: OnceLock<()> = OnceLock::new();
    WARNED.get_or_init(|| {
        eprintln!(
            "intreeger: pinning to cpu {cpu} refused ({}); running unpinned",
            std::io::Error::last_os_error()
        );
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn injector_claims_every_task_exactly_once() {
        for (n_tasks, n_shards) in [(0usize, 3usize), (1, 1), (7, 3), (64, 4), (10, 16)] {
            let inj = Injector::new(n_tasks, n_shards);
            let mut seen = vec![0u32; n_tasks];
            // Drain from one "worker" after another, including stealing
            // across shard seams.
            for home in 0..n_shards {
                while let Some(i) = inj.claim(home) {
                    seen[i] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "tasks {n_tasks} shards {n_shards}: {seen:?}");
            assert_eq!(inj.claim(0), None, "drained injector must stay drained");
        }
    }

    #[test]
    fn run_tasks_covers_all_tasks_at_any_thread_count() {
        for threads in [1usize, 2, 3, 8] {
            let n_tasks = 37;
            let hits: Vec<AtomicU32> = (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
            run_tasks(threads, n_tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads {threads}"
            );
        }
    }

    #[test]
    fn tile_chunks_are_aligned_contiguous_and_exhaustive() {
        for (n_rows, tile, threads) in
            [(0usize, 8usize, 4usize), (1, 8, 4), (8, 8, 1), (17, 8, 2), (4096, 8, 3), (100, 8, 16)]
        {
            let chunks = tile_chunks(n_rows, tile, threads);
            let mut expect_lo = 0usize;
            for &(lo, hi) in &chunks {
                assert_eq!(lo, expect_lo, "contiguous");
                assert!(hi > lo, "non-empty");
                assert_eq!(lo % tile, 0, "tile-aligned start");
                assert!(hi % tile == 0 || hi == n_rows, "tile-aligned end or batch tail");
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n_rows, "rows {n_rows} tile {tile} threads {threads}");
            if n_rows == 0 {
                assert!(chunks.is_empty());
            }
        }
    }

    #[test]
    fn shared_slab_disjoint_ranges_round_trip() {
        let mut data = vec![0u32; 64];
        {
            let slab = SharedSlab::new(&mut data);
            run_tasks(4, 8, |i| {
                // SAFETY: tasks cover disjoint 8-element ranges.
                let chunk = unsafe { slab.slice_mut(i * 8, 8) };
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 8 + k) as u32;
                }
            });
        }
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn clamp_and_detection_sane() {
        assert!(detected() >= 1);
        assert_eq!(clamp(0), 1);
        assert_eq!(clamp(1), 1);
        assert_eq!(clamp(usize::MAX), detected());
        if let Some(p) = physical_cores() {
            assert!(p >= 1);
        }
        // preferred() reports the basis truthfully and never exceeds the
        // logical count.
        let (pref, basis) = preferred();
        assert!((1..=detected()).contains(&pref));
        match physical_cores() {
            Some(_) => assert_eq!(basis, "physical"),
            None => assert_eq!(basis, "logical"),
        }
        // sweep() starts at the single-thread baseline and never exceeds
        // the host (when the env override is not set, sweep is derived
        // from detection; when it is set, it is the resolved pin — both
        // are clamped).
        let s = sweep();
        assert!(!s.is_empty());
        assert!(s.iter().all(|&t| (1..=detected()).contains(&t)));
    }

    #[test]
    fn cpu_list_parsing() {
        assert_eq!(parse_cpu_list("0-3,8-11"), Some(vec![0, 1, 2, 3, 8, 9, 10, 11]));
        assert_eq!(parse_cpu_list("5"), Some(vec![5]));
        assert_eq!(parse_cpu_list(" 2,0 ,1\n"), Some(vec![0, 1, 2]));
        assert_eq!(parse_cpu_list("0-1,1-2"), Some(vec![0, 1, 2]), "overlaps deduplicate");
        assert_eq!(parse_cpu_list(""), None);
        assert_eq!(parse_cpu_list("3-1"), None, "reversed range is malformed");
        assert_eq!(parse_cpu_list("a-b"), None);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn cpuinfo_stanza_parse() {
        let text = "processor\t: 0\nphysical id\t: 0\ncore id\t: 0\n\n\
                    processor\t: 1\nphysical id\t: 0\ncore id\t: 1\n\n\
                    processor\t: 2\nphysical id\t: 0\ncore id\t: 0\n";
        assert_eq!(parse_cpuinfo(text), Some(vec![(0, 0, 0), (1, 0, 1), (2, 0, 0)]));
        assert_eq!(parse_cpuinfo("flags\t: fpu sse\n"), None, "no ids, no topology");
    }

    #[test]
    fn pin_plan_shapes_and_graceful_degradation() {
        assert!(pin_plan(0).is_none(), "zero slots never plan");
        if let Some(plan) = pin_plan(4) {
            assert_eq!(plan.cpus.len(), 4, "one target per requested slot");
            assert!(matches!(plan.basis, "llc" | "physical"));
            assert!(plan.cpus.iter().all(|&c| c < 1024), "targets fit the affinity mask");
        }
        if let Some(groups) = llc_groups() {
            assert!(!groups.is_empty());
            for g in &groups {
                assert!(!g.is_empty());
                assert!(g.windows(2).all(|w| w[0] < w[1]), "groups sorted, deduplicated");
            }
        }
        // Pinning to cpu 0 either takes or degrades to a loud no-op —
        // both fine, panicking is not; an absurd id must degrade.
        let _ = pin_current_thread(0);
        assert!(!pin_current_thread(usize::MAX));
    }
}
