//! Batch-first tiled traversal kernel — the crate's high-throughput
//! execution core.
//!
//! The scalar engines walk one row through the whole forest at a time;
//! each branch node is a dependent load, so the walk stalls on every
//! cache miss. Following Koschel et al. (*Fast Inference of Tree
//! Ensembles on ARM Devices*), this module instead walks **tiles of
//! [`TILE_ROWS`] independent rows in lockstep through each tree**: the
//! per-lane node loads have no data dependence on each other, so the
//! out-of-order core overlaps their miss latency instead of serializing
//! it. On top of that, the whole batch is pre-transformed into
//! ordered-u32 space **once** (FlInt's trick, amortized batch-wide), so
//! the integer variants stay integer-only end to end.
//!
//! ## Parity invariant (load-bearing — the parity suite enforces it)
//!
//! For every engine variant, the batched kernels are **bit-identical** to
//! the scalar engines: for each row, leaf payloads are accumulated in
//! ascending tree order — exactly the scalar iteration order — so float
//! sums see the same rounding sequence and u32/i64 sums are exact either
//! way. Tiling changes only *when* each tree walk happens, never the
//! per-row accumulation sequence.
//!
//! ## Scratch buffers
//!
//! The seed engines transformed rows through a fixed 128-slot stack
//! buffer and rejected wider rows. Both the scalar path
//! ([`with_ordered_row`]) and the batch path now use thread-local
//! growable scratch: no per-call allocation in steady state, no feature
//! count limit (the ≥200-feature regression tests cover this), and no
//! interior-mutability hazard on the `Sync` engines.

use super::compiled::{CompiledForest, LEAF};
use crate::flint::ordered_u32;
use crate::ir::argmax;
use std::cell::RefCell;

/// Rows walked in lockstep per tile. Eight lanes is enough to cover
/// L2-miss latency with independent work on current cores while the
/// lane state (cursor + leaf + done flag per lane) stays in registers /
/// L1.
pub const TILE_ROWS: usize = 8;

thread_local! {
    /// Scalar-path scratch: one ordered row.
    static ROW_ORD: RefCell<Vec<u32>> = RefCell::new(Vec::new());
    /// Batch-path scratch: a whole ordered batch.
    static BATCH_ORD: RefCell<Vec<u32>> = RefCell::new(Vec::new());
}

/// Run `f` on `row` transformed into ordered-u32 space using reusable
/// thread-local scratch (replaces the seed's 128-feature stack buffer;
/// any width is supported).
///
/// The buffer is moved out of the slot for the duration of `f`, so a
/// re-entrant call simply allocates a fresh buffer instead of aliasing.
#[inline]
pub fn with_ordered_row<R>(row: &[f32], f: impl FnOnce(&[u32]) -> R) -> R {
    ROW_ORD.with(|cell| {
        let mut buf = cell.take();
        buf.clear();
        buf.extend(row.iter().map(|&x| ordered_u32(x)));
        let out = f(&buf);
        cell.replace(buf);
        out
    })
}

/// Run `f` on a whole row-major batch transformed into ordered-u32 space
/// (one pass, amortized across every tree walk of the batch). Shared
/// with the GBT batch path (`crate::inference::gbt_int`).
#[inline]
pub(crate) fn with_ordered_batch<R>(rows: &[f32], f: impl FnOnce(&[u32]) -> R) -> R {
    BATCH_ORD.with(|cell| {
        let mut buf = cell.take();
        buf.clear();
        buf.extend(rows.iter().map(|&x| ordered_u32(x)));
        let out = f(&buf);
        cell.replace(buf);
        out
    })
}

/// Walk one tree over a tile of rows in the ordered-u32 domain,
/// interleaved: every loop iteration advances all unfinished lanes by one
/// node, so the per-lane loads overlap.
///
/// SAFETY of the unchecked indexing: identical argument to
/// [`CompiledForest::walk_ord`] — `Model::validate()` bounds child and
/// feature indices at compile time, and the public batch entry points
/// assert the row buffer shape once per call.
#[inline]
fn walk_tile_ord(
    f: &CompiledForest,
    t: usize,
    rows_ord: &[u32],
    tile_start: usize,
    tile_rows: usize,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert!(tile_rows <= TILE_ROWS);
    debug_assert!((tile_start + tile_rows) * f.n_features <= rows_ord.len());
    let base = f.tree_offsets[t] as usize;
    let nodes = &f.nodes_ord;
    let stride = f.n_features;
    let mut idx = [base; TILE_ROWS];
    let mut done = [false; TILE_ROWS];
    let mut remaining = tile_rows;
    while remaining > 0 {
        for r in 0..tile_rows {
            if done[r] {
                continue;
            }
            let n = unsafe { nodes.get_unchecked(idx[r]) };
            if n.feature == LEAF {
                leaves[r] = n.left;
                done[r] = true;
                remaining -= 1;
            } else {
                let x = unsafe {
                    *rows_ord.get_unchecked((tile_start + r) * stride + n.feature as usize)
                };
                idx[r] = base + if x <= n.threshold { n.left } else { n.right } as usize;
            }
        }
    }
}

/// Float-domain twin of [`walk_tile_ord`] (raw f32 compares on
/// [`CompiledForest::nodes_f32`]) for the float baseline engine.
#[inline]
fn walk_tile_f32(
    f: &CompiledForest,
    t: usize,
    rows: &[f32],
    tile_start: usize,
    tile_rows: usize,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert!(tile_rows <= TILE_ROWS);
    debug_assert!((tile_start + tile_rows) * f.n_features <= rows.len());
    let base = f.tree_offsets[t] as usize;
    let nodes = &f.nodes_f32;
    let stride = f.n_features;
    let mut idx = [base; TILE_ROWS];
    let mut done = [false; TILE_ROWS];
    let mut remaining = tile_rows;
    while remaining > 0 {
        for r in 0..tile_rows {
            if done[r] {
                continue;
            }
            let n = unsafe { nodes.get_unchecked(idx[r]) };
            if n.feature == LEAF {
                leaves[r] = n.left;
                done[r] = true;
                remaining -= 1;
            } else {
                let x =
                    unsafe { *rows.get_unchecked((tile_start + r) * stride + n.feature as usize) };
                idx[r] = base + if x <= n.threshold { n.left } else { n.right } as usize;
            }
        }
    }
}

/// Shape-check a flat row-major batch; returns the row count.
fn batch_rows(f: &CompiledForest, rows: &[f32]) -> usize {
    assert!(f.n_features > 0);
    assert!(
        rows.len() % f.n_features == 0,
        "batch length {} is not a multiple of n_features {}",
        rows.len(),
        f.n_features
    );
    rows.len() / f.n_features
}

/// Batched float engine accumulation: averaged per-class probabilities,
/// flat `n_rows * n_classes`, bit-identical to
/// `FloatEngine::accumulate` per row.
pub fn float_proba_batch(f: &CompiledForest, rows: &[f32]) -> Vec<f32> {
    let n_rows = batch_rows(f, rows);
    let c = f.n_classes;
    let mut acc = vec![0.0f32; n_rows * c];
    let mut leaves = [0u32; TILE_ROWS];
    let mut tile_start = 0;
    while tile_start < n_rows {
        let tile_rows = TILE_ROWS.min(n_rows - tile_start);
        for t in 0..f.n_trees {
            walk_tile_f32(f, t, rows, tile_start, tile_rows, &mut leaves);
            for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                let leaf = &f.leaf_f32[p as usize * c..(p as usize + 1) * c];
                let row_acc = &mut acc[(tile_start + r) * c..(tile_start + r + 1) * c];
                for (a, &v) in row_acc.iter_mut().zip(leaf) {
                    *a += v;
                }
            }
        }
        tile_start += tile_rows;
    }
    let inv = 1.0 / f.n_trees as f32;
    for a in &mut acc {
        *a *= inv;
    }
    acc
}

/// Batched FlInt accumulation: ordered-u32 compares (whole batch
/// transformed once), float accumulation — flat `n_rows * n_classes`,
/// bit-identical to `FlIntEngine`'s per-row path.
pub fn flint_proba_batch(f: &CompiledForest, rows: &[f32]) -> Vec<f32> {
    let n_rows = batch_rows(f, rows);
    let c = f.n_classes;
    with_ordered_batch(rows, |rows_ord| {
        let mut acc = vec![0.0f32; n_rows * c];
        let mut leaves = [0u32; TILE_ROWS];
        let mut tile_start = 0;
        while tile_start < n_rows {
            let tile_rows = TILE_ROWS.min(n_rows - tile_start);
            for t in 0..f.n_trees {
                walk_tile_ord(f, t, rows_ord, tile_start, tile_rows, &mut leaves);
                for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                    let leaf = &f.leaf_f32[p as usize * c..(p as usize + 1) * c];
                    let row_acc = &mut acc[(tile_start + r) * c..(tile_start + r + 1) * c];
                    for (a, &v) in row_acc.iter_mut().zip(leaf) {
                        *a += v;
                    }
                }
            }
            tile_start += tile_rows;
        }
        let inv = 1.0 / f.n_trees as f32;
        for a in &mut acc {
            *a *= inv;
        }
        acc
    })
}

/// Batched InTreeger accumulation: ordered-u32 compares, `u32`
/// fixed-point sums — flat `n_rows * n_classes`, bit-identical to
/// `IntEngine::predict_fixed` per row. Integer-only after the one
/// batch-wide transform.
pub fn int_fixed_batch(f: &CompiledForest, rows: &[f32]) -> Vec<u32> {
    let n_rows = batch_rows(f, rows);
    let c = f.n_classes;
    with_ordered_batch(rows, |rows_ord| {
        let mut acc = vec![0u32; n_rows * c];
        let mut leaves = [0u32; TILE_ROWS];
        let mut tile_start = 0;
        while tile_start < n_rows {
            let tile_rows = TILE_ROWS.min(n_rows - tile_start);
            for t in 0..f.n_trees {
                walk_tile_ord(f, t, rows_ord, tile_start, tile_rows, &mut leaves);
                for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                    let leaf = &f.leaf_u32[p as usize * c..(p as usize + 1) * c];
                    let row_acc = &mut acc[(tile_start + r) * c..(tile_start + r + 1) * c];
                    for (a, &v) in row_acc.iter_mut().zip(leaf) {
                        // Exact: quant::max_accumulated bounds the sum below
                        // u32::MAX (same argument as the scalar engine).
                        *a += v;
                    }
                }
            }
            tile_start += tile_rows;
        }
        acc
    })
}

/// Per-row argmax over a flat `n_rows * n_classes` score matrix.
pub fn argmax_rows<T: PartialOrd + Copy>(flat: &[T], n_classes: usize) -> Vec<u32> {
    assert!(n_classes > 0);
    assert!(flat.len() % n_classes == 0);
    flat.chunks_exact(n_classes).map(argmax).collect()
}

/// Split a flat `n_rows * n_classes` matrix into per-row vectors (the
/// shape the serving layer hands back to clients).
pub fn split_rows<T: Clone>(flat: Vec<T>, n_classes: usize) -> Vec<Vec<T>> {
    assert!(n_classes > 0);
    assert!(flat.len() % n_classes == 0);
    flat.chunks_exact(n_classes).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn forest() -> CompiledForest {
        let ds = shuttle_like(1200, 21);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 9, max_depth: 6, ..Default::default() },
            21,
        );
        CompiledForest::compile(&m)
    }

    #[test]
    fn tiled_walks_match_scalar_walks() {
        let f = forest();
        let ds = shuttle_like(300, 22);
        let n = 100usize;
        let rows = &ds.features[..n * ds.n_features];
        let rows_ord: Vec<u32> = rows.iter().map(|&x| ordered_u32(x)).collect();
        let mut leaves = [0u32; TILE_ROWS];
        let mut tile_start = 0;
        while tile_start < n {
            let tile_rows = TILE_ROWS.min(n - tile_start);
            for t in 0..f.n_trees {
                walk_tile_ord(&f, t, &rows_ord, tile_start, tile_rows, &mut leaves);
                for r in 0..tile_rows {
                    let row_ord: Vec<u32> =
                        ds.row(tile_start + r).iter().map(|&x| ordered_u32(x)).collect();
                    let want = f.walk_ord(t, &row_ord);
                    assert_eq!(leaves[r], want, "tree {t} row {}", tile_start + r);
                    assert_eq!(leaves[r], f.walk_f32(t, ds.row(tile_start + r)));
                }
            }
            tile_start += tile_rows;
        }
    }

    #[test]
    fn batch_shapes() {
        let f = forest();
        let ds = shuttle_like(50, 23);
        let rows = &ds.features[..10 * ds.n_features];
        assert_eq!(float_proba_batch(&f, rows).len(), 10 * f.n_classes);
        assert_eq!(flint_proba_batch(&f, rows).len(), 10 * f.n_classes);
        assert_eq!(int_fixed_batch(&f, rows).len(), 10 * f.n_classes);
        assert!(float_proba_batch(&f, &[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "multiple of n_features")]
    fn ragged_batch_rejected() {
        let f = forest();
        int_fixed_batch(&f, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn argmax_and_split_helpers() {
        let flat = vec![1u32, 5, 2, 9, 0, 0];
        assert_eq!(argmax_rows(&flat, 3), vec![1, 0]);
        assert_eq!(split_rows(flat, 3), vec![vec![1, 5, 2], vec![9, 0, 0]]);
    }

    #[test]
    fn ordered_row_scratch_reusable_and_reentrant() {
        let row = [1.0f32, -2.0, 3.0];
        let out = with_ordered_row(&row, |a| {
            // Re-entrant use must not alias the outer buffer.
            let inner = with_ordered_row(&[4.0f32], |b| b.to_vec());
            assert_eq!(inner, vec![ordered_u32(4.0)]);
            a.to_vec()
        });
        let want: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
        assert_eq!(out, want);
        // Second call reuses the (restored) scratch.
        let out2 = with_ordered_row(&row, |a| a.to_vec());
        assert_eq!(out2, want);
    }
}
